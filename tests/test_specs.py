"""Dry-run cell construction for ALL 40 (arch x shape) cells: shape math,
spec trees and step functions must build without a mesh (no allocation, no
compile — the compile proof is scripts/run_dryruns.sh + its artifacts)."""

import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS, SHAPE_IDS, SHAPES, get_config, shape_applicable)
from repro.launch.specs import build_cell
from repro.models.config import ModelConfig
from repro.models.model import param_specs
from repro.models.params import Spec, is_spec
from repro.utils.tree import tree_size_bytes

import jax


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", SHAPE_IDS)
def test_cell_builds(arch, shape):
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        pytest.skip("long_500k x full attention (DESIGN.md §4)")
    cell = build_cell(cfg, shape, mesh=None)
    leaves = jax.tree.leaves(cell.args)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert cell.tokens_per_step == (
        SHAPES[shape]["global_batch"] * SHAPES[shape]["seq_len"]
        if cell.kind != "decode" else SHAPES[shape]["global_batch"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_bytes_reasonable(arch):
    """bf16 weights of the full config match param_count (shape math)."""
    cfg = get_config(arch)
    specs = param_specs(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        specs, is_leaf=is_spec))
    assert n == cfg.param_count()


def test_decode_cache_bytes_vs_hand_count():
    """yi-9b decode_32k KV cache: 48L x 2 x 4 kvh x 128 d x 32768 s x 128 b
    x 2B = ~412 GB global."""
    from repro.models.model import cache_specs
    cfg = get_config("yi-9b")
    cs = cache_specs(cfg, batch=128, max_seq=32768)
    total = sum(int(np.prod(s.shape)) * 2 for s in jax.tree.leaves(
        cs, is_leaf=is_spec))
    expect = 48 * 2 * 4 * 128 * 32768 * 128 * 2
    assert total == expect
