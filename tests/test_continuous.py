"""Continuous batching on the real engine: correctness (same tokens as the
batch engine) and the iteration-level scheduling benefit."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.continuous import serve_continuous, splice_cache
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, num_layers=2,
                              decode_cache_update="scatter")
    return Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                    prompt_bucket=16))


def test_continuous_matches_batch_tokens(engine):
    """Every request produces exactly its target count, and the first
    generated token matches the padded-batch engine (same greedy path)."""
    prompts = [np.arange(5, dtype=np.int32) + 3 * i for i in range(5)]
    targets = [6, 2, 9, 4, 3]
    res = serve_continuous(engine, prompts, targets, slots=2)
    assert list(res.produced) == targets
    assert np.isfinite(res.completion).all()
    # short requests complete before the longest
    assert res.completion[1] < res.completion[2]


def test_continuous_greedy_consistency(engine):
    """A single request served continuously == the batch engine's output
    count and timing structure (1 prefill + target-1 decode steps)."""
    prompts = [np.arange(4, dtype=np.int32)]
    res = serve_continuous(engine, prompts, [5], slots=2)
    assert list(res.produced) == [5]
    assert res.decode_steps >= 4


def test_splice_preserves_other_slots(engine):
    """Splicing a new request into slot 0 must not perturb slot 1."""
    cfg = engine.cfg
    pool = engine.new_cache(2)
    # fill slot 1 with a sentinel pattern
    pool = jax.tree.map(lambda l: l.at[:, 1].set(1.5), pool)
    single, lens, last, _, _ = engine.prefill_batch(
        [np.arange(4, dtype=np.int32)])
    spliced = splice_cache(cfg, pool, single, 0, 2, engine.ecfg.max_seq)
    for leaf in jax.tree.leaves(spliced):
        np.testing.assert_array_equal(np.asarray(leaf[:, 1]),
                                      np.full_like(np.asarray(leaf[:, 1]), 1.5))


def test_continuous_interleaves_admissions(engine):
    """With 2 slots and 4 requests, later requests must start before the
    earliest long request completes (iteration-level refill)."""
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(4)]
    targets = [12, 2, 2, 2]
    res = serve_continuous(engine, prompts, targets, slots=2)
    assert list(res.produced) == targets
    # request 3's TTFT must come before request 0's completion
    assert res.ttft[3] < res.completion[0]
