"""Fault-injection suite (PR 6): the robustness contract across layers.

Pins :mod:`repro.core.faults` + :mod:`repro.serving.resilience`:

  * salted fault streams: same seed => bit-identical traces/drop masks,
    and fault draws never perturb the workload stream;
  * the operational-time transform round-trips and skips crash flats;
  * fault rate 0 => every layer is BIT-EQUAL to the PR 5 fault-free path
    (oracle fleet, fast fleet, serving FleetScheduler);
  * faults on => oracle ≡ fastsim per (router × policy): identical kill
    sets, retries, shed counts and per-request wait trajectories;
  * masked backlog routing: NumPy reference ≡ jitted kernel;
  * conservation: served + shed + failed + unserved == arrived, on the
    sim layer and the serving layer;
  * ``bulk.breakdown_wait`` (M/G/1 with breakdowns + envelope arm)
    matches the fault-injected simulation within tolerance;
  * serving resilience: a mid-run replica kill completes every non-shed
    request exactly once (first-completion-wins dedup), hedging produces
    wins, and the controller learns availability / recommends shedding;
  * engine guard: non-finite logits fall back to greedy per slot and are
    counted (``sample_fallbacks``).
"""

import dataclasses

import numpy as np
import pytest

from repro.core.bulk import breakdown_wait
from repro.core.distributions import LogNormalTokens
from repro.core.faults import (
    FAULTS, CrashRepair, NoFaults, ReplicaTrace, RequestDrop, Slowdown,
    _fault_rng, default_faults, effective_lambda, fault_from_spec,
    masked_assign, simulate_fleet_faulty, up_matrix)
from repro.core.fastsim import (
    masked_backlog_route, simulate_fleet_fast, simulate_policy_fast)
from repro.core.fleet import (
    ROUTERS, _masked_backlog_assign_np, route_oracle)
from repro.core.latency_model import BatchLatencyModel
from repro.core.policies import (
    DynamicPolicy, FixedPolicy, single_from_batch)
from repro.core.simulate import simulate_policy
from repro.data.pipeline import make_request_stream
from repro.serving.router import FleetScheduler, summarize_fleet
from repro.serving.scheduler import ModelClock

LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
LN = LogNormalTokens(7.0, 0.7)
CLOCK = ModelClock(single_from_batch(LAT), LAT)
CRASH = CrashRepair(mtbf=120.0, mttr=8.0)


# ---------------------------------------------------------------------------
# registry + streams
# ---------------------------------------------------------------------------

def test_registry_and_spec_forms():
    assert set(default_faults()) == {"none", "crash", "slowdown", "drop"}
    assert set(default_faults()) <= set(FAULTS) | {"none"} or True
    f = fault_from_spec({"kind": "crash", "mtbf": 50.0, "mttr": 5.0})
    assert isinstance(f, CrashRepair) and f.mtbf == 50.0
    assert isinstance(fault_from_spec("drop"), RequestDrop)
    assert isinstance(fault_from_spec(None), NoFaults)
    assert fault_from_spec(f) is f
    assert NoFaults().is_null and not CRASH.is_null


def test_trace_determinism_and_stream_isolation():
    t1 = CRASH.trace(7, 0, 5000.0)
    t2 = CRASH.trace(7, 0, 5000.0)
    np.testing.assert_array_equal(t1.starts, t2.starts)
    np.testing.assert_array_equal(t1.ends, t2.ends)
    # different replica / seed -> different episodes
    assert not np.array_equal(t1.starts, CRASH.trace(7, 1, 5000.0).starts)
    assert not np.array_equal(t1.starts, CRASH.trace(8, 0, 5000.0).starts)
    # drop mask deterministic
    d = RequestDrop(p=0.1)
    np.testing.assert_array_equal(d.drop_mask(3, 500), d.drop_mask(3, 500))
    # fault draws live on a salted stream: the workload a policy samples
    # is untouched by the fault model consuming its own lanes
    pol = DynamicPolicy(32)
    wl1 = pol.sample_workload(2.0, LN, 200, seed=5)
    _ = CRASH.trace(5, 0, 1000.0)
    _ = d.drop_mask(5, 200)
    wl2 = pol.sample_workload(2.0, LN, 200, seed=5)
    np.testing.assert_array_equal(wl1.arrivals, wl2.arrivals)
    np.testing.assert_array_equal(wl1.tokens, wl2.tokens)
    # salted lanes are distinct from each other
    a = _fault_rng(5, 1).random(4)
    b = _fault_rng(5, 2).random(4)
    assert not np.array_equal(a, b)


def test_episode_structure():
    tr = CRASH.trace(0, 0, 20_000.0)
    assert len(tr.starts) == len(tr.ends) > 0
    assert (tr.ends >= tr.starts).all()
    assert (np.diff(tr.starts) > 0).all()
    assert (tr.starts[1:] >= tr.ends[:-1]).all()        # disjoint
    assert tr.speed == 0.0
    sl = Slowdown(mtbf=100.0, duration=10.0, factor=4.0).trace(0, 0, 5000.0)
    assert 0.0 < sl.speed < 1.0
    assert len(sl.crash_starts()) == 0                  # stragglers accept


def test_operational_time_round_trip():
    tr = ReplicaTrace(np.array([10.0, 40.0]), np.array([15.0, 55.0]), 0.0)
    t = np.array([0.0, 5.0, 10.0, 12.0, 15.0, 30.0, 60.0])
    u = tr.op_time(t)
    # capacity is flat inside crash episodes, slope 1 outside
    np.testing.assert_allclose(u, [0.0, 5.0, 10.0, 10.0, 10.0, 25.0, 40.0])
    # wall_time skips flats: service landing on a flat resumes at the end
    np.testing.assert_allclose(tr.wall_time(np.array([10.0])), [15.0])
    np.testing.assert_allclose(tr.wall_time(np.array([35.0])), [55.0])
    # round trip off the flats
    off = np.array([3.0, 8.0, 20.0])
    np.testing.assert_allclose(tr.op_time(tr.wall_time(off)), off)
    # up/down queries
    np.testing.assert_array_equal(
        tr.up_at(t), [True, True, False, False, True, True, True])
    np.testing.assert_allclose(tr.next_up(np.array([12.0, 20.0])),
                               [15.0, 20.0])
    assert tr.availability(60.0) == pytest.approx(1.0 - 20.0 / 60.0)
    # straggler: fractional slope, no flat skip
    sl = ReplicaTrace(np.array([10.0]), np.array([20.0]), 0.5)
    np.testing.assert_allclose(sl.op_time(np.array([20.0])), [15.0])
    np.testing.assert_allclose(sl.wall_time(np.array([12.5])), [15.0])


def test_effective_lambda():
    assert effective_lambda(2.0, NoFaults()) == 2.0
    a = CRASH.mtbf / (CRASH.mtbf + CRASH.mttr)
    assert effective_lambda(2.0, CRASH) == pytest.approx(2.0 / a)


# ---------------------------------------------------------------------------
# masked routing: NumPy reference ≡ jitted kernel
# ---------------------------------------------------------------------------

def test_masked_backlog_np_equals_jit():
    rng = np.random.default_rng(0)
    n, R = 400, 4
    arr = np.cumsum(rng.exponential(0.3, n))
    work = rng.exponential(1.0, n)
    up = rng.random((n, R)) > 0.25
    up[~up.any(axis=1)] = True          # at least one live replica per row
    ref = _masked_backlog_assign_np(arr, work, R, up)
    jit = masked_backlog_route(arr, work, up, R)
    np.testing.assert_array_equal(ref, np.asarray(jit))
    # all-up masked routing equals the unmasked PR 5 assignment
    all_up = np.ones((n, R), bool)
    r0 = ROUTERS["least_work"]()
    base = r0.assign(arr, work, R, 0)
    np.testing.assert_array_equal(
        masked_assign(r0, arr, work, R, 0, all_up), np.asarray(base))


def test_masked_assign_avoids_down_replicas():
    tr = ReplicaTrace(np.array([0.0]), np.array([1e9]), 0.0)   # 0 dead
    traces = [tr] + [CRASH.trace(0, r, 100.0) for r in (1, 2)]
    arr = np.linspace(0.0, 50.0, 100)
    up = up_matrix(traces, arr)
    assert not up[:, 0].any()
    for name, mk in ROUTERS.items():
        rep = masked_assign(mk(), arr, np.ones(100), 3, 0, up)
        assert (np.asarray(rep) != 0).all(), name


# ---------------------------------------------------------------------------
# zero-fault bit-equality with the PR 5 fleet
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fast", [False, True], ids=["oracle", "fast"])
def test_zero_fault_is_pr5_fleet(fast):
    res = simulate_fleet_faulty("least_work", DynamicPolicy(16), 4.0, 3,
                                LN, LAT, None, num_requests=600, seed=1,
                                fast=fast)
    if fast:
        ref = simulate_fleet_fast("least_work", DynamicPolicy(16), 4.0, 3,
                                  LN, LAT, num_requests=600, seed=1)
    else:
        ref = route_oracle("least_work", DynamicPolicy(16), 4.0, 3,
                           LN, LAT, num_requests=600, seed=1)
    assert res["shed"] == res["retries"] == res["failed"] == 0
    np.testing.assert_array_equal(res["replica_of"], ref["replica_of"])
    for r in range(3):
        np.testing.assert_array_equal(res["per_replica"][r]["waits"],
                                      ref["per_replica"][r]["waits"])


# ---------------------------------------------------------------------------
# oracle ≡ fastsim under faults, per (router × policy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("router", sorted(ROUTERS))
@pytest.mark.parametrize("policy", [DynamicPolicy(16), FixedPolicy(8)],
                         ids=["dynamic", "fixed"])
def test_oracle_equals_fast_under_crash(router, policy):
    kw = dict(lam=4.0, R=3, dist=LN, lat=LAT, fault=CRASH,
              num_requests=500, seed=2)
    o = simulate_fleet_faulty(router, policy, fast=False, **kw)
    f = simulate_fleet_faulty(router, policy, fast=True, **kw)
    assert o["retries"] == f["retries"]
    assert o["failed"] == f["failed"]
    assert o["shed"] == f["shed"] == 0
    np.testing.assert_array_equal(o["served_mask"], f["served_mask"])
    np.testing.assert_array_equal(o["replica_of"], f["replica_of"])
    m = o["served_mask"]
    np.testing.assert_allclose(o["waits_by_request"][m],
                               f["waits_by_request"][m],
                               rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("fault", ["slowdown", "drop"])
def test_oracle_equals_fast_other_faults(fault):
    kw = dict(lam=4.0, R=3, dist=LN, lat=LAT,
              fault=default_faults()[fault], num_requests=500, seed=3)
    o = simulate_fleet_faulty("jsq", DynamicPolicy(16), fast=False, **kw)
    f = simulate_fleet_faulty("jsq", DynamicPolicy(16), fast=True, **kw)
    np.testing.assert_array_equal(o["served_mask"], f["served_mask"])
    np.testing.assert_array_equal(o["replica_of"], f["replica_of"])
    m = o["served_mask"]
    np.testing.assert_allclose(o["waits_by_request"][m],
                               f["waits_by_request"][m],
                               rtol=1e-6, atol=1e-9)
    if fault == "drop":
        assert o["shed"] == f["shed"] > 0


def test_conservation_and_availability():
    res = simulate_fleet_faulty(
        "round_robin", DynamicPolicy(16), 4.0, 3, LN, LAT,
        CrashRepair(mtbf=60.0, mttr=10.0), num_requests=500, seed=4)
    assert (res["n_served"] + res["shed"] + res["failed"]
            + res["unserved"] == res["n_arrived"])
    assert res["retries"] > 0
    for a in res["availability"]:
        assert 0.0 < a <= 1.0


def test_single_server_fault_trace_injection():
    """simulate_policy(fault_trace=) agrees with its fast twin and slows
    the queue down relative to fault-free."""
    tr = CRASH.trace(11, 0, 10_000.0)
    pol = DynamicPolicy(16)
    o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=6,
                        fault_trace=tr)
    f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400, seed=6,
                             fault_trace=tr)
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=1e-6,
                               atol=1e-9)
    base = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=6)
    assert o["mean_wait"] >= base["mean_wait"]


# ---------------------------------------------------------------------------
# analytics: M/G/1 with breakdowns
# ---------------------------------------------------------------------------

def test_breakdown_wait_fcfs_matches_sim():
    from repro.core.policies import FCFSPolicy
    mtbf, mttr, lam = 300.0, 12.0, 0.02
    single = single_from_batch(LAT)
    got = breakdown_wait(LN, single, lam, mtbf, mttr)["wait"]
    sims = []
    for seed in range(3):
        tr = CrashRepair(mtbf=mtbf, mttr=mttr).trace(seed, 0, 1e9)
        sims.append(simulate_policy(FCFSPolicy(), lam, LN, single,
                                    num_requests=60_000, seed=seed,
                                    fault_trace=tr)["mean_wait"])
    sim = float(np.mean(sims))
    assert got == pytest.approx(sim, rel=0.15)
    # reduces to plain PK as faults vanish
    from repro.core.mg1 import pollaczek_khinchine
    nofault = breakdown_wait(LN, single, lam, 1e12, 1e-6)["wait"]
    es, es2 = single.moments(LN, None)
    assert nofault == pytest.approx(
        pollaczek_khinchine(lam, es, es2), rel=1e-3)


def test_breakdown_wait_envelope_arm():
    out = breakdown_wait(LN, LAT, 4.0, 200.0, 10.0, R=3,
                         policy=DynamicPolicy(16))
    a = 200.0 / 210.0
    assert out["availability"] == pytest.approx(a)
    assert out["lam_eff"] == pytest.approx(4.0 / 3 / a)
    base = DynamicPolicy(16).analytic_delay(4.0 / 3, LN, LAT)
    # dilation + residual repair both push the wait ABOVE fault-free
    assert out["kind"] == "envelope" and out["wait"] > base


# ---------------------------------------------------------------------------
# serving layer: resilience
# ---------------------------------------------------------------------------

def _reqs(n=200, lam=3.0, seed=0):
    return make_request_stream(n, lam=lam, dist=LN, vocab=512, seed=seed)


def test_serving_zero_fault_bit_equal_to_pr5():
    reqs = _reqs()
    base = FleetScheduler("least_work", DynamicPolicy(16), CLOCK, 3).run(
        reqs)
    res = FleetScheduler("least_work", DynamicPolicy(16), CLOCK, 3,
                         faults=None, kill_at=None).run(reqs)
    # no knobs -> PR 5 body verbatim
    np.testing.assert_array_equal(base.waits, res.waits)
    np.testing.assert_array_equal(base.replica_of, res.replica_of)
    # the null fault model through the resilient path must agree too
    res2 = FleetScheduler("least_work", DynamicPolicy(16), CLOCK, 3,
                         faults="none").run(reqs)
    np.testing.assert_array_equal(base.replica_of, res2.replica_of)
    np.testing.assert_allclose(base.waits, res2.waits, rtol=1e-9,
                               atol=1e-12)


def test_midrun_kill_exactly_once():
    """Kill replica 0 mid-run: every non-shed request completes EXACTLY
    once, none on the dead replica after the kill."""
    reqs = _reqs(250)
    kill_t = float(np.median([r.arrival for r in reqs]))
    sched = FleetScheduler("jsq", DynamicPolicy(16), CLOCK, 3,
                           kill_at={0: kill_t}, seed=1)
    res = sched.run(reqs)
    rep = res.resilience
    assert rep.arrived == len(reqs)
    assert rep.served + rep.shed + rep.failed == rep.arrived
    assert rep.shed == 0 and rep.failed == 0
    assert rep.retries > 0
    assert rep.kill_events
    # exactly once: every request has one finite wait, one final replica
    assert np.isfinite(res.waits).all()
    assert (res.replica_of >= 0).all()
    # nothing STARTS service on the dead replica after the kill
    starts = np.array([r.arrival for r in reqs]) + res.waits
    on_dead = res.replica_of == 0
    assert (starts[on_dead] <= kill_t + 1e-9).all()
    assert rep.availability[0] < 1.0


def test_serving_determinism_and_summary():
    reqs = _reqs(150)
    mk = lambda: FleetScheduler(
        "least_work", DynamicPolicy(16), CLOCK, 3,
        faults=CrashRepair(mtbf=80.0, mttr=6.0), seed=2,
        shed_prob=0.05).run(reqs)
    r1, r2 = mk(), mk()
    np.testing.assert_array_equal(r1.waits, r2.waits)
    np.testing.assert_array_equal(r1.replica_of, r2.replica_of)
    assert r1.resilience.shed == r2.resilience.shed > 0
    s = summarize_fleet(r1)
    for k in ("served", "shed", "failed", "retries", "hedged",
              "hedge_wins", "kill_events", "availability",
              "p99_wait"):
        assert k in s, k
    assert s["served"] + s["shed"] + s["failed"] == len(reqs)


def test_hedging_dedup_first_completion_wins():
    reqs = _reqs(300, lam=8.0)
    res = FleetScheduler("random", DynamicPolicy(16), CLOCK, 3,
                         hedge_slo=0.05, seed=3).run(reqs)
    rep = res.resilience
    assert rep.hedged > 0
    assert 0 <= rep.hedge_wins <= rep.hedged
    # dedup: hedged copies never double-count completions
    assert rep.served == rep.arrived
    assert np.isfinite(res.waits).all()


def test_controller_learns_availability():
    from repro.core.control import AdaptiveController
    ctl = AdaptiveController(single_from_batch(LAT), LAT, max_replicas=4,
                             elastic_available=False)
    assert ctl.availability_hat() == 1.0
    for _ in range(10):
        ctl.observe_episode(90.0, 10.0)
    assert ctl.availability_hat() == pytest.approx(0.9)
    # overload => positive shed recommendation; scales with availability
    p = ctl.shed_probability(100.0, LN)
    assert 0.0 < p < 1.0
    assert ctl.shed_probability(1e-6, LN) == 0.0
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(200):
        t += rng.exponential(1 / 50.0)
        ctl.observe_arrival(t)
        ctl.observe_completion(int(LN.sample(rng, 1)[0]))
    rec = ctl.recommendation()
    assert rec.availability == pytest.approx(0.9)
    assert 0.0 <= rec.shed_prob <= 1.0


# ---------------------------------------------------------------------------
# engine guard
# ---------------------------------------------------------------------------

def test_engine_logit_guard_unit():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.serving.engine import _guarded_argmax, _sample_tokens
    logits = jnp.array([[1.0, 3.0, 2.0],
                        [jnp.nan, 5.0, 1.0],
                        [jnp.inf, 0.0, 0.0]])
    tok, bad = _guarded_argmax(logits)
    np.testing.assert_array_equal(np.asarray(bad), [False, True, True])
    assert int(tok[0]) == 1
    # guarded rows still emit a VALID token (greedy over finite entries)
    assert int(tok[1]) == 1 and int(tok[2]) in (1, 2)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3))
    t2, b2 = _sample_tokens(keys, logits, 0.8, 2)
    np.testing.assert_array_equal(np.asarray(b2), [False, True, True])
    assert int(t2[1]) == 1                      # fell back to greedy
    # finite logits: bit-identical to the unguarded path, bad stays False
    fin = jax.random.normal(jax.random.PRNGKey(0), (4, 11))
    tg, bg = _guarded_argmax(fin)
    np.testing.assert_array_equal(np.asarray(tg),
                                  np.asarray(jnp.argmax(fin, axis=-1)))
    assert not np.asarray(bg).any()


@pytest.mark.slow
def test_engine_fallback_counter_end_to_end():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import jax.tree_util as jtu
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                   prompt_bucket=16))
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    eng.generate(prompts, [6, 4, 5])
    assert eng.sample_fallbacks == 0            # healthy model: no guard
    leaves, tree = jtu.tree_flatten(eng.params)
    eng.params = jtu.tree_unflatten(
        tree, [l.at[...].set(jnp.nan) if hasattr(l, "at") else l
               for l in leaves])
    res = eng.generate(prompts, [5, 5, 5])
    assert list(res["produced"]) == [5, 5, 5]   # generation still finishes
    assert eng.sample_fallbacks > 0


# ---------------------------------------------------------------------------
# chaos smoke (hypothesis, optional dep)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False


def _chaos_body(seed, mtbf, mttr, p):
    """Any (seed, MTBF, MTTR, drop-p): accounting always closes —
    served + shed + failed + unserved == arrived."""
    res = simulate_fleet_faulty(
        "round_robin", DynamicPolicy(16), 4.0, 2, LN, LAT,
        CrashRepair(mtbf=mtbf, mttr=mttr), num_requests=150, seed=seed)
    drop = simulate_fleet_faulty(
        "random", DynamicPolicy(16), 4.0, 2, LN, LAT,
        RequestDrop(p=p), num_requests=150, seed=seed)
    for r in (res, drop):
        assert (r["n_served"] + r["shed"] + r["failed"] + r["unserved"]
                == r["n_arrived"])


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), mtbf=st.floats(20.0, 500.0),
           mttr=st.floats(1.0, 50.0), p=st.floats(0.0, 0.3))
    def test_chaos_conservation(seed, mtbf, mttr, p):
        _chaos_body(seed, mtbf, mttr, p)
else:                                            # pragma: no cover
    def test_chaos_conservation():
        """Deterministic fallback sweep when hypothesis is unavailable."""
        for seed in (0, 7, 42):
            _chaos_body(seed, 60.0, 10.0, 0.1)
