"""PR 8 conformance harness, control half: closed-loop autoscaling.

Pins the closed-loop driver (``control.simulate_controlled`` /
``fastsim.run_controlled``), the controller laws it consults, and the
serving-layer scale-schedule drain protocol:

1. **Controller units** — ``observe_episode``/``availability_hat``
   renewal math, ``shed_probability`` edges (idle, overload,
   availability discount) and ``fleet.recommend_replicas`` edges
   (lam -> 0, lam near capacity, max_replicas clamp).
2. **Driver conformance** — controller-action determinism, fast==oracle
   trajectory equality, and a single-window fixed R=1 run pinned
   bit-exactly to the plain PR 2 simulator.
3. **Scale-schedule conservation** — scaling the serving fleet down
   mid-run (including during a crash episode) never loses a request:
   served + shed + failed == arrived.

Multi-seed regret sweeps live behind the ``regret`` marker
(``--runregret``) so tier-1 stays fast.
"""

import numpy as np
import pytest

from repro.core.control import (AdaptiveController, pow2_replicas,
                                simulate_controlled)
from repro.core.distributions import LogNormalTokens
from repro.core.fastsim import run_controlled
from repro.core.fleet import recommend_replicas
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import DynamicPolicy, ElasticPolicy, single_from_batch
from repro.core.simulate import no_warmup, simulate_policy
from repro.core.traffic import SinusoidTraffic
from repro.serving.resilience import ResilientFleetScheduler, scale_spans
from repro.serving.scheduler import ModelClock, Request

LN = LogNormalTokens(5.0, 0.6)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
SINGLE = single_from_batch(LAT)


# ---------------------------------------------------------------------------
# 1: controller units
# ---------------------------------------------------------------------------

def _controller(**kw):
    kw.setdefault("max_replicas", 8)
    return AdaptiveController(SINGLE, LAT, **kw)


def test_availability_hat_renewal_math():
    c = _controller()
    assert c.availability_hat() == 1.0          # fault-free prior
    c.observe_episode(90.0, 10.0)
    assert abs(c.availability_hat() - 0.9) < 1e-12
    c.observe_episode(30.0, 70.0)               # pooled, not averaged
    assert abs(c.availability_hat() - 120.0 / 200.0) < 1e-12


def test_shed_probability_idle_and_overload():
    c = _controller(replica_target_util=0.5)
    assert c.shed_probability(0.0, LN) == 0.0   # lam -> 0: admit all
    assert c.shed_probability(2.0, None) == 0.0  # no dist yet: admit all
    alpha = LAT.k1 + LAT.k3 * LN.mean()
    cap = 8 * 0.5 / alpha                        # full-availability edge
    assert c.shed_probability(cap * 0.99, LN) == 0.0
    p = c.shed_probability(cap * 2.0, LN)
    assert abs(p - 0.5) < 1e-9                   # shed exactly the excess
    assert 0.0 <= c.shed_probability(cap * 100.0, LN) <= 1.0


def test_shed_probability_availability_discount():
    c = _controller(replica_target_util=0.5)
    alpha = LAT.k1 + LAT.k3 * LN.mean()
    lam = 8 * 0.5 / alpha                        # exactly at capacity
    assert c.shed_probability(lam, LN) <= 1e-9
    c.observe_episode(50.0, 50.0)                # availability drops to 0.5
    p = c.shed_probability(lam, LN)
    assert abs(p - 0.5) < 1e-9                   # half the fleet is gone


def test_recommend_replicas_edges():
    assert recommend_replicas(1e-9, LN, LAT) == 1       # lam -> 0
    r_mid = recommend_replicas(4.0, LN, LAT, max_replicas=64)
    assert 1 <= r_mid <= 64
    # near-capacity load needs more replicas than light load
    assert recommend_replicas(16.0, LN, LAT, max_replicas=64) > \
        recommend_replicas(0.5, LN, LAT, max_replicas=64)
    # the clamp binds
    assert recommend_replicas(1e6, LN, LAT, max_replicas=8) == 8


def test_pow2_replicas():
    assert pow2_replicas(1, 8) == 1
    assert pow2_replicas(3, 8) == 4
    assert pow2_replicas(5, 8) == 8
    assert pow2_replicas(9, 8) == 8     # clamped to largest pow2 <= max
    assert pow2_replicas(5, 6) == 4     # max_replicas itself not a pow2


# ---------------------------------------------------------------------------
# 2: driver conformance
# ---------------------------------------------------------------------------

CTRL_KW = dict(traffic=SinusoidTraffic(amplitude=0.8, period=250.0),
               num_requests=2_000, seed=1, window=50.0, max_replicas=4,
               replica_cost=1.0)


def test_controller_actions_deterministic():
    a = run_controlled(ElasticPolicy(), 4.0, LN, LAT, **CTRL_KW)
    b = run_controlled(ElasticPolicy(), 4.0, LN, LAT, **CTRL_KW)
    assert a.actions == b.actions
    assert np.array_equal(a.waits, b.waits)
    assert a.objective == b.objective


def test_fast_equals_oracle_trajectory():
    f = simulate_controlled(ElasticPolicy(), 4.0, LN, LAT, fast=True,
                            **CTRL_KW)
    o = simulate_controlled(ElasticPolicy(), 4.0, LN, LAT, fast=False,
                            **CTRL_KW)
    assert f.actions == o.actions
    np.testing.assert_allclose(f.waits, o.waits, rtol=0, atol=1e-6)


def test_adaptive_scales_with_the_burst():
    res = run_controlled(ElasticPolicy(), 4.0, LN, LAT, **CTRL_KW)
    rs = [a.replicas for a in res.actions]
    assert min(rs) < max(rs), "controller must actually change fleet size"
    assert all(r in (1, 2, 4) for r in rs), rs   # pow2, clamped
    assert res.served + res.shed == len(res.waits) + res.shed


def test_single_window_fixed_r1_pins_plain_simulator():
    # one window, one replica, no shedding: the closed-loop driver IS the
    # PR 2 simulator (full-length waits, no warmup trim)
    pol = DynamicPolicy(8)
    tm = SinusoidTraffic(amplitude=0.5, period=100.0)
    res = simulate_controlled(pol, 2.0, LN, LAT, traffic=tm,
                              num_requests=400, seed=9, window=1e9,
                              fixed=(1, "round_robin"), fast=False)
    assert len(res.windows) == 1
    with no_warmup():
        base = simulate_policy(pol, 2.0, LN, LAT, num_requests=400,
                               seed=9, traffic=tm)
    np.testing.assert_array_equal(res.waits, base["waits"])


def test_fixed_vs_clairvoyant_are_exclusive():
    with pytest.raises(AssertionError):
        simulate_controlled(ElasticPolicy(), 4.0, LN, LAT,
                            num_requests=200, fixed=(2, "round_robin"),
                            clairvoyant=True)


def test_objective_accounting():
    res = run_controlled(ElasticPolicy(), 4.0, LN, LAT, shed_cost=2.0,
                         **CTRL_KW)
    n = res.served + res.shed
    expect = (res.mean_wait + res.replica_cost * res.avg_replicas
              + res.shed_cost * res.shed / n)
    assert abs(res.objective - expect) < 1e-9


# ---------------------------------------------------------------------------
# 3: serving-layer scale schedule — drain conservation
# ---------------------------------------------------------------------------

def _reqs(n=300, lam=3.0, seed=0):
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / lam, n))
    toks = LN.sample(np.random.default_rng(seed + 1), n)
    return [Request(i, float(a), np.zeros(4, np.int32), int(t))
            for i, (a, t) in enumerate(zip(arr, toks))]


def test_scale_spans_shapes():
    sp = scale_spans([(10.0, 1), (20.0, 3), (30.0, 2)], 4, 50.0)
    assert sp[0] == []                            # replica 0 always up
    assert sp[1] == [(10.0, 20.0)]
    assert sp[2][0] == (10.0, 20.0) and sp[2][1][0] == 30.0
    assert sp[3][0][0] == 10.0 and sp[3][0][1] > 50.0  # never back up


def _clock():
    return ModelClock(LatencyModel(0.0205, 0.55), LAT)


def test_scale_down_conserves_requests():
    reqs = _reqs()
    horizon = reqs[-1].arrival
    res = ResilientFleetScheduler(
        "least_work", DynamicPolicy(8), _clock(), 4,
        scale_schedule=[(horizon * 0.3, 2), (horizon * 0.6, 4)]).run(reqs)
    rep = res.resilience
    assert rep.served + rep.shed + rep.failed == rep.arrived == len(reqs)
    assert rep.served > 0
    # scaled-down replicas show reduced availability in the report
    assert min(rep.availability) < 1.0


def test_scale_down_during_crash_conserves_requests():
    reqs = _reqs()
    horizon = reqs[-1].arrival
    res = ResilientFleetScheduler(
        "least_work", DynamicPolicy(8), _clock(), 4,
        kill_at={1: horizon * 0.25},
        scale_schedule=[(horizon * 0.3, 2), (horizon * 0.6, 4)]).run(reqs)
    rep = res.resilience
    assert rep.served + rep.shed + rep.failed == rep.arrived == len(reqs)


def test_noop_schedule_is_bit_identical():
    reqs = _reqs()
    base = ResilientFleetScheduler("least_work", DynamicPolicy(8),
                                   _clock(), 4).run(reqs)
    noop = ResilientFleetScheduler("least_work", DynamicPolicy(8),
                                   _clock(), 4,
                                   scale_schedule=[(0.0, 4)]).run(reqs)
    assert np.array_equal(base.waits, noop.waits)
    assert np.array_equal(base.replica_of, noop.replica_of)


def test_explicit_down_spans():
    reqs = _reqs()
    horizon = reqs[-1].arrival
    spans = [[], [], [(horizon * 0.2, horizon * 0.8)],
             [(0.0, horizon * 0.5)]]
    res = ResilientFleetScheduler("least_work", DynamicPolicy(8), _clock(),
                                  4, down_spans=spans).run(reqs)
    rep = res.resilience
    assert rep.served + rep.shed + rep.failed == rep.arrived


# ---------------------------------------------------------------------------
# multi-seed regret sweep (slow — behind --runregret)
# ---------------------------------------------------------------------------

@pytest.mark.regret
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_adaptive_beats_best_static_multi_seed(seed):
    # the bench_autoscale operating point, swept over seeds
    dist = LogNormalTokens(5.0, 0.8)
    kw = dict(traffic=SinusoidTraffic(amplitude=0.9, period=2000.0),
              num_requests=32_000, seed=seed, window=200.0,
              max_replicas=8, replica_cost=5.0)
    adaptive = run_controlled(
        ElasticPolicy(), 8.0, dist, LAT,
        controller_kwargs={"replica_target_util": 0.4}, **kw)
    statics = [run_controlled(ElasticPolicy(), 8.0, dist, LAT,
                              fixed=(R, rt), **kw).objective
               for R in (1, 2, 4, 8)
               for rt in ("round_robin", "least_work")]
    assert adaptive.objective < min(statics), (seed, adaptive.objective,
                                               min(statics))
    clair = run_controlled(ElasticPolicy(), 8.0, dist, LAT,
                           clairvoyant=True, **kw)
    regret = adaptive.objective - clair.objective
    assert np.isfinite(regret)
    assert abs(regret) < min(statics)
