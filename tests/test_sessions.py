"""PR 9 conformance harness: re-entrant agentic sessions (M/G/1 with
feedback) across all four layers.

Pins the load-bearing invariants of ``repro.core.sessions``:

1. **Null conformance** — every registered session model in its null
   (single-turn) configuration reproduces the historical trajectories
   BIT-exactly at every layer: ``make_request_stream``,
   ``simulate_policy`` (oracle), ``simulate_policy_fast``,
   ``route_oracle`` / ``simulate_fleet_fast``, and the serving
   schedulers.
2. **Oracle ≡ fastsim under feedback** — both layers share one
   fixed-point runner per topology, so their trajectories stay equal
   under every (session model × policy) and (session model × router ×
   prefix discount) cell.
3. **Feedback correctness** — at the converged fixed point every
   re-entry satisfies ``arrival(turn t+1) == completion(turn t) +
   think``; turn accounting closes (arrived == served + lost) even with
   impatience shedding and fault traces; unsupported compositions
   raise.
4. **Analytics** — the λ_eff = λ·E[turns] transfer
   (``mg1_feedback_wait``) reduces to P-K on null models and tracks
   multi-seed simulation within 15% at three loads.
"""

import numpy as np
import pytest

from repro.core.distributions import LogNormalTokens
from repro.core.fastsim import simulate_fleet_fast, simulate_policy_fast
from repro.core.fleet import ROUTERS, SessionAffinityRouter, route_oracle
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.mg1 import mg1_feedback_wait, mg1_wait
from repro.core.bulk import feedback_policy_delay
from repro.core.policies import (ContinuousPolicy, DynamicPolicy,
                                 ElasticPolicy, FCFSPolicy, FixedPolicy,
                                 SRPTPolicy, single_from_batch)
from repro.core.sessions import (ChainSession, GeometricSession, SESSIONS,
                                 SessionModel, SingleSession,
                                 ToolcallSession, _session_rng,
                                 check_policy_supports_sessions,
                                 default_sessions, expand_workload,
                                 get_session, null_sessions, plan_sessions,
                                 session_from_spec, simulate_fleet_sessions,
                                 simulate_policy_sessions)
from repro.core.simulate import simulate_policy
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.router import FleetScheduler
from repro.serving.scheduler import (FCFSScheduler, ModelClock,
                                     PolicyScheduler)

LN = LogNormalTokens(5.0, 0.6)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
SINGLE = LatencyModel(a=0.0205, c=0.55)
CLOCK = ModelClock(single_from_batch(LAT), LAT)

GEO = {"name": "geometric", "p": 0.5, "think_mean": 2.0}

POLICIES = {"dynamic": DynamicPolicy(8), "elastic": ElasticPolicy(),
            "srpt": SRPTPolicy(b_max=8)}
FLEET_ROUTERS = ["session_affinity", "round_robin", "random"]


def _nonnull_models():
    return {k: m for k, m in default_sessions().items() if not m.is_null}


# ---------------------------------------------------------------------------
# registry / spec round-trip
# ---------------------------------------------------------------------------

def test_registry_contents():
    for name in ("single", "geometric", "chain", "toolcall"):
        assert name in SESSIONS
        sm = get_session(name)
        assert isinstance(sm, SessionModel)
        assert sm.name == name


def test_session_from_spec_forms():
    assert isinstance(session_from_spec(None), SingleSession)
    assert session_from_spec(None).is_null
    assert isinstance(session_from_spec("chain"), ChainSession)
    sm = session_from_spec({"name": "geometric", "p": 0.25,
                            "think_mean": 3.0})
    assert sm.p == 0.25 and sm.think_mean == 3.0
    inst = ToolcallSession()
    assert session_from_spec(inst) is inst
    with pytest.raises(KeyError):
        session_from_spec("no_such_model")


def test_default_and_null_sets_cover_registry():
    assert set(default_sessions()) == set(SESSIONS)
    nulls = null_sessions()
    assert set(nulls) == set(SESSIONS)
    for name, sm in nulls.items():
        assert sm.is_null, name
    for name, sm in default_sessions().items():
        if name != "single":
            assert not sm.is_null, name


def test_mean_turns_formulas():
    assert SingleSession().mean_turns() == 1.0
    assert GeometricSession(p=0.5).mean_turns() == 2.0
    assert ChainSession(k=4).mean_turns() == 4.0
    tc = ToolcallSession(p=0.5, max_turns=3)
    assert abs(tc.mean_turns() - (1 + 0.5 + 0.25)) < 1e-12
    # capped draws respect the budget and the closed form
    k = tc.draw_turns(np.random.default_rng(0), 20_000)
    assert k.max() <= 3 and k.min() >= 1
    assert abs(k.mean() - tc.mean_turns()) < 0.02


# ---------------------------------------------------------------------------
# plan structure + stream isolation
# ---------------------------------------------------------------------------

def test_plan_sessions_structure():
    plan = plan_sessions(GeometricSession(p=0.6, think_mean=2.0), 200, 7)
    assert plan.total == int(plan.turns.sum())
    assert plan.n_sessions == 200
    first = plan.offsets
    assert np.all(plan.turn[first] == 1)
    assert np.all(plan.parent[first] == -1)
    assert np.all(plan.think[first] == 0.0)
    later = plan.turn >= 2
    assert np.all(plan.parent[later] == np.nonzero(later)[0] - 1)
    assert np.all(plan.think[later] > 0.0)
    # deterministic in seed
    again = plan_sessions(GeometricSession(p=0.6, think_mean=2.0), 200, 7)
    assert np.array_equal(plan.turns, again.turns)
    assert np.array_equal(plan.think, again.think)


def test_session_rng_is_salted_lane():
    a = _session_rng(0, 11).random(8)
    b = np.random.default_rng(0).random(8)
    assert not np.array_equal(a, b)
    assert np.array_equal(_session_rng(3, 11).random(4),
                          _session_rng(3, 11).random(4))
    # tuple seeds fold like traffic.py
    assert np.array_equal(_session_rng((2, 5), 13).random(4),
                          _session_rng((2, 5), 13).random(4))


def test_expand_workload_turn1_rows_verbatim():
    pol = DynamicPolicy(8)
    wl = pol.sample_workload(2.0, LN, 300, seed=9)
    ewl, plan = expand_workload(wl, GeometricSession(p=0.5, think_mean=2.0),
                                LN, pol, 9)
    first = plan.offsets
    assert np.array_equal(ewl.tokens[first], wl.tokens)
    assert np.array_equal(ewl.arrivals[first], wl.arrivals)
    if wl.predicted is not None:
        assert np.array_equal(ewl.predicted[first], wl.predicted)
    # lower-bound arrivals: base + cumulative think within each session
    later = plan.turn >= 2
    assert np.all(ewl.arrivals[later] >= np.repeat(wl.arrivals,
                                                   plan.turns)[later])


# ---------------------------------------------------------------------------
# 1: null conformance — bit-equality to the session-free paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SESSIONS))
def test_null_models_pin_make_request_stream(name):
    sm = null_sessions()[name]
    base = make_request_stream(200, lam=3.0, dist=LN, vocab=256, seed=7)
    null = make_request_stream(200, lam=3.0, dist=LN, vocab=256, seed=7,
                               sessions=sm)
    assert len(base) == len(null)
    for a, b in zip(base, null):
        assert a.arrival == b.arrival
        assert a.target_output_tokens == b.target_output_tokens
        assert np.array_equal(a.prompt_tokens, b.prompt_tokens)
        assert b.session == -1 and b.turn == 1


@pytest.mark.parametrize("name", sorted(SESSIONS))
def test_null_models_pin_simulators(name):
    sm = null_sessions()[name]
    pol = DynamicPolicy(8)
    base_o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=3)
    null_o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=3,
                             sessions=sm)
    assert np.array_equal(base_o["waits"], null_o["waits"])
    base_f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400,
                                  seed=3)
    null_f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400,
                                  seed=3, sessions=sm)
    assert np.array_equal(base_f["waits"], null_f["waits"])


@pytest.mark.parametrize("name", sorted(SESSIONS))
def test_null_models_pin_fleet(name):
    sm = null_sessions()[name]
    for router in ("least_work", "random"):
        base = simulate_fleet_fast(router, DynamicPolicy(8), 3.0, 2, LN,
                                   LAT, num_requests=400, seed=5)
        null = simulate_fleet_fast(router, DynamicPolicy(8), 3.0, 2, LN,
                                   LAT, num_requests=400, seed=5,
                                   sessions=sm)
        assert np.array_equal(base["replica_of"], null["replica_of"])
        assert base["mean_wait"] == null["mean_wait"]


def test_null_models_pin_schedulers():
    base = make_request_stream(120, lam=1.0, dist=LN, vocab=256, seed=4)
    null = make_request_stream(120, lam=1.0, dist=LN, vocab=256, seed=4,
                               sessions={"name": "chain", "k": 1})
    sch = PolicyScheduler(DynamicPolicy(8), CLOCK)
    r0 = sch.run(base)
    rn = sch.run_sessions(null)
    assert rn.sessions is None
    assert np.array_equal(r0.waits, rn.waits)
    fl = FleetScheduler("session_affinity", DynamicPolicy(8), CLOCK, R=3)
    f0 = fl.run(base)
    fn = fl.run_sessions(null)
    assert fn.sessions is None
    assert np.array_equal(f0.waits, fn.waits)
    assert np.array_equal(f0.replica_of, fn.replica_of)


def test_expansion_preserves_base_stream_as_turn1():
    base = make_request_stream(150, lam=1.0, dist=LN, vocab=256, seed=8)
    exp = make_request_stream(150, lam=1.0, dist=LN, vocab=256, seed=8,
                              sessions=GEO)
    first = [r for r in exp if r.turn == 1]
    assert len(first) == 150 and len(exp) > 150
    for a, b in zip(base, first):
        assert a.arrival == b.arrival
        assert a.target_output_tokens == b.target_output_tokens
        assert np.array_equal(a.prompt_tokens, b.prompt_tokens)


# ---------------------------------------------------------------------------
# 2: oracle ≡ fastsim under every (session × policy/router) cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", sorted(_nonnull_models()))
@pytest.mark.parametrize("pol", sorted(POLICIES))
def test_oracle_equals_fastsim_single(model, pol):
    sm = default_sessions()[model]
    o = simulate_policy_sessions(POLICIES[pol], 1.2, LN, LAT, 250, 11, sm)
    f = simulate_policy_sessions(POLICIES[pol], 1.2, LN, LAT, 250, 11, sm,
                                 fast=True)
    assert o["converged"] and f["converged"]
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=0, atol=1e-9)


@pytest.mark.parametrize("model", sorted(_nonnull_models()))
@pytest.mark.parametrize("router", FLEET_ROUTERS)
def test_oracle_equals_fastsim_fleet(model, router):
    sm = default_sessions()[model]
    o = simulate_fleet_sessions(router, DynamicPolicy(8), 1.5, 3, LN, LAT,
                                250, 13, sm, prefix_discount=0.5)
    f = simulate_fleet_sessions(router, DynamicPolicy(8), 1.5, 3, LN, LAT,
                                250, 13, sm, prefix_discount=0.5, fast=True)
    assert np.array_equal(o["replica_of"], f["replica_of"])
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=0, atol=1e-9)


def test_route_oracle_matches_fleet_fast_with_sessions():
    # public fleet entry points dispatch to the same runner
    o = route_oracle("session_affinity", DynamicPolicy(8), 1.5, 3, LN, LAT,
                     num_requests=250, seed=13, sessions=GEO,
                     prefix_discount=0.5)
    f = simulate_fleet_fast("session_affinity", DynamicPolicy(8), 1.5, 3,
                            LN, LAT, num_requests=250, seed=13,
                            sessions=GEO, prefix_discount=0.5)
    assert np.array_equal(o["replica_of"], f["replica_of"])
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=0, atol=1e-9)


# ---------------------------------------------------------------------------
# 3: feedback fixed-point correctness + shedding/fault accounting
# ---------------------------------------------------------------------------

def _check_causal(rows, atol=1e-9):
    served = ~rows["cancelled"] & ~rows["lost"]
    ch = np.nonzero(rows["parent"] >= 0)[0]
    ok = ch[~rows["cancelled"][ch] & served[rows["parent"][ch]]]
    err = np.abs(rows["arrival"][ok]
                 - (rows["completion"][rows["parent"][ok]]
                    + rows["think"][ok]))
    assert err.max() < atol


@pytest.mark.parametrize("model", sorted(_nonnull_models()))
def test_feedback_fixed_point_is_causal(model):
    sm = default_sessions()[model]
    res = simulate_policy_sessions(DynamicPolicy(8), 1.2, LN, LAT, 250, 5,
                                   sm)
    assert res["converged"]
    s = res["sessions"]
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    assert s["turns_lost"] == 0 and s["turns_cancelled"] == 0
    assert s["sessions_completed"] == s["n_sessions"]
    _check_causal(s["rows"])


def test_turn_accounting_closes_with_shedding():
    res = simulate_policy(FCFSPolicy(tau=5.0), 0.3, LN, SINGLE,
                          num_requests=400, seed=3, sessions=GEO)
    s = res["sessions"]
    rows = s["rows"]
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    assert int((rows["lost"] & rows["cancelled"]).sum()) == 0
    assert np.isfinite(rows["wait"][~rows["cancelled"]]).all()
    # a lost turn terminates its session: every descendant is cancelled
    ch = np.nonzero(rows["parent"] >= 0)[0]
    assert rows["cancelled"][ch[rows["lost"][rows["parent"][ch]]]].all()
    _check_causal(rows)
    assert 0.0 < res["loss_frac"] < 1.0
    assert np.isfinite(s["mean_session_e2e"])


def test_shedding_event_loop_matches_pr1_on_null_plan():
    # the causal tau engine IS the PR 1 workload recursion on a null plan
    base = simulate_policy(FCFSPolicy(tau=5.0), 0.3, LN, SINGLE,
                           num_requests=400, seed=3)
    ev = simulate_policy_sessions(FCFSPolicy(tau=5.0), 0.3, LN, SINGLE,
                                  400, 3, GeometricSession(p=0.0))
    np.testing.assert_allclose(base["waits"], ev["waits"], rtol=0,
                               atol=1e-9)
    assert abs(base["loss_frac"] - ev["loss_frac"]) < 1e-12


def test_fleet_shedding_accounting_closes():
    res = simulate_fleet_sessions("round_robin", FCFSPolicy(tau=5.0), 0.9,
                                  3, LN, SINGLE, 250, 7,
                                  session_from_spec(GEO))
    s = res["sessions"]
    rows = s["rows"]
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    assert int((rows["lost"] & rows["cancelled"]).sum()) == 0
    assert np.isfinite(rows["wait"][~rows["cancelled"]]).all()


def test_fault_trace_composes_with_sessions():
    from repro.core.faults import Slowdown
    trace = Slowdown(mtbf=40.0, duration=10.0, factor=4.0).trace(11, 0,
                                                                 5000.0)
    o = simulate_policy_sessions(DynamicPolicy(8), 1.0, LN, LAT, 250, 5,
                                 session_from_spec(GEO), fault_trace=trace)
    f = simulate_policy_sessions(DynamicPolicy(8), 1.0, LN, LAT, 250, 5,
                                 session_from_spec(GEO), fault_trace=trace,
                                 fast=True)
    base = simulate_policy_sessions(DynamicPolicy(8), 1.0, LN, LAT, 250, 5,
                                    session_from_spec(GEO))
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=0, atol=1e-9)
    assert o["mean_wait"] > base["mean_wait"]
    s = o["sessions"]
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]


def test_unsupported_compositions_raise():
    with pytest.raises(ValueError):
        check_policy_supports_sessions(ContinuousPolicy())
    with pytest.raises(ValueError):
        check_policy_supports_sessions(FixedPolicy(b=4))
    pol = DynamicPolicy(8)
    wl = pol.sample_workload(1.0, LN, 50, seed=0)
    with pytest.raises(ValueError):
        simulate_policy(pol, 1.0, LN, LAT, workload=wl, sessions=GEO)
    reqs = make_request_stream(40, lam=1.0, dist=LN, vocab=64, seed=1,
                               sessions=GEO)
    fl = FleetScheduler("random", pol, CLOCK, R=2, faults="crash")
    with pytest.raises(ValueError):
        fl.run_sessions(reqs)


# ---------------------------------------------------------------------------
# serving layer: scheduler + fleet scheduler sessions
# ---------------------------------------------------------------------------

def test_scheduler_sessions_close_and_discount_helps():
    reqs = make_request_stream(100, lam=1.0, dist=LN, vocab=256, seed=4,
                               sessions=GEO)
    # b_max (not n_max): a token clip would hide the prefix discount —
    # both true and discounted lengths clamp to the same n_max
    sch = PolicyScheduler(DynamicPolicy(b_max=8), CLOCK)
    res = sch.run_sessions(reqs)
    s = res.sessions
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    assert s["sessions_completed"] == s["n_sessions"]
    m = summarize(res)
    for key in ("n_sessions", "turns_arrived", "turns_served",
                "sessions_completed", "mean_session_e2e",
                "p95_session_e2e"):
        assert key in m
    disc = summarize(sch.run_sessions(reqs, prefix_discount=0.5))
    assert disc["mean_session_e2e"] < m["mean_session_e2e"]


def test_scheduler_shedding_closure():
    reqs = make_request_stream(100, lam=1.0, dist=LN, vocab=256, seed=4,
                               sessions=GEO)
    sch = FCFSScheduler(CLOCK, tau=5.0)
    s = sch.run_sessions(reqs).sessions
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    rows = s["rows"]
    assert int((rows["lost"] & rows["cancelled"]).sum()) == 0


@pytest.mark.parametrize("router", FLEET_ROUTERS)
def test_fleet_scheduler_sessions(router):
    reqs = make_request_stream(100, lam=1.0, dist=LN, vocab=256, seed=4,
                               sessions=GEO)
    fl = FleetScheduler(router, DynamicPolicy(8), CLOCK, R=3)
    res = fl.run_sessions(reqs, prefix_discount=0.5)
    s = res.sessions
    assert s["turns_arrived"] == s["turns_served"] + s["turns_lost"]
    assert s["sessions_completed"] == s["n_sessions"]
    assert len(res.waits) == len(reqs)


# ---------------------------------------------------------------------------
# session_affinity router
# ---------------------------------------------------------------------------

def test_affinity_router_registered_and_sticky():
    assert "session_affinity" in ROUTERS
    r = SessionAffinityRouter()
    sess = np.array([0, 0, 1, 1, 2, 2, 0], np.int64)
    arr = np.arange(7, dtype=np.float64)
    rep = r.assign(arr, None, 4, seed=3, sessions=sess)
    for s in (0, 1, 2):
        assert len(set(rep[sess == s])) == 1
    # deterministic + arrival-order independent (pure hash of session id)
    again = r.assign(arr + 100.0, None, 4, seed=3, sessions=sess)
    assert np.array_equal(rep, again)


def test_affinity_router_fallback_and_masking():
    r = SessionAffinityRouter()
    # sessions=None: per-index hash, spreads across replicas
    rep = r.assign(np.arange(200, dtype=np.float64), None, 4, seed=1)
    assert len(np.unique(rep)) == 4
    # masked probing avoids down replicas but keeps stickiness among up
    sess = np.repeat(np.arange(50, dtype=np.int64), 2)
    # up is per-arrival [n, R]: replica 1 down for every arrival
    up = np.tile(np.array([True, False, True, True]), (100, 1))
    rep = r.masked_assign(np.arange(100, dtype=np.float64), None, 4,
                          seed=2, up=up, sessions=sess)
    assert not np.any(rep == 1)
    for s in range(50):
        assert len(set(rep[sess == s])) == 1


def test_prefix_discount_improves_affinity_wait():
    base = simulate_fleet_fast("session_affinity", DynamicPolicy(8), 1.5,
                               3, LN, LAT, num_requests=250, seed=5,
                               sessions=GEO)
    disc = simulate_fleet_fast("session_affinity", DynamicPolicy(8), 1.5,
                               3, LN, LAT, num_requests=250, seed=5,
                               sessions=GEO, prefix_discount=0.5)
    assert disc["mean_wait"] < base["mean_wait"]


# ---------------------------------------------------------------------------
# 4: analytics — λ_eff transfer
# ---------------------------------------------------------------------------

def test_mg1_feedback_reduces_to_pk_on_null():
    for sm in null_sessions().values():
        ref = mg1_wait(LN, SINGLE, 0.1)
        fb = mg1_feedback_wait(LN, SINGLE, 0.1, sm)
        assert fb.wait == ref.wait and fb.rho == ref.rho


def test_stability_boundary_detected():
    geo = GeometricSession(p=0.5, think_mean=2.0)
    lo = mg1_feedback_wait(LN, SINGLE, 0.05, geo)
    assert lo.stable and np.isfinite(lo.wait) and lo.rho < 1.0
    hi = mg1_feedback_wait(LN, SINGLE, 0.15, geo)
    assert not hi.stable and hi.rho >= 1.0
    # the feedback multiplier is what tips it: single-turn is stable here
    assert mg1_wait(LN, SINGLE, 0.15).stable


def test_feedback_policy_delay_transfer():
    out = feedback_policy_delay(FCFSPolicy(), 0.05, LN, SINGLE,
                                GeometricSession(p=0.5, think_mean=2.0))
    assert out["mean_turns"] == 2.0
    assert abs(out["lam_eff"] - 0.1) < 1e-12
    assert out["stable"]
    ref = mg1_wait(LN, SINGLE, 0.1)
    assert abs(out["wait"] - ref.wait) < 1e-9
    # SRPT's size-interval envelope (bulk.srpt_bound) transfers too: at
    # this lam_eff the serial envelope of the capped batch is unstable,
    # so the transfer reports wait=inf / stable=False
    srpt = feedback_policy_delay(SRPTPolicy(b_max=8), 0.05, LN, LAT,
                                 GeometricSession(p=0.5))
    assert srpt["wait"] == np.inf and not srpt["stable"]
    # a noisy predictor voids the envelope -> no closed form at all
    nowin = feedback_policy_delay(
        SRPTPolicy(b_max=8, predictor="lognormal_noise"), 0.05, LN, LAT,
        GeometricSession(p=0.5))
    assert nowin["wait"] is None and not nowin["stable"]


@pytest.mark.sessions_slow
def test_mg1_feedback_tracks_simulation_within_15pct():
    # Kleinrock regime: think time well above a busy period decorrelates
    # re-arrivals, so P-K at λ_eff tracks multi-seed sim at every load
    geo = GeometricSession(p=0.5, think_mean=50.0)
    for lam in (0.04, 0.07, 0.10):
        ref = mg1_feedback_wait(LN, SINGLE, lam, geo)
        assert ref.stable
        sims = [simulate_policy_sessions(FCFSPolicy(), lam, LN, SINGLE,
                                         3000, s, geo)["mean_wait"]
                for s in range(5)]
        m = float(np.mean(sims))
        assert abs(m - ref.wait) / ref.wait < 0.15, (lam, ref.wait, m)


# ---------------------------------------------------------------------------
# property tests (hypothesis optional — the CI sessions job installs it;
# tier-1 skips only this section, never the conformance tests above)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container image ships without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), p=st.floats(0.05, 0.85))
    def test_effective_rate_within_5sigma_geometric(seed, p):
        # realized turn count is a sum of n iid Geometric(1-p): mean
        # n/(1-p), var n*p/(1-p)^2 — check the plan within 5 sigma
        n = 2_000
        sm = GeometricSession(p=p)
        plan = plan_sessions(sm, n, seed)
        mean = n * sm.mean_turns()
        sigma = np.sqrt(n * p) / (1.0 - p)
        assert abs(plan.total - mean) < 5.0 * sigma + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 12))
    def test_effective_rate_exact_chain(seed, k):
        plan = plan_sessions(ChainSession(k=k), 500, seed)
        assert plan.total == 500 * k

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), lam=st.floats(0.01, 0.30))
    def test_stability_flag_matches_rho(seed, lam):
        geo = GeometricSession(p=0.5, think_mean=2.0)
        ref = mg1_feedback_wait(LN, SINGLE, lam, geo)
        assert ref.stable == (ref.rho < 1.0)
        assert np.isfinite(ref.wait) == ref.stable

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_session_littles_law_on_oracle(seed):
        # sample-path Little's law at the session level: time-average
        # sessions in system == (completions/T) * mean session e2e,
        # with N(t) rebuilt from the per-row event times
        geo = GeometricSession(p=0.5, think_mean=2.0)
        res = simulate_policy_sessions(DynamicPolicy(8), 1.0, LN, LAT,
                                       300, seed, geo)
        assert res["converged"]
        rows = res["sessions"]["rows"]
        plan_off = np.nonzero(rows["parent"] == -1)[0]
        sess = rows["session"]
        enter = rows["arrival"][plan_off]
        leave = np.array([rows["completion"][sess == s].max()
                          for s in range(len(plan_off))])
        assert np.isfinite(leave).all()
        assert np.all(leave > enter)
        n = len(plan_off)
        T = float(leave.max())
        # rebuild N(t) by an event sweep and integrate it
        times = np.concatenate([enter, leave])
        delta = np.concatenate([np.ones(n), -np.ones(n)])
        o = np.argsort(times, kind="stable")
        t_s, d_s = times[o], delta[o]
        nt = np.cumsum(d_s)
        assert np.all(nt >= 0) and nt[-1] == 0
        area = float(np.sum(nt[:-1] * np.diff(t_s)))
        lhs = area / T                       # time-average N(t)
        rhs = (n / T) * float(np.mean(leave - enter))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-9)
        # and the reported session e2e equals the event-time rebuild
        assert abs(res["sessions"]["mean_session_e2e"]
                   - float(np.mean(leave - enter))) < 1e-9
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI sessions job "
                             "installs it)")
    def test_property_suite_requires_hypothesis():
        pass
