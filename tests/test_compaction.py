"""Fused (Pallas) elastic-bucket compaction vs the host reference path.

The contract (ISSUE 7): ``fused_compact`` must be BIT-equal to
``Engine.compact`` — every cache leaf, ``kv_lens``, the last tokens, and
the per-slot PRNG keys (the carrier of PR 4's sampling-invariance
guarantee) — while adding ZERO host syncs per compaction event."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels.compaction import (
    compact_reference, fused_compact, gather_rows)
from repro.serving.engine import Engine, EngineConfig

RNG = jax.random.PRNGKey(7)
ECFG = EngineConfig(max_batch=4, max_seq=128, prompt_bucket=16)


def _tree_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------------
# Kernel-level: the row gather against plain indexing
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("g,b,f", [
    (2, 8, 256),      # lane-aligned
    (1, 4, 64),       # sub-lane F -> padded to 128 internally
    (3, 8, 65),       # odd F
    (2, 16, 1024),    # 512-block path
])
def test_gather_rows_matches_indexing(g, b, f, dtype):
    src = jax.random.normal(RNG, (g, b, f), jnp.float32)
    src = src.astype(dtype) if dtype != jnp.int32 else \
        (src * 100).astype(jnp.int32)
    idx = jnp.array([0, b - 1, 2 % b, 0], jnp.int32)
    out = gather_rows(src, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(src[:, idx]))


def test_gather_rows_multidim_trailing():
    src = jax.random.normal(RNG, (2, 8, 4, 3, 5), jnp.float32)
    idx = jnp.array([5, 1, 1], jnp.int32)
    out = gather_rows(src, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(src[:, idx]))


# ----------------------------------------------------------------------------
# fused_compact vs the reference gathers on REAL engine caches
# ----------------------------------------------------------------------------

def _engine_cache(arch):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, num_layers=max(2, len(cfg.group_pattern)))
    eng = Engine(cfg, ECFG)
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    cache, kv_lens, last, b, _ = eng.prefill_batch(prompts)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(3), b)
    return eng, cache, kv_lens, tok, keys, b


# qwen: pure-attention KV cache; jamba: hybrid attention + Mamba conv/ssm
# leaves (different ranks/trailing dims all funnel through the one kernel)
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "jamba-1.5-large-398b"])
def test_fused_compact_bit_equal_on_model_cache(arch):
    eng, cache, kv_lens, tok, keys, b = _engine_cache(arch)
    # slots 0 and 2 still owe tokens; slot 1 finished; slot 3 is padding
    produced = jnp.asarray([2, 5, 1, 0])
    targets = jnp.asarray([5, 5, 3, 0])
    nb = 2
    fc, fl, ft, fk, keep = fused_compact(cache, kv_lens, tok, keys,
                                         produced, targets, nb=nb)
    assert list(np.asarray(keep)) == [0, 2]
    rc, rl, rt, rk = compact_reference(cache, kv_lens, tok, keep, keys)
    _tree_equal(fc, rc)
    np.testing.assert_array_equal(np.asarray(fl), np.asarray(rl))
    np.testing.assert_array_equal(np.asarray(ft), np.asarray(rt))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(rk))


def test_fused_compact_matches_engine_host_compact():
    """End-to-end twin check: ``Engine.compact_fused`` output ==
    ``Engine.compact`` output (same keep set, zero-padded to the bucket),
    and only the host path pays a host-visible sync."""
    eng, cache, kv_lens, tok, keys, b = _engine_cache("qwen2.5-3b")
    produced = np.array([2, 5, 1, 0])
    targets = np.array([5, 5, 3, 0])
    keep = np.nonzero(targets - produced > 0)[0].astype(np.int32)

    syncs0 = eng.host_syncs
    hc, hl, ht, hb, _, hk = eng.compact(cache, kv_lens, tok, keep, keys)
    assert eng.host_syncs == syncs0 + 1         # host path: one event

    syncs1 = eng.host_syncs
    fc, fl, ft, fb, fk = eng.compact_fused(
        cache, kv_lens, tok, jnp.asarray(produced), jnp.asarray(targets),
        len(keep), keys)
    assert eng.host_syncs == syncs1             # fused path: zero syncs
    assert fb == hb
    _tree_equal(fc, hc)
    np.testing.assert_array_equal(np.asarray(fl), np.asarray(hl))
    np.testing.assert_array_equal(np.asarray(ft), np.asarray(ht))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(hk))
    ev = [e for e in eng.step_log if e["kind"] == "compact"]
    assert [e["impl"] for e in ev] == ["host", "fused"]
    assert [e["syncs"] for e in ev] == [1, 0]


# ----------------------------------------------------------------------------
# Engine accounting: fused is the default and saves one sync per compaction
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gen_setup():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    return cfg, prompts, [17, 3, 9]


def test_elastic_generate_fused_vs_host_accounting(gen_setup):
    """Elastic generate under both compaction impls: identical sampled
    token streams (temperature>0 pins the gathered per-slot PRNG keys) and
    ``host_syncs(fused) == host_syncs(host) - n_compaction_events`` with
    every fused event logging zero syncs."""
    cfg, prompts, targets = gen_setup
    runs = {}
    for impl in ("fused", "host"):
        eng = Engine(cfg, dataclasses.replace(ECFG, compact_impl=impl))
        r = eng.generate(prompts, targets, elastic=True, chunk=4,
                         return_tokens=True, temperature=0.8, seed=123)
        ev = [e for e in eng.step_log if e["kind"] == "compact"]
        runs[impl] = (r, ev)
    (rf, evf), (rh, evh) = runs["fused"], runs["host"]
    assert rf["tokens"] == rh["tokens"]
    assert list(rf["produced"]) == list(rh["produced"]) == targets
    assert len(evf) == len(evh) >= 1            # compaction actually fired
    assert all(e["impl"] == "fused" and e["syncs"] == 0 for e in evf)
    assert all(e["impl"] == "host" and e["syncs"] == 1 for e in evh)
    assert rf["host_syncs"] == rh["host_syncs"] - len(evh)


def test_fused_is_default_impl(gen_setup):
    assert EngineConfig().compact_impl == "fused"
    cfg, prompts, targets = gen_setup
    eng = Engine(cfg, ECFG)
    eng.generate(prompts, targets, elastic=True, chunk=4)
    ev = [e for e in eng.step_log if e["kind"] == "compact"]
    assert ev and all(e["impl"] == "fused" for e in ev)
