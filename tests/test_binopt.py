"""Load-dependent multi-bin boundary optimization (Guldogan et al. 2024)
and its wiring into the adaptive controller.

``optimize_bin_edges`` replaces the equal-probability-mass quantile
boundaries with load-dependent ones: the arrival rate fixes an effective
per-bin batch size b(lam), and coordinate descent minimizes the saturated
per-request service time sbar(edges; b) (reciprocal of service capacity).
"""

import numpy as np

from repro.core.bulk import (
    multibin_bound, multibin_saturated_service, multibin_split,
    optimize_bin_edges)
from repro.core.distributions import LogNormalTokens, UniformTokens
from repro.core.latency_model import BatchLatencyModel
from repro.core.policies import MultiBinPolicy

LN = LogNormalTokens(7.0, 0.7)
HT = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)   # Fig-6b consts


def _quantile_edges(dist, num_bins=4):
    return MultiBinPolicy(num_bins=num_bins).bin_edges(dist)


def test_split_partitions_the_distribution():
    parts = multibin_split(LN, _quantile_edges(LN))
    ps = [p for p, _, _ in parts]
    assert abs(sum(ps) - 1.0) < 1e-12
    assert all(abs(p - 0.25) < 0.02 for p in ps)    # equal-mass quantiles
    pads = [pad for _, _, pad in parts]
    assert pads == sorted(pads)
    for p, d, pad in parts:
        if p > 0:
            assert d.support[d.pmf > 0].max() <= pad


def test_optimized_edges_ascending_and_inside_support():
    for lam in (0.5, 1.0, 2.0):
        e = optimize_bin_edges(LN, HT, lam, num_bins=4)
        assert len(e) == 3
        assert (np.diff(e) > 0).all()
        assert 0 < e[0] and e[-1] < LN.max_tokens


def test_optimized_edges_improve_saturated_service():
    """Never worse than the quantile default on the objective (descent
    starts there), strictly better under heavy tail at high load."""
    q = _quantile_edges(LN)
    e = optimize_bin_edges(LN, HT, 1.0, num_bins=4)
    for b in (8, 16, 32):
        sq = multibin_saturated_service(LN, HT, q, b)
        se = multibin_saturated_service(LN, HT, e, b)
        assert se <= sq + 1e-12
    assert multibin_saturated_service(LN, HT, e, 16) < \
        0.95 * multibin_saturated_service(LN, HT, q, 16)


def test_edges_are_load_dependent():
    """Light load: b(lam)=1, sbar telescopes to the global mean and the
    quantile start is returned unchanged.  Heavy load: the per-bin batch
    maxima dominate and the boundaries move."""
    q = _quantile_edges(LN)
    np.testing.assert_allclose(optimize_bin_edges(LN, HT, 0.01), q)
    assert not np.allclose(optimize_bin_edges(LN, HT, 1.0), q)


def test_optimized_edges_improve_simulated_delay_high_load():
    from repro.core.fastsim import simulate_policy_fast
    lam = 1.0
    quant = simulate_policy_fast(MultiBinPolicy(num_bins=4), lam, LN, HT,
                                 num_requests=40_000, seed=15)["mean_wait"]
    opt = simulate_policy_fast(
        MultiBinPolicy.optimized(lam, LN, HT, num_bins=4), lam, LN, HT,
        num_requests=40_000, seed=15)["mean_wait"]
    assert opt < quant * 1.02, (opt, quant)


def test_multibin_bound_uses_explicit_edges():
    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    d = multibin_bound(uni, lat, 0.2, [250.0, 500.0, 750.0])
    assert d["stable"] and np.isfinite(d["wait_bound"])
    # the round arm pays every bin's per-batch overhead once
    assert abs(d["beta"] - (4 * 0.5 + 0.02 * (250 + 500 + 750 + 1000))) < 1e-9
    assert abs(d["alpha"] - (0.05 + 0.0005 * 1000)) < 1e-12


def test_controller_recommends_optimized_multibin_without_elastic():
    from repro.core.control import AdaptiveController
    from repro.core.latency_model import PAPER_A100_LLAMA2_7B
    rng = np.random.default_rng(0)
    ctrl = AdaptiveController(PAPER_A100_LLAMA2_7B, HT, theta=119 / 120,
                              elastic_available=False, min_samples=64)
    t = 0.0
    for n in LN.sample(rng, 512):
        t += rng.exponential(1.0)        # heavy load: lam_hat ~ 1
        ctrl.observe_arrival(t)
        ctrl.observe_completion(int(n))
    rec = ctrl.recommendation(force=True)
    assert rec.heavy_tailed
    assert rec.policy == "multibin"
    assert rec.bin_edges is not None and len(rec.bin_edges) == 3
    assert (np.diff(rec.bin_edges) > 0).all()
    # elastic engines keep the paper's optimal policy; no edges computed
    ctrl2 = AdaptiveController(PAPER_A100_LLAMA2_7B, HT, theta=119 / 120,
                               elastic_available=True, min_samples=64)
    ctrl2._tokens = ctrl._tokens
    ctrl2._arrivals = ctrl._arrivals
    rec2 = ctrl2.recommendation(force=True)
    assert rec2.policy == "elastic" and rec2.bin_edges is None
