"""KV-memory budget + prefill/decode tandem (repro.core.memory, PR 10).

Conformance discipline mirrors faults/traffic/sessions: the infinite-budget
null model is BIT-equal to the pre-PR-10 paths at every layer (oracle,
fastsim, fleet, scheduler), and the tandem oracle and the compiled kernel
agree per (policy x router x budget) grid cell.  Property tests (occupancy
never exceeds the budget, allocated == freed at drain) run under
hypothesis when available; the conformance tests never skip.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.bulk import dynamic_batching_bound, tandem_bound
from repro.core.control import AdaptiveController
from repro.core.distributions import UniformTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.memory import (
    MemoryBudget, TandemClock, check_policy_supports_memory,
    memory_from_spec, occupancy_stats, tandem_oracle)
from repro.core.policies import (
    ContinuousPolicy, DynamicPolicy, ElasticPolicy, FCFSPolicy, FixedPolicy,
    SRPTPolicy, default_policies)
from repro.core.simulate import simulate_policy
from repro.core.fastsim import simulate_policy_fast, simulate_fleet_fast
from repro.core.fleet import get_router, route_oracle
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.scheduler import ModelClock, PolicyScheduler
from repro.serving.router import FleetScheduler

UNI = UniformTokens(1000)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
LAT1 = LatencyModel(a=0.0212, c=1.79)
CLOCK = ModelClock(LAT1, LAT)

# non-integer budgets dodge searchsorted ties in the release ledger
M_TIGHT = 1777.25
M_MID = 4000.25


# ---------------------------------------------------------------------------
# units: budget model, spec parsing, policy gate, tandem clock
# ---------------------------------------------------------------------------

def test_budget_null_and_footprint():
    b = MemoryBudget()
    assert b.is_null
    assert MemoryBudget(capacity=np.inf).is_null
    assert not MemoryBudget(capacity=100.0).is_null
    b = MemoryBudget(capacity=1000.0, prompt_tokens=32.0)
    np.testing.assert_allclose(b.footprint([10, 20]), [42.0, 52.0])


def test_budget_max_batch():
    b = MemoryBudget(capacity=4000.0)
    assert b.max_batch(UNI) == 4000 // 999
    # quantile caps the worst-case member length -> larger b(M)
    assert b.max_batch(UNI, quantile=0.5) > b.max_batch(UNI)
    assert MemoryBudget(capacity=10.0).max_batch(UNI) == 1   # floor at 1
    with pytest.raises(ValueError):
        MemoryBudget().max_batch(UNI)


def test_memory_from_spec():
    assert memory_from_spec(None).is_null
    assert memory_from_spec(2000).capacity == 2000.0
    b = memory_from_spec({"capacity": 100.0, "prompt_tokens": 8.0})
    assert b.prompt_tokens == 8.0
    assert memory_from_spec(b) is b
    with pytest.raises(ValueError):
        memory_from_spec("not-a-budget")


def test_policy_gate():
    check_policy_supports_memory(DynamicPolicy(8))
    check_policy_supports_memory(SRPTPolicy(b_max=8))
    for pol in (FCFSPolicy(), ContinuousPolicy(slots=8)):
        with pytest.raises(ValueError, match="admission point"):
            check_policy_supports_memory(pol)


def test_tandem_clock_recovers_serial_law():
    tc = TandemClock(LAT)
    for b, l in [(1, 10), (4, 100), (8, 999)]:
        np.testing.assert_allclose(
            tc.prefill_time(b) + tc.decode_time(b, l),
            tc.serial_time(b, l), rtol=1e-12)


def test_stage_split_padded_and_elastic():
    ns = np.array([10.0, 400.0, 999.0])
    for pol in (DynamicPolicy(None), FixedPolicy(3), SRPTPolicy(b_max=3)):
        pf, off = pol.stage_split(ns, LAT)
        assert pf == pytest.approx(LAT.prefill_time(3))
        # padded: everyone completes at the batch max
        np.testing.assert_allclose(pf + off, pol.batch_time(ns, LAT))
    epol = ElasticPolicy(3)
    pf, off = epol.stage_split(ns, LAT)
    # Eq 26 early exit: shorter members complete earlier, the longest
    # member lands exactly on the elastic batch end (< padded end)
    assert off[0] < off[1] < off[2]
    assert pf + off[2] == pytest.approx(float(epol.batch_time(ns, LAT)))
    assert pf + off[2] < float(DynamicPolicy(None).batch_time(ns, LAT))


def test_formation_rewind_reoffers_members():
    arr = np.array([0.0, 0.1, 0.2, 0.3])
    tok = np.array([5.0, 6.0, 7.0, 8.0])
    fs = DynamicPolicy(None).formation(arr, tok, UNI)
    _, idx = fs.next_batch(10.0)          # everyone queued: one batch of 4
    assert len(idx) == 4
    fs.rewind(2)                          # defer the last two members
    _, idx2 = fs.next_batch(20.0)
    np.testing.assert_array_equal(idx2, idx[2:])
    assert fs.next_batch(30.0) is None


def test_single_request_overflow_raises():
    wl = DynamicPolicy(None).sample_workload(0.1, UNI, 200, seed=0)
    with pytest.raises(ValueError, match="largest single request"):
        tandem_oracle(DynamicPolicy(None), wl, LAT, UNI,
                      MemoryBudget(capacity=500.0))


# ---------------------------------------------------------------------------
# null-budget bit-equality at every layer (infinite budget == PR 9 path)
# ---------------------------------------------------------------------------

NULL_SPECS = [None, MemoryBudget(), MemoryBudget(capacity=np.inf), np.inf]


@pytest.mark.parametrize("name", ["dynamic", "elastic", "srpt_b8"])
def test_null_budget_bit_equal_oracle_and_fast(name):
    pol = default_policies()[name]
    base_o = simulate_policy(pol, 0.1, UNI, LAT, num_requests=5_000, seed=3)
    base_f = simulate_policy_fast(pol, 0.1, UNI, LAT, num_requests=5_000,
                                  seed=3)
    for spec in NULL_SPECS:
        r = simulate_policy(pol, 0.1, UNI, LAT, num_requests=5_000, seed=3,
                            memory=spec)
        np.testing.assert_array_equal(r["waits"], base_o["waits"])
        r = simulate_policy_fast(pol, 0.1, UNI, LAT, num_requests=5_000,
                                 seed=3, memory=spec)
        np.testing.assert_array_equal(r["waits"], base_f["waits"])


def test_null_budget_bit_equal_fleet():
    pol = DynamicPolicy(8)
    rt = get_router("round_robin")
    base = route_oracle(rt, pol, 0.3, 2, UNI, LAT, num_requests=4_000,
                        seed=5)
    r = route_oracle(rt, pol, 0.3, 2, UNI, LAT, num_requests=4_000, seed=5,
                     memory=np.inf)
    for p0, p1 in zip(base["per_replica"], r["per_replica"]):
        np.testing.assert_array_equal(p0["waits"], p1["waits"])
    base_f = simulate_fleet_fast(rt, pol, 0.3, 2, UNI, LAT,
                                 num_requests=4_000, seed=5)
    r_f = simulate_fleet_fast(rt, pol, 0.3, 2, UNI, LAT, num_requests=4_000,
                              seed=5, memory=np.inf)
    for p0, p1 in zip(base_f["per_replica"], r_f["per_replica"]):
        np.testing.assert_array_equal(p0["waits"], p1["waits"])


def test_null_budget_bit_equal_scheduler():
    reqs = make_request_stream(3_000, lam=0.1, dist=UNI, vocab=100, seed=11)
    base = PolicyScheduler(DynamicPolicy(8), CLOCK).run(reqs)
    for spec in NULL_SPECS:
        r = PolicyScheduler(DynamicPolicy(8), CLOCK, memory=spec).run(reqs)
        np.testing.assert_array_equal(r.waits, base.waits)
        np.testing.assert_array_equal(r.e2e, base.e2e)
        assert r.memory is None


# ---------------------------------------------------------------------------
# tandem oracle == compiled kernel per (policy x router x budget) cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["dynamic", "elastic", "srpt_b8", "fixed_b4"])
@pytest.mark.parametrize("M", [M_TIGHT, M_MID])
def test_tandem_oracle_matches_fast(name, M):
    pol = default_policies()[name]
    ro = simulate_policy(pol, 0.1, UNI, LAT, num_requests=8_000, seed=7,
                         memory=M)
    rf = simulate_policy_fast(pol, 0.1, UNI, LAT, num_requests=8_000,
                              seed=7, memory=M)
    np.testing.assert_allclose(rf["waits"], ro["waits"],
                               rtol=1e-6, atol=1e-9)
    # integer event statistics are exactly equal
    for k in ("blocked_batches", "deferred_requests"):
        assert ro["memory"][k] == rf["memory"][k], k
    np.testing.assert_allclose(rf["memory"]["kv_peak"],
                               ro["memory"]["kv_peak"], rtol=1e-9)


@pytest.mark.parametrize("router", ["round_robin", "least_work"])
def test_tandem_fleet_oracle_matches_fast(router):
    pol = DynamicPolicy(None)
    rt = get_router(router)
    ro = route_oracle(rt, pol, 0.3, 2, UNI, LAT, num_requests=6_000,
                      seed=9, memory=M_TIGHT)
    rf = simulate_fleet_fast(rt, pol, 0.3, 2, UNI, LAT, num_requests=6_000,
                             seed=9, memory=M_TIGHT)
    for p0, p1 in zip(ro["per_replica"], rf["per_replica"]):
        np.testing.assert_allclose(p1["waits"], p0["waits"],
                                   rtol=1e-6, atol=1e-9)
        assert (p0["memory"]["blocked_batches"]
                == p1["memory"]["blocked_batches"])
    assert ro["memory"]["capacity"] == M_TIGHT   # per-replica budgets


# ---------------------------------------------------------------------------
# conservation: occupancy <= budget, allocated == freed at drain
# ---------------------------------------------------------------------------

def _occupancy_trace(res):
    mem = res["memory"]
    assert mem["kv_peak"] <= mem["capacity"] + 1e-9
    assert mem["kv_mean"] <= mem["kv_peak"] + 1e-9
    np.testing.assert_allclose(mem["allocated"], mem["freed"], rtol=1e-12)
    assert 0.0 <= mem["utilization"] <= 1.0 + 1e-12


@pytest.mark.parametrize("name", ["dynamic", "elastic", "srpt_b8", "fixed_b4"])
def test_occupancy_within_budget(name):
    pol = default_policies()[name]
    for M in (M_TIGHT, M_MID):
        _occupancy_trace(simulate_policy(pol, 0.1, UNI, LAT,
                                         num_requests=6_000, seed=2,
                                         memory=M))


def test_occupancy_stats_tie_break():
    # a release and an allocation at the same instant: the freed slot is
    # reusable, so the peak never double-counts the handoff
    starts = np.array([0.0, 5.0])
    comps = np.array([5.0, 9.0])
    fp = np.array([800.0, 900.0])
    mem = occupancy_stats(starts, comps, fp, 1000.0)
    assert mem["kv_peak"] == 900.0
    assert mem["allocated"] == mem["freed"] == 1700.0


# ---------------------------------------------------------------------------
# analytics: the tandem decomposition bound (bulk.tandem_bound)
# ---------------------------------------------------------------------------

def test_tandem_bound_null_is_slack_arm():
    tb = tandem_bound(UNI, LAT, 0.1, memory=None)
    slack = dynamic_batching_bound(UNI, LAT, 0.1)
    assert tb["wait_bound"] == pytest.approx(slack["wait_bound"])
    assert tb["memory_arm"] is None and tb["b_mem"] is None


@pytest.mark.parametrize("lam,M", [(0.05, 2000.25), (0.05, 4000.25),
                                   (0.1, 4000.25)])
def test_tandem_bound_dominates_simulation(lam, M):
    """Multi-seed dominance in the admission-dominated regime the bound
    certifies (small b_mem; see the bulk.tandem_bound docstring for the
    intermediate-budget fragmentation regime it excludes)."""
    tb = tandem_bound(UNI, LAT, lam, memory=M)
    assert tb["stable"]
    for seed in (1, 2, 3):
        r = simulate_policy(DynamicPolicy(None), lam, UNI, LAT,
                            num_requests=30_000, seed=seed, memory=M)
        assert tb["wait_bound"] >= r["mean_wait"], (seed, tb, r["mean_wait"])


def test_tandem_bound_tight_at_heavy_cell():
    # the memory arm is an ENVELOPE, but at the heavily-gated cell it is
    # within 2x of simulation — non-vacuous
    tb = tandem_bound(UNI, LAT, 0.1, memory=4000.25)
    r = simulate_policy(DynamicPolicy(None), 0.1, UNI, LAT,
                        num_requests=30_000, seed=1, memory=4000.25)
    assert tb["wait_bound"] <= 2.0 * r["mean_wait"]


def test_tandem_bound_instability_flag():
    tb = tandem_bound(UNI, LAT, 0.2, memory=4000.25)
    assert not tb["stable"]
    assert tb["wait_bound"] == np.inf


def test_tandem_bound_monotone_in_budget():
    b1 = tandem_bound(UNI, LAT, 0.05, memory=2000.25)["wait_bound"]
    b2 = tandem_bound(UNI, LAT, 0.05, memory=4000.25)["wait_bound"]
    b3 = tandem_bound(UNI, LAT, 0.05, memory=None)["wait_bound"]
    assert b1 > b2 > b3      # looser budget -> smaller envelope


# ---------------------------------------------------------------------------
# controller: batch size vs KV headroom
# ---------------------------------------------------------------------------

def _fed_controller(**kw):
    c = AdaptiveController(LAT1, LAT, max_replicas=1, **kw)
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(1_500):
        t += float(rng.exponential(10.0))
        c.observe_arrival(t)
        c.observe_completion(int(rng.integers(1, 1000)))
    return c.recommendation(force=True)


def test_controller_memory_caps_batch():
    blind = _fed_controller()
    aware = _fed_controller(memory=600.0)
    assert blind.memory_budget is None
    assert blind.details.get("b_mem") is None
    assert aware.memory_budget == 600.0
    b_mem = aware.details["b_mem"]
    assert b_mem is not None
    # tight budget at this load: the gate binds, so the controller
    # throttles formation with a count trigger sized for two batches in
    # flight (docs/memory.md) instead of serve-all
    assert aware.details["memory_binding"]
    assert aware.policy == "fixed"
    assert 1 <= aware.b_max <= max(1, b_mem // 2)


def test_controller_loose_budget_only_caps():
    rec = _fed_controller(memory=60_000.0)
    # plenty of headroom: the gate does not bind, the policy is the
    # blind choice and b_max is merely capped at the (large) b(M)
    assert not rec.details["memory_binding"]
    assert rec.policy == _fed_controller().policy
    assert rec.b_max == rec.details["b_mem"]


def test_controller_prefix_discount_grows_b_of_m():
    budget = MemoryBudget(capacity=4000.0, prompt_tokens=500.0)
    plain = _fed_controller(memory=budget)
    reuse = _fed_controller(memory=budget, prefix_discount=0.5)
    # gamma shrinks the per-request footprint -> larger effective b(M)
    assert reuse.details["b_mem"] > plain.details["b_mem"]


def test_controller_warmup_has_no_memory_budget():
    c = AdaptiveController(LAT1, LAT, memory=4000.0)
    rec = c.recommendation()
    assert rec.details.get("reason") == "warmup"
    assert rec.memory_budget is None


# ---------------------------------------------------------------------------
# serving layer: scheduler admission, fleet roll-up, composition guards
# ---------------------------------------------------------------------------

def test_scheduler_tandem_reports_memory():
    reqs = make_request_stream(4_000, lam=0.1, dist=UNI, vocab=100, seed=11)
    res = PolicyScheduler(DynamicPolicy(None), CLOCK, memory=M_MID).run(reqs)
    out = summarize(res)
    mem = out["memory"]
    assert mem["capacity"] == M_MID
    assert 0.0 < mem["kv_peak"] <= M_MID
    assert mem["allocated"] == pytest.approx(mem["freed"])
    # the tandem under a tight budget waits longer than unconstrained
    base = summarize(PolicyScheduler(DynamicPolicy(None), CLOCK).run(reqs))
    assert out["mean_wait"] >= base["mean_wait"]


def test_fleet_scheduler_memory_rollup():
    reqs = make_request_stream(4_000, lam=0.2, dist=UNI, vocab=100, seed=4)
    fs = FleetScheduler("round_robin", DynamicPolicy(None), CLOCK, R=2,
                        memory=M_MID)
    out = summarize(fs.run(reqs))
    mem = out["memory"]
    assert mem["capacity"] == M_MID          # per-replica, not pooled
    assert mem["kv_peak"] <= M_MID
    assert mem["deferred_requests"] >= 0


def test_sessions_x_memory_raises():
    from repro.core.sessions import GeometricSession
    with pytest.raises(ValueError, match="sessions"):
        simulate_policy(DynamicPolicy(8), 0.1, UNI, LAT, num_requests=500,
                        seed=0, sessions=GeometricSession(p=0.5),
                        memory=M_MID)
    reqs = make_request_stream(200, lam=0.1, dist=UNI, vocab=100, seed=0,
                               sessions=GeometricSession(p=0.5))
    sched = PolicyScheduler(DynamicPolicy(8), CLOCK, memory=M_MID)
    with pytest.raises(ValueError, match="sessions"):
        sched.run_sessions(reqs)


def test_memory_rejects_unsupported_policies():
    with pytest.raises(ValueError, match="admission point"):
        simulate_policy(FCFSPolicy(), 0.1, UNI, LAT1, num_requests=500,
                        seed=0, memory=M_MID)
    with pytest.raises(ValueError, match="admission point"):
        PolicyScheduler(ContinuousPolicy(slots=8), CLOCK, memory=M_MID)


# ---------------------------------------------------------------------------
# property tests (hypothesis optional — the CI memory job installs it;
# tier-1 skips only this section, never the conformance tests above)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container image ships without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000),
           cap=st.floats(1100.0, 9000.0),
           lam=st.floats(0.02, 0.12))
    def test_property_occupancy_never_exceeds_budget(seed, cap, lam):
        res = simulate_policy(DynamicPolicy(None), lam, UNI, LAT,
                              num_requests=1_500, seed=seed, memory=cap)
        mem = res["memory"]
        assert mem["kv_peak"] <= cap + 1e-9
        np.testing.assert_allclose(mem["allocated"], mem["freed"],
                                   rtol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), cap=st.floats(1100.0, 9000.0))
    def test_property_allocated_equals_served_footprint(seed, cap):
        # every served request allocates exactly footprint(n) and frees
        # it at drain: allocated == freed == sum of served footprints
        pol = FixedPolicy(4)
        res = simulate_policy(pol, 0.05, UNI, LAT, num_requests=1_000,
                              seed=seed, memory=cap)
        wl = pol.sample_workload(0.05, UNI, 1_000, seed)
        served = pol.schedule_length(len(wl.tokens))
        expect = float(wl.tokens[:served].sum())  # footprint == tokens here
        mem = res["memory"]
        np.testing.assert_allclose(mem["allocated"], mem["freed"],
                                   rtol=1e-12)
        np.testing.assert_allclose(mem["allocated"], expect, rtol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_null_budget_bit_equal(seed):
        base = simulate_policy(DynamicPolicy(8), 0.1, UNI, LAT,
                               num_requests=1_200, seed=seed)
        r = simulate_policy(DynamicPolicy(8), 0.1, UNI, LAT,
                            num_requests=1_200, seed=seed, memory=np.inf)
        np.testing.assert_array_equal(r["waits"], base["waits"])
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI memory job "
                             "installs it)")
    def test_property_suite_requires_hypothesis():
        pass
