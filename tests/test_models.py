"""Model-zoo smoke tests (deliverable f): every assigned architecture at
reduced scale — one forward/train step on CPU, shape + finiteness asserts,
serving-path consistency, and the Mamba2 SSD oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.config import ModelConfig
from repro.models.model import (
    cache_specs, decode_step, forward, init_cache, param_specs, prefill)
from repro.models.params import init_params
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainConfig, make_train_step
from repro.utils.tree import tree_num_params

RNG = jax.random.PRNGKey(0)


def _dropless(cfg):
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / cfg.num_experts_per_tok)
    return cfg


def _inputs(cfg, b, s, rng=RNG):
    kw = {}
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.embeddings_input:
        kw["embeds"] = jax.random.normal(
            rng, (b, s, cfg.d_model), jnp.float32) * 0.02
    if cfg.vision_seq:
        kw["cross_kv"] = jax.random.normal(
            rng, (b, cfg.vision_seq, cfg.d_model), jnp.float32) * 0.02
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    tokens, kw = _inputs(cfg, 2, 64)
    logits, aux = forward(
        cfg, params, None if cfg.embeddings_input else tokens, **kw)
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1))
    step = jax.jit(make_train_step(cfg, tcfg))
    from repro.training.optimizer import adamw_init
    opt = adamw_init(params, tcfg.adamw)
    tokens, kw = _inputs(cfg, 2, 32)
    batch = {"labels": jax.random.randint(RNG, (2, 32), 0, cfg.vocab_size)}
    if cfg.embeddings_input:
        batch["embeds"] = kw["embeds"][:, :32]
    else:
        batch["tokens"] = tokens
    if cfg.vision_seq:
        batch["image_embeds"] = kw["cross_kv"]
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually move
    delta = sum(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serving_consistency(arch):
    """prefill + decode == full forward (the engine's correctness basis)."""
    cfg = _dropless(get_smoke_config(arch))
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    B, S, EXTRA = 2, 32, 3
    tokens, kw = _inputs(cfg, B, S + EXTRA)
    if cfg.embeddings_input:
        # decode consumes LM-table embeddings of generated tokens: build the
        # oracle input the same way
        table = params["embed"]
        emb = jnp.concatenate(
            [kw["embeds"][:, :S], table[tokens[:, S:]].astype(jnp.float32)],
            axis=1)
        full, _ = forward(cfg, params, embeds=emb)
        cache = init_cache(cfg, B, 64, jnp.float32)
        last, cache = prefill(cfg, params, embeds=emb[:, :S], cache=cache)
    else:
        full, _ = forward(cfg, params, tokens, **kw)
        cache = init_cache(cfg, B, 64, jnp.float32)
        last, cache = prefill(cfg, params, tokens[:, :S], cache=cache, **kw)
    errs = [float(jnp.abs(last - full[:, S - 1]).max())]
    kv_lens = jnp.full((B,), S, jnp.int32)
    for t in range(EXTRA):
        sl, cache = decode_step(cfg, params, cache, tokens[:, S + t], kv_lens)
        kv_lens = kv_lens + 1
        errs.append(float(jnp.abs(sl - full[:, S + t]).max()))
    assert max(errs) < 5e-4, errs


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "jamba-1.5-large-398b"])
def test_blockwise_attention_matches_dense(arch):
    cfg = _dropless(get_smoke_config(arch))
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    tokens, kw = _inputs(cfg, 2, 64)
    dense, _ = forward(cfg, params, tokens, **kw)
    cfg_blk = dataclasses.replace(cfg, attn_dense_max_seq=16,
                                  attn_chunk_q=16, attn_chunk_kv=16)
    blk, _ = forward(cfg_blk, params, tokens, **kw)
    assert float(jnp.abs(dense - blk).max()) < 5e-4


def test_param_counts_match_published():
    """Full configs' parameter formulas land near the published sizes."""
    tol = {"gemma-7b": 0.02, "yi-9b": 0.02, "qwen2.5-3b": 0.04,
           "internlm2-1.8b": 0.03, "musicgen-large": 0.25,
           "moonshot-v1-16b-a3b": 0.10, "mixtral-8x7b": 0.02,
           "llama-3.2-vision-90b": 0.10, "jamba-1.5-large-398b": 0.08,
           "mamba2-2.7b": 0.05}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        exp = cfg.expected_params
        assert abs(n - exp) / exp < tol[arch], (arch, n, exp)


def test_smoke_param_specs_consistent():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = init_params(param_specs(cfg), RNG, jnp.float32)
        assert tree_num_params(params) == cfg.param_count(), arch


def test_mamba2_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence (oracle)."""
    from repro.models.mamba import _ssd_chunked
    from repro.distributed.sharding import NULL_CTX
    cfg = get_smoke_config("mamba2-2.7b")
    cfg = dataclasses.replace(cfg, ssm_chunk=8)
    b, s, h, p, g, n = 2, 40, cfg.ssm_heads, cfg.ssm_head_dim, \
        cfg.ssm_n_groups, cfg.ssm_state
    rng = np.random.default_rng(0)
    xh = jnp.asarray(rng.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = jnp.asarray(rng.normal(0, 1, (b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (b, s, g, n)), jnp.float32)
    y, hT = _ssd_chunked(xh, dt, A, B, C, cfg, NULL_CTX)
    # naive recurrence
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    state = np.zeros((b, h, p, n))
    y_ref = np.zeros((b, s, h, p))
    for t in range(s):
        dec = np.exp(np.asarray(dt)[:, t] * np.asarray(A)[None, :])
        xb = np.einsum("bhp,bhn->bhpn", np.asarray(xh)[:, t], Bh[:, t])
        state = state * dec[:, :, None, None] + \
            np.asarray(dt)[:, t][:, :, None, None] * xb
        y_ref[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], state)
    assert np.abs(np.asarray(y) - y_ref).max() < 1e-3
    assert np.abs(np.asarray(hT) - state).max() < 1e-3


def test_sliding_window_cache_ring_buffer():
    """Decode with window < prompt behaves like full recompute with window."""
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = _dropless(cfg)
    cfg = dataclasses.replace(cfg, sliding_window=16)
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    B, S, EXTRA = 1, 12, 10   # prompt < window; decode grows past window
    tokens, _ = _inputs(cfg, B, S + EXTRA)
    full, _ = forward(cfg, params, tokens)
    cache = init_cache(cfg, B, 16, jnp.float32)   # span == window
    last, cache = prefill(cfg, params, tokens[:, :S], cache=cache)
    errs = [float(jnp.abs(last - full[:, S - 1]).max())]
    kv_lens = jnp.full((B,), S, jnp.int32)
    for t in range(EXTRA):
        sl, cache = decode_step(cfg, params, cache, tokens[:, S + t], kv_lens)
        kv_lens = kv_lens + 1
        errs.append(float(jnp.abs(sl - full[:, S + t]).max()))
    assert max(errs) < 5e-4, errs
