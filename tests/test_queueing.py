"""Analytics vs event-driven simulation — the paper's own validation axis
(SV: 'our mathematical models coincide with the event-driven simulations')."""

import numpy as np
import pytest

from repro.core.bulk import (
    dynamic_batching_bound, elastic_batching_bound, inoue_bound,
    mdb1_wait_exact, mdb1_wait_paper, _mdb1_roots_newton, _mdb1_roots_series,
    optimal_fixed_batch)
from repro.core.distributions import (
    DeterministicTokens, LogNormalTokens, UniformTokens)
from repro.core.impatience import (
    dekok_tijms, exact_impatience, level_crossing,
    mm1_impatience_closed_form)
from repro.core.latency_model import (
    BatchLatencyModel, LatencyModel, PAPER_A100_LLAMA2_7B)
from repro.core.mg1 import mg1_wait
from repro.core.policy_opt import optimize_token_limit_v1
from repro.core.simulate import (
    simulate_dynamic_batching, simulate_fixed_batching, simulate_mg1)

LN = LogNormalTokens(7.0, 0.7)
LAT = PAPER_A100_LLAMA2_7B


# ----------------------------------------------------------------------------
# M/G/1 + clipping (paper Eqs 1-5, Fig 4a)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("n_max", [800, 1600, 3000])
def test_mg1_clipping_matches_simulation(n_max):
    lam = 1 / 40
    ana = mg1_wait(LN, LAT, lam, n_max)
    sim = simulate_mg1(lam, LN, LAT, n_max=n_max, num_requests=400_000, seed=3)
    assert ana.stable
    assert abs(ana.wait - sim["mean_wait"]) / ana.wait < 0.08


def test_mg1_paper_fig4_numbers():
    """Paper SV-B: optimal n_max=1600 gives E[W]~23s; ~59% below n_max=3000."""
    lam = 1 / 40
    w1600 = mg1_wait(LN, LAT, lam, 1600).wait
    w3000 = mg1_wait(LN, LAT, lam, 3000).wait
    assert 18 < w1600 < 28
    assert 0.45 < 1 - w1600 / w3000 < 0.70


def test_clipping_monotone_in_wait():
    lam = 1 / 40
    waits = [mg1_wait(LN, LAT, lam, n).wait for n in (500, 1000, 2000, 4000)]
    assert all(a <= b + 1e-9 for a, b in zip(waits, waits[1:]))


def test_v1_optimum_in_paper_range():
    """theta=119/120 gives n_max* ~ 1600 on the paper's setup."""
    choice = optimize_token_limit_v1(
        LN, LAT, 1 / 40, theta=119 / 120,
        grid=np.arange(200, 4001, 50))
    assert 1100 <= choice.n_max <= 2200


# ----------------------------------------------------------------------------
# Impatience (paper Eqs 6-9, Figs 4b-4c)
# ----------------------------------------------------------------------------

def test_levelcrossing_matches_mm1_closed_form():
    lam, mu, tau = 1 / 25, 1 / 20, 60.0
    cf = mm1_impatience_closed_form(lam, mu, tau)
    lc = level_crossing(lambda u: np.exp(-mu * u), lam, tau, s_max=240.0)
    assert abs(cf.pi - lc.pi) < 0.003
    assert abs(cf.wq_all - lc.wq_all) / cf.wq_all < 0.02


def test_erlang_b_limit_at_tau_zero():
    lam, mu = 0.8, 1.0
    cf = mm1_impatience_closed_form(lam, mu, tau=1e-9)
    rho = lam / mu
    assert abs(cf.pi - rho / (1 + rho)) < 1e-6


@pytest.mark.parametrize("n_max", [1300, 3000])
def test_exact_impatience_matches_simulation(n_max):
    lam, tau = 1 / 25, 60.0
    ex = exact_impatience(LN, LAT, lam, tau, n_max)
    sim = simulate_mg1(lam, LN, LAT, n_max=n_max, tau=tau,
                       num_requests=300_000, seed=5)
    assert abs(ex.pi - sim["loss_frac"]) < 0.01
    assert abs(ex.wq_all - sim["mean_wait"]) / sim["mean_wait"] < 0.05


def test_dekok_interpolation_close_to_exact():
    lam, tau = 1 / 25, 60.0
    dk = dekok_tijms(LN, LAT, lam, tau, 1300)
    ex = exact_impatience(LN, LAT, lam, tau, 1300)
    assert abs(dk.pi - ex.pi) < 0.02
    assert abs(dk.wq_all - ex.wq_all) / ex.wq_all < 0.05


def test_eq9_identity():
    lam, tau = 1 / 25, 60.0
    r = exact_impatience(LN, LAT, lam, tau, 2000)
    lhs = r.wq_all
    rhs = tau * r.pi + r.wq_served * (1 - r.pi)
    assert abs(lhs - rhs) < 1e-6


# ----------------------------------------------------------------------------
# Bulk queues (paper Eqs 14-26, Figs 5-6)
# ----------------------------------------------------------------------------

BLAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)


def test_mdb1_roots_satisfy_equation():
    for lam_h, b in [(0.5, 2), (3.0, 8), (7.9, 8), (14.0, 16)]:
        z = _mdb1_roots_newton(lam_h, b)
        assert np.max(np.abs(z ** b - np.exp(lam_h * (z - 1)))) < 1e-10
        assert np.all(np.abs(z) < 1.0)


def test_mdb1_series_matches_newton_moderate_load():
    z1 = np.sort_complex(_mdb1_roots_newton(3.0, 8))
    z2 = np.sort_complex(_mdb1_roots_series(3.0, 8))
    assert np.max(np.abs(z1 - z2)) < 1e-4


@pytest.mark.parametrize("b,h", [(2, 4.5), (4, 5.92), (8, 7.71), (16, 10.11)])
def test_mdb1_exact_matches_det_simulation(b, h):
    lam = 0.43
    ana = mdb1_wait_exact(lam, h, b)
    sim = simulate_fixed_batching(lam, b, None, batch_time=lambda ns: h,
                                  num_requests=300_000, seed=7)
    assert abs(ana - sim["mean_wait"]) / max(sim["mean_wait"], 0.1) < 0.06


def test_mdb1_paper_formula_reduces_to_md1_sojourn():
    lam, h = 0.4, 1.5
    w = mdb1_wait_paper(lam, h, 1)
    md1_wait = lam * h ** 2 / (2 * (1 - lam * h))
    assert abs(w - (md1_wait + h)) < 1e-9


def test_inoue_bound_dominates_simulation():
    uni = UniformTokens(1000)
    for lam in (0.05, 0.1, 0.3):
        bnd = dynamic_batching_bound(uni, BLAT, lam)
        sim = simulate_dynamic_batching(lam, uni, BLAT,
                                        num_requests=120_000, seed=9)
        assert bnd["wait_bound"] >= sim["mean_wait"] * 0.98


def test_elastic_beats_dynamic_uniform():
    """Paper Fig 5: elastic <= dynamic, gap grows with arrival rate."""
    uni = UniformTokens(1000)
    gaps = []
    for lam in (0.05, 0.2, 0.5):
        d = simulate_dynamic_batching(lam, uni, BLAT,
                                      num_requests=120_000, seed=11)
        e = simulate_dynamic_batching(lam, uni, BLAT, elastic=True,
                                      num_requests=120_000, seed=11)
        assert e["mean_wait"] <= d["mean_wait"] * 1.02
        gaps.append(d["mean_wait"] - e["mean_wait"])
    assert gaps[-1] > gaps[0] - 1e-6


def test_elastic_beats_dynamic_heavy_tail():
    """Paper SIV conclusion: elastic wins for every distribution."""
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-5, k4=0.002)
    for lam in (0.2, 0.4):
        d = simulate_dynamic_batching(lam, LN, lat,
                                      num_requests=100_000, seed=13)
        e = simulate_dynamic_batching(lam, LN, lat, elastic=True,
                                      num_requests=100_000, seed=13)
        assert e["mean_wait"] <= d["mean_wait"] * 1.02


def test_bmax_capping_helps_heavy_tail_high_load():
    """Paper Fig 6b: under heavy-tailed outputs at high arrival rate,
    unbounded dynamic batching grows huge batches whose max-token padding
    cost (k3*b*E[L_b]) runs away; a finite b_max is much better."""
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
    lam = 1.0
    unb = simulate_dynamic_batching(lam, LN, lat,
                                    num_requests=60_000, seed=15)
    cap = simulate_dynamic_batching(lam, LN, lat, b_max=32,
                                    num_requests=60_000, seed=15)
    assert cap["mean_wait"] < 0.6 * unb["mean_wait"]
    # and at LOW arrival rate the cap is harmless (paper: b_max only binds
    # when the queue actually builds)
    unb_lo = simulate_dynamic_batching(0.2, LN, lat,
                                       num_requests=60_000, seed=15)
    cap_lo = simulate_dynamic_batching(0.2, LN, lat, b_max=32,
                                       num_requests=60_000, seed=15)
    assert abs(cap_lo["mean_wait"] - unb_lo["mean_wait"]) < 0.05 * \
        max(unb_lo["mean_wait"], 1e-9)


def test_light_tail_prefers_unbounded():
    """Paper conclusion: light-tailed outputs -> larger batches only help."""
    det = DeterministicTokens(500)
    lam = 0.5
    unb = simulate_dynamic_batching(lam, det, BLAT,
                                    num_requests=80_000, seed=17)
    cap = simulate_dynamic_batching(lam, det, BLAT, b_max=2,
                                    num_requests=80_000, seed=17)
    assert unb["mean_wait"] <= cap["mean_wait"] * 1.05
