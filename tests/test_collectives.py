"""Compressed gradient reduction on a fake 8-device mesh (subprocess)."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_compressed_mean_matches_fp32_mean():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import compressed_mean_rows

mesh = jax.make_mesh((8,), ("data",))
n, size = 8, 8 * 512
rng = np.random.default_rng(0)
g = rng.normal(0, 1.0, (n, size)).astype(np.float32)
gd = jax.device_put(g, NamedSharding(mesh, P("data")))
out = np.asarray(compressed_mean_rows(gd, mesh, "data"))
ref = g.mean(axis=0)
# int8 quantization + bf16 gather error bound: ~max|g|/127 + bf16 eps
err = np.abs(out - ref[None]).max()
assert err < np.abs(g).max() / 127.0 + 0.02, err
# all rows identical (replicated mean)
assert np.abs(out - out[0:1]).max() < 1e-6
print("OK", err)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_wire_bytes_are_compressed():
    """The lowered HLO's collective payloads must be int8/bf16, not fp32."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import compressed_mean_rows
from repro.utils.hlo import analyze_hlo_text

mesh = jax.make_mesh((8,), ("data",))
n, size = 8, 8 * 512
sds = jax.ShapeDtypeStruct((n, size), jnp.float32,
                           sharding=NamedSharding(mesh, P("data")))
with mesh:
    comp = jax.jit(lambda g: compressed_mean_rows(g, mesh, "data")) \
        .lower(sds).compile()
cost = analyze_hlo_text(comp.as_text())
wire = cost.collective_wire_bytes
# fp32 ring all-reduce baseline wire: 2 * 4B * size * (n-1)/n per device
fp32_wire = 2 * 4 * size * (n - 1) / n
assert wire < fp32_wire * 0.8, (wire, fp32_wire)
print("OK", wire, fp32_wire)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
