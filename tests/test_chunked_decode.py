"""Fused chunked decode vs the per-step reference loop.

The contract (ISSUE 1): chunked decode must produce IDENTICAL tokens and
``produced`` counts to per-step decode while cutting host syncs from
O(tokens) to O(tokens/chunk); the ragged decode-attention kernel path must
not change tokens either."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.continuous import serve_continuous
from repro.serving.engine import Engine, EngineConfig

ECFG = EngineConfig(max_batch=4, max_seq=128, prompt_bucket=16)


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    return Engine(cfg, ECFG)


@pytest.fixture(scope="module")
def prompts():
    return [np.arange(4, dtype=np.int32) + i for i in range(3)]


TARGETS = [17, 3, 9]


def test_chunked_padded_same_tokens_and_counts(engine, prompts):
    r1 = engine.generate(prompts, TARGETS, chunk=1, return_tokens=True)
    r8 = engine.generate(prompts, TARGETS, chunk=8, return_tokens=True)
    assert list(r1["produced"]) == list(r8["produced"]) == TARGETS
    assert r1["tokens"] == r8["tokens"]


def test_chunked_elastic_same_tokens_and_counts(engine, prompts):
    r1 = engine.generate(prompts, TARGETS, elastic=True, chunk=1,
                         return_tokens=True)
    r8 = engine.generate(prompts, TARGETS, elastic=True, chunk=8,
                         return_tokens=True)
    assert list(r1["produced"]) == list(r8["produced"]) == TARGETS
    assert r1["tokens"] == r8["tokens"]
    c = r8["completion_seconds"]
    assert c[1] < c[2] < c[0]          # short replies still exit earlier


def test_chunked_reduces_host_syncs(engine, prompts):
    """1 prefill sync + ceil((max_target-1)/chunk-ish) decode syncs."""
    r1 = engine.generate(prompts, TARGETS, chunk=1)
    r8 = engine.generate(prompts, TARGETS, chunk=8)
    l_max = max(TARGETS)
    assert r1["host_syncs"] == 1 + (l_max - 1)          # per-step reference
    # power-of-two tail quantization: at most log2 extra chunks
    assert r8["host_syncs"] <= 1 + (l_max - 1 + 7) // 8 + 3
    assert r8["host_syncs"] < r1["host_syncs"] / 2


def test_chunk_default_comes_from_engine_config(prompts):
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, dataclasses.replace(ECFG, decode_chunk=16))
    r = eng.generate(prompts, TARGETS)
    assert list(r["produced"]) == TARGETS
    assert r["host_syncs"] <= 1 + 2    # prefill + 16-chunk + tail


def test_ragged_decode_attention_same_tokens(engine, prompts):
    """Routing decode attention through the ragged kernel must not change
    what is generated (greedy argmax is robust to the fp32-softmax vs
    online-softmax rounding difference)."""
    cfg_r = dataclasses.replace(engine.cfg, decode_attention_impl="ragged")
    eng_r = Engine(cfg_r, ECFG, params=engine.params)
    r = engine.generate(prompts, TARGETS, chunk=8, return_tokens=True)
    rr = eng_r.generate(prompts, TARGETS, chunk=8, return_tokens=True)
    assert r["tokens"] == rr["tokens"]
    assert list(rr["produced"]) == TARGETS


def test_sampling_chunk_invariant(engine, prompts):
    """PRNG key rides the scan carry and splits once per decode step, so
    temperature sampling gives IDENTICAL tokens for any chunking."""
    kw = dict(temperature=0.8, seed=123, return_tokens=True)
    r1 = engine.generate(prompts, TARGETS, chunk=1, **kw)
    r8 = engine.generate(prompts, TARGETS, chunk=8, **kw)
    assert list(r1["produced"]) == list(r8["produced"]) == TARGETS
    assert r1["tokens"] == r8["tokens"]


def test_sampling_compaction_and_composition_invariant(engine, prompts):
    """Per-slot PRNG key carries (ISSUE 4): each request samples from its
    own fold_in key that is gathered on elastic compaction, so sampled
    streams are identical between padded and elastic modes (compaction
    fires here: 3 -> 2 live at bucket 4) and even for the same request
    served alone vs inside a batch."""
    kw = dict(temperature=0.8, seed=123, return_tokens=True)
    rp = engine.generate(prompts, TARGETS, chunk=4, **kw)
    re_ = engine.generate(prompts, TARGETS, elastic=True, chunk=4, **kw)
    assert rp["tokens"] == re_["tokens"]
    r1 = engine.generate(prompts, TARGETS, elastic=True, chunk=1, **kw)
    assert r1["tokens"] == re_["tokens"]       # chunking still invariant
    solo = engine.generate([prompts[0]], [TARGETS[0]], **kw)
    assert solo["tokens"][0] == rp["tokens"][0]


def test_sampling_differs_from_greedy_and_reseeds(engine, prompts):
    g = engine.generate(prompts, TARGETS, chunk=8, return_tokens=True)
    s1 = engine.generate(prompts, TARGETS, chunk=8, temperature=1.5,
                         seed=7, return_tokens=True)
    s2 = engine.generate(prompts, TARGETS, chunk=8, temperature=1.5,
                         seed=7, return_tokens=True)
    assert s1["tokens"] == s2["tokens"]          # same seed -> same stream
    assert s1["tokens"] != g["tokens"]           # hot sampling != greedy
    assert list(s1["produced"]) == TARGETS


def test_top_k_one_equals_greedy(engine, prompts):
    """top_k=1 collapses the categorical onto the argmax, whatever the
    temperature — a determinism check of the in-scan masking."""
    g = engine.generate(prompts, TARGETS, chunk=8, return_tokens=True)
    s = engine.generate(prompts, TARGETS, chunk=8, temperature=0.7,
                        top_k=1, seed=3, return_tokens=True)
    assert s["tokens"] == g["tokens"]


@pytest.fixture(scope="module")
def cont_engine():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2,
                              decode_cache_update="scatter")
    return Engine(cfg, ECFG)


def test_continuous_chunked_same_produced(cont_engine):
    prompts = [np.arange(5, dtype=np.int32) + 3 * i for i in range(5)]
    targets = [6, 2, 9, 4, 3]
    r1 = serve_continuous(cont_engine, prompts, targets, slots=2, chunk=1)
    r8 = serve_continuous(cont_engine, prompts, targets, slots=2, chunk=8)
    assert list(r1.produced) == list(r8.produced) == targets
    # chunk cut at earliest completion while queued => no extra decode work
    assert r8.decode_steps == r1.decode_steps
    assert r8.host_syncs < r1.host_syncs
    assert np.isfinite(r8.completion).all()
