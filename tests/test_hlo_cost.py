"""The trip-count-corrected HLO cost model vs XLA's own cost_analysis on
unrolled graphs (where cost_analysis is trustworthy)."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.utils.hlo import analyze_hlo_text, parse_hlo_module


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_flops(compiled) -> float:
    """compiled.cost_analysis() returns a dict in older jax and a list of
    per-partition dicts in newer releases — normalize to total flops."""
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return float(ca["flops"])
    return float(sum(d.get("flops", 0.0) for d in ca))


def test_scan_flops_match_unrolled():
    n, steps = 64, 10

    def body(c, _):
        return c @ c, None

    def f_scan(x):
        return lax.scan(body, x, None, length=steps)[0]

    def f_unroll(x):
        for _ in range(steps):
            x = x @ x
        return x

    x = jnp.ones((n, n), jnp.float32)
    cs, cu = _compile(f_scan, x), _compile(f_unroll, x)
    ps = analyze_hlo_text(cs.as_text())
    pu = analyze_hlo_text(cu.as_text())
    truth = steps * 2 * n ** 3
    assert abs(ps.flops - truth) / truth < 0.01
    assert abs(pu.flops - truth) / truth < 0.01
    # XLA's own analysis undercounts the scan (documents why we parse):
    assert _xla_flops(cs) < truth / 2


def test_nested_scan_flops():
    n, outer, inner = 32, 4, 6

    def inner_body(c, _):
        return c @ c, None

    def outer_body(c, _):
        c2, _ = lax.scan(inner_body, c, None, length=inner)
        return c2, None

    def f(x):
        return lax.scan(outer_body, x, None, length=outer)[0]

    x = jnp.ones((n, n), jnp.float32)
    cost = analyze_hlo_text(_compile(f, x).as_text())
    truth = outer * inner * 2 * n ** 3
    assert abs(cost.flops - truth) / truth < 0.02


def test_unrolled_flops_match_cost_analysis():
    def f(a, b):
        return jnp.tanh(a @ b).sum()

    a = jnp.ones((128, 256), jnp.float32)
    b = jnp.ones((256, 64), jnp.float32)
    c = _compile(f, a, b)
    mine = analyze_hlo_text(c.as_text())
    theirs = _xla_flops(c)
    assert abs(mine.flops - theirs) / theirs < 0.2


def test_dynamic_slice_bytes_not_full_operand():
    """Slicing one layer from a stacked [G, ...] param must charge the slice,
    not the stack (the bug class this parser exists to avoid)."""
    big = jnp.ones((64, 256, 256), jnp.float32)

    def f(x, i):
        return lax.dynamic_slice(x, (i, 0, 0), (1, 256, 256)).sum()

    cost = analyze_hlo_text(_compile(f, big, jnp.int32(3)).as_text())
    # full operand would be 64 MB; slice accounting must stay ~2x256KB
    assert cost.bytes_accessed < 4e6


def test_while_trip_count_parsed():
    def f(x):
        return lax.scan(lambda c, _: (c + 1, None), x, None, length=17)[0]

    comps = parse_hlo_module(_compile(f, jnp.zeros((8,))).as_text())
    trips = [i.trip_count for c in comps.values()
             for i in c.instructions.values() if i.opcode == "while"]
    assert 17 in trips
