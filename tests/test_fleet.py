"""Fleet-layer agreement suite (routing across parallel batched replicas).

Pins the contract of :mod:`repro.core.fleet` + :mod:`repro.serving.router`:

  * router-oracle ≡ fastsim: identical routing decisions and per-replica
    wait trajectories for every (router, policy) pair;
  * the ``random`` router's exact superposition split: each replica is
    BIT-EQUAL to the single-server model at λ/R, so the single-server
    analytic forms transfer with their own ``analytic_kind``;
  * the ``jsq`` two-moment balanced-split approximation (Whitt QNA);
  * routing-quality ordering at matched load: least_work <= jsq <=
    round_robin <= random, power-of-d between jsq and random;
  * an R=1 fleet degenerates to the existing single-server path for every
    registered policy;
  * the serving layer (``FleetScheduler``) agrees statistically with the
    fleet oracle, and ``run_fleet_schedule`` executes on the real engine;
  * satellites: ``bulk.wait_bound`` (WAIT joins the analytic
    cross-checks), ``PromptFeaturePredictor`` (real prompt features feed
    ``least_work``), and the controller's replicas/router axis.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.distributions import LogNormalTokens, UniformTokens
from repro.core.latency_model import BatchLatencyModel
from repro.core.policies import (
    DynamicPolicy, ElasticPolicy, MultiBinPolicy, SRPTPolicy, WaitPolicy,
    default_policies, single_from_batch)
from repro.core.fleet import (
    ROUTERS, _backlog_assign_np, default_routers, fleet_analytic_delay,
    fleet_analytic_kind, recommend_replicas, route_oracle, router_from_spec,
    sweep)
from repro.core.fastsim import (
    backlog_route, simulate_fleet_fast, simulate_policy_fast)
from repro.core.simulate import simulate_policy
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.router import (
    FleetScheduler, run_fleet_schedule, summarize_fleet)
from repro.serving.scheduler import ModelClock

UNI = UniformTokens(1000)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
LN = LogNormalTokens(7.0, 0.7)
HT = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
CLOCK = ModelClock(single_from_batch(LAT), LAT)

ROUTER_SET = default_routers()
# the acceptance set: every length-signal path (padded, early-exit,
# binned, ordered membership) behind every router
PAIR_POLICIES = {
    "dynamic": DynamicPolicy(b_max=8),
    "elastic": ElasticPolicy(),
    "multibin": MultiBinPolicy(num_bins=4),
    "srpt": SRPTPolicy(b_max=8),
}


def test_registry_covers_all_routers():
    assert {"random", "round_robin", "power_of_d", "jsq",
            "least_work", "session_affinity"} == set(ROUTERS)
    assert set(ROUTERS) == {type(r).name for r in ROUTER_SET.values()}


def test_router_from_spec():
    assert router_from_spec("jsq").name == "jsq"
    assert router_from_spec({"kind": "power_of_d", "d": 3}).d == 3
    r = ROUTER_SET["least_work"]
    assert router_from_spec(r) is r


@pytest.mark.parametrize("pname", sorted(PAIR_POLICIES))
@pytest.mark.parametrize("rname", sorted(ROUTER_SET))
def test_fleet_oracle_vs_fast_trajectory_equal(rname, pname):
    """For every (router, policy) pair: the fast fleet makes the SAME
    routing decisions and reproduces the oracle's per-replica wait
    trajectories (the per-replica kernels are already pinned, so this is
    chiefly a routing-equality pin — incl. the jitted backlog scan)."""
    router, pol = ROUTER_SET[rname], PAIR_POLICIES[pname]
    o = route_oracle(router, pol, 0.6, 3, UNI, LAT,
                     num_requests=6_000, seed=7)
    f = simulate_fleet_fast(router, pol, 0.6, 3, UNI, LAT,
                            num_requests=6_000, seed=7)
    assert np.array_equal(o["replica_of"], f["replica_of"])
    for po, pf in zip(o["per_replica"], f["per_replica"]):
        np.testing.assert_allclose(pf["waits"], po["waits"],
                                   rtol=1e-6, atol=1e-9)
    assert abs(o["mean_wait"] - f["mean_wait"]) < 1e-6


@pytest.mark.parametrize("pname", sorted(default_policies()))
def test_random_split_replicas_bit_equal_single_server(pname):
    """The exact M/G/R split: under the ``random`` router each replica's
    trajectory is BIT-equal to the single-server model at λ/R (same
    per-replica seeds), on the oracle AND the fast layer."""
    pol = default_policies()[pname]
    lam, R, n = 0.21, 3, 9_000
    o = route_oracle("random", pol, lam, R, UNI, LAT,
                     num_requests=n, seed=5)
    f = simulate_fleet_fast("random", pol, lam, R, UNI, LAT,
                            num_requests=n, seed=5)
    for r in range(R):
        ref = simulate_policy(pol, lam / R, UNI, LAT,
                              num_requests=n // R, seed=(5, r))
        assert np.array_equal(o["per_replica"][r]["waits"], ref["waits"])
        ref_f = simulate_policy_fast(pol, lam / R, UNI, LAT,
                                     num_requests=n // R, seed=(5, r))
        assert np.array_equal(f["per_replica"][r]["waits"], ref_f["waits"])


def test_random_split_analytic_transfer():
    """Every single-server ``analytic_kind`` transfers through the random
    split: the fleet closed form IS the policy's at λ/R, and it stands in
    the same relation (exact / bound / approx) to the fleet simulation —
    WAIT included, now that ``bulk.wait_bound`` gives it a bound."""
    lam, R = 0.21, 3
    checked = []
    for name, pol in default_policies().items():
        kind = fleet_analytic_kind("random", pol)
        assert kind == pol.analytic_kind
        ana = fleet_analytic_delay("random", pol, lam, R, UNI, LAT)
        if kind is None:
            assert ana is None
            continue
        assert ana == pol.analytic_delay(lam / R, UNI, LAT)
        sim = simulate_fleet_fast("random", pol, lam, R, UNI, LAT,
                                  num_requests=90_000, seed=11)["mean_wait"]
        if kind == "exact":
            assert abs(ana - sim) / max(sim, 1e-9) < 0.10, (name, ana, sim)
        elif kind == "bound":
            assert ana >= sim * 0.95, (name, ana, sim)
            assert ana <= max(sim * 4.0, 1.0), (name, ana, sim)
        else:  # approx
            assert abs(ana - sim) / max(sim, 1e-9) < 0.35, (name, ana, sim)
        checked.append(kind)
    # the transfer must have exercised every analytic family
    assert {"exact", "bound", "approx"} <= set(checked)


def test_jsq_two_moment_approx():
    """jsq + FCFS replicas: the Whitt/QNA balanced-split two-moment
    formula tracks simulation across (λ, R) cells, and is registered as
    ``analytic_kind='approx'`` through the same machinery."""
    from repro.core.policies import FCFSPolicy
    pol = FCFSPolicy()
    assert fleet_analytic_kind("jsq", pol) == "approx"
    assert fleet_analytic_kind("jsq", DynamicPolicy()) is None
    assert fleet_analytic_kind("round_robin", pol) is None
    for lam, R in ((0.2, 3), (0.25, 3), (0.5, 8)):
        ana = fleet_analytic_delay("jsq", pol, lam, R, UNI, LAT)
        sim = simulate_fleet_fast("jsq", pol, lam, R, UNI, LAT,
                                  num_requests=60_000, seed=5)["mean_wait"]
        assert abs(ana - sim) / max(sim, 1e-9) < 0.30, (lam, R, ana, sim)


def test_router_ordering_heavy_tail():
    """Routing quality at matched load (heavy-tail lengths, SRPT
    replicas): least_work <= jsq <= round_robin <= random, with
    power-of-d between jsq and random — and the prediction-aware
    least_work strictly beating the length-blind jsq."""
    lam, R, n = 1.6, 4, 40_000
    w = {name: simulate_fleet_fast(router, SRPTPolicy(b_max=16), lam, R,
                                   LN, HT, num_requests=n, seed=3)
         ["mean_wait"]
         for name, router in ROUTER_SET.items()}
    assert w["least_work"] < 0.95 * w["jsq"], w
    assert w["jsq"] <= w["round_robin"] * 1.02, w
    assert w["round_robin"] <= w["random"] * 1.02, w
    assert w["jsq"] * 0.98 <= w["power_of_2"] <= w["random"] * 1.02, w


@pytest.mark.parametrize("pname", sorted(default_policies()))
def test_r1_fleet_equals_single_server(pname):
    """A one-replica fleet IS the single-server path, bit-equal, for
    every registered policy and any router (R=1 bypasses assignment)."""
    pol = default_policies()[pname]
    n = 2_000 if pol.name == "continuous" else 4_000
    ref = simulate_policy(pol, 0.2, UNI, LAT, num_requests=n, seed=3)
    for rname in ("jsq", "random"):
        o = route_oracle(rname, pol, 0.2, 1, UNI, LAT,
                         num_requests=n, seed=3)
        assert np.array_equal(o["per_replica"][0]["waits"], ref["waits"])
        assert o["mean_wait"] == pytest.approx(ref["mean_wait"], abs=1e-12)
    f = simulate_fleet_fast("jsq", pol, 0.2, 1, UNI, LAT,
                            num_requests=n, seed=3)
    ref_f = simulate_policy_fast(pol, 0.2, UNI, LAT, num_requests=n, seed=3)
    assert np.array_equal(f["per_replica"][0]["waits"], ref_f["waits"])


def test_backlog_route_jit_matches_numpy():
    rng = np.random.default_rng(0)
    arr = np.cumsum(rng.exponential(1.0, 5_000))
    work = rng.exponential(10.0, 5_000)
    np_assign = _backlog_assign_np(arr, work, 5)
    assert np.array_equal(backlog_route(arr, work, 5), np_assign)
    assert len(np.unique(np_assign)) == 5


def test_fleet_sweep_scaling_curve():
    """fleet.sweep: delay vs R at fixed TOTAL λ — adding replicas
    monotonically cuts the mean wait (the scaling-curve surface)."""
    grid = sweep([1, 2, 4], [0.6], "jsq", DynamicPolicy(b_max=8), UNI, LAT,
                 num_requests=12_000, seed=3)
    mw = grid["mean_wait"][:, 0]
    assert grid["mean_wait"].shape == (3, 1)
    assert mw[0] > mw[1] > mw[2]
    assert np.isfinite(mw).all()


def test_fleet_scheduler_matches_oracle():
    """Serving layer: FleetScheduler (R PolicyScheduler timelines) agrees
    statistically with the fleet oracle on an independent stream, and the
    fleet metrics decompose per replica."""
    lam, R, n = 0.6, 3, 20_000
    reqs = make_request_stream(n, lam=lam, dist=UNI, vocab=100, seed=11)
    for rname in ("least_work", "round_robin"):
        res = FleetScheduler(rname, DynamicPolicy(b_max=8), CLOCK, R).run(
            reqs)
        s = summarize_fleet(res)
        o = route_oracle(rname, DynamicPolicy(b_max=8), lam, R, UNI, LAT,
                         num_requests=n, seed=11)
        assert abs(s["mean_wait"] - o["mean_wait"]) / \
            max(o["mean_wait"], 0.1) < 0.15, (rname, s["mean_wait"], o)
        assert sum(s["replica_requests"]) == n
        assert len(s["per_replica"]) == R
        assert all(p is not None and np.isfinite(p["mean_wait"])
                   for p in s["per_replica"])
        assert not res.lost.any()      # dynamic serves everyone


def test_fleet_scheduler_runs_continuous_policy():
    """Continuous batching binds its own scheduler; the fleet adapter
    must route to it rather than the generic formation walker."""
    from repro.core.policies import ContinuousPolicy
    reqs = make_request_stream(2_000, lam=0.6, dist=UNI, vocab=100, seed=4)
    res = FleetScheduler("round_robin", ContinuousPolicy(slots=8), CLOCK,
                         2).run(reqs)
    s = summarize_fleet(res)
    assert np.isfinite(s["mean_wait"]) and not res.lost.any()
    assert sum(s["replica_requests"]) == len(reqs)


def test_fleet_scheduler_least_work_prompt_predictor():
    """The satellite loop closed end-to-end: a PromptFeaturePredictor
    fitted on served (prompt, length) pairs drives least_work dispatch on
    the serving layer and beats random routing under heavy-tail lengths
    — a non-synthetic estimator behind prediction-aware routing."""
    from repro.core.fleet import LeastWorkRouter
    from repro.core.predictors import PromptFeaturePredictor
    train = make_request_stream(6_000, lam=1.6, dist=LN, vocab=100, seed=1,
                                prompt_len_corr=1.0)
    pred = PromptFeaturePredictor.fitted_on(train)
    reqs = make_request_stream(20_000, lam=1.6, dist=LN, vocab=100, seed=2,
                               prompt_len_corr=1.0)
    clock = ModelClock(single_from_batch(HT), HT)
    pol = SRPTPolicy(b_max=16)
    router = LeastWorkRouter(predictor=pred)
    res = FleetScheduler(router, pol, clock, 4).run(reqs)
    lw = summarize(res)
    rnd = summarize(FleetScheduler("random", pol, clock, 4).run(reqs))
    assert lw["mean_wait"] < rnd["mean_wait"], (lw, rnd)
    # the prompt signal must actually reach the router: its work estimates
    # are prompt-driven (non-constant, correlated with the true lengths),
    # so routing differs from the length-blind jsq assignment
    from repro.core.policies import Workload
    ns = np.array([r.target_output_tokens for r in reqs], np.float64)
    work = router.routing_work(
        Workload(arrivals=np.array([r.arrival for r in reqs]), tokens=ns),
        single_from_batch(HT), 0,
        prompts=[r.prompt_tokens for r in reqs])
    assert np.std(work) > 0, "prompt predictor fell back to a constant"
    assert np.corrcoef(np.log(work), np.log(np.maximum(ns, 1)))[0, 1] > 0.5
    jsq = FleetScheduler("jsq", pol, clock, 4).run(reqs)
    assert (res.replica_of != jsq.replica_of).any()


@pytest.fixture(scope="module")
def engine():
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    return Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                    prompt_bucket=16))


def test_run_fleet_schedule_on_engine(engine):
    """Engine layer: a routed fleet executes each replica's batches on
    the REAL engine (one shared engine, replica-tagged batches)."""
    rng = np.random.default_rng(0)
    reqs = make_request_stream(8, lam=5.0, dist=UNI, vocab=50, seed=2)
    for r in reqs:                      # keep the smoke model's decode short
        r.target_output_tokens = int(rng.integers(2, 12))
    res = run_fleet_schedule("round_robin", DynamicPolicy(b_max=4), engine,
                             reqs, R=2, lat=LAT)
    assert np.isfinite(res.waits).all() and (res.waits >= 0).all()
    assert (res.e2e >= res.waits).all()
    assert sum(res.batch_sizes) == len(reqs)
    assert len(res.per_replica) == 2
    assert set(np.unique(res.replica_of)) == {0, 1}
    assert not res.lost.any()
    s = summarize_fleet(res)
    assert s["replica_requests"] == [4, 4]


# ----------------------------------------------------------------------------
# Satellite: bulk.wait_bound — WAIT joins the analytic cross-checks
# ----------------------------------------------------------------------------

def test_wait_bound_shape_and_dominance():
    from repro.core.bulk import wait_bound
    assert WaitPolicy(k=8).analytic_kind == "bound"
    assert WaitPolicy(k=8, b_max=4).analytic_kind is None
    assert WaitPolicy(k=8, b_max=4).analytic_delay(0.2, UNI, LAT) is None
    for lam in (0.1, 0.4):
        pol = WaitPolicy(k=8)
        ana = pol.analytic_delay(lam, UNI, LAT)
        sim = simulate_policy_fast(pol, lam, UNI, LAT,
                                   num_requests=60_000, seed=11)["mean_wait"]
        assert ana >= sim * 0.95, (lam, ana, sim)
        assert ana <= max(sim * 4.0, 1.0), (lam, ana, sim)
    # the hold arm: (k-1)/(2λ) positional mean without a timer
    d = wait_bound(UNI, LAT, 0.1, k=8)
    assert d["hold_arm"] == pytest.approx(7 / (2 * 0.1))
    assert d["wait_bound"] == d["hold_arm"] + d["clearing_arm"]


def test_wait_bound_timer_caps_holding_and_k_monotone():
    from repro.core.bulk import wait_bound
    lam = 0.05
    pure = wait_bound(UNI, LAT, lam, k=50)
    timed = wait_bound(UNI, LAT, lam, k=50, timeout=5.0)
    assert timed["hold_arm"] <= 5.0 < pure["hold_arm"]
    assert timed["wait_bound"] < pure["wait_bound"]
    # more holding, more bound
    assert wait_bound(UNI, LAT, 0.2, k=4)["wait_bound"] < \
        wait_bound(UNI, LAT, 0.2, k=16)["wait_bound"]


def test_wait_bound_transfers_through_random_split():
    """The new WAIT bound rides the fleet transfer: at R replicas under
    the random split the bound at λ/R dominates the fleet simulation."""
    lam, R = 0.6, 3
    pol = WaitPolicy(k=8)
    ana = fleet_analytic_delay("random", pol, lam, R, UNI, LAT)
    assert fleet_analytic_kind("random", pol) == "bound"
    sim = simulate_fleet_fast("random", pol, lam, R, UNI, LAT,
                              num_requests=45_000, seed=9)["mean_wait"]
    assert ana >= sim * 0.95
    assert ana <= max(sim * 4.0, 1.0)


# ----------------------------------------------------------------------------
# Satellite: PromptFeaturePredictor — real prompt features
# ----------------------------------------------------------------------------

def test_prompt_feature_predictor_learns_correlated_prompts():
    from repro.core.predictors import (
        PREDICTORS, PromptFeaturePredictor, prediction_log_rmse)
    assert "prompt_features" in PREDICTORS
    train = make_request_stream(8_000, lam=0.5, dist=LN, vocab=100, seed=1,
                                prompt_len_corr=1.0)
    test = make_request_stream(4_000, lam=0.5, dist=LN, vocab=100, seed=2,
                               prompt_len_corr=1.0)
    p = PromptFeaturePredictor.fitted_on(train)
    true = np.array([r.target_output_tokens for r in test], np.float64)
    prompts = [r.prompt_tokens for r in test]
    rmse = prediction_log_rmse(p.predict(0, true, prompts), true)
    const = prediction_log_rmse(
        np.full(len(true), np.exp(np.mean(np.log(true)))), true)
    assert rmse < 0.6 * const          # the prompt signal is real
    # deterministic given the prompts (no hidden access to true lengths)
    assert np.array_equal(p.predict(0, true, prompts),
                          p.predict(99, np.ones_like(true), prompts))


def test_prompt_feature_predictor_honest_without_signal():
    from repro.core.predictors import (
        PromptFeaturePredictor, prediction_log_rmse)
    # uncorrelated prompts: no better than the marginal (no length leak)
    train = make_request_stream(8_000, lam=0.5, dist=LN, vocab=100, seed=1)
    test = make_request_stream(4_000, lam=0.5, dist=LN, vocab=100, seed=2)
    p = PromptFeaturePredictor.fitted_on(train)
    true = np.array([r.target_output_tokens for r in test], np.float64)
    rmse = prediction_log_rmse(
        p.predict(0, true, [r.prompt_tokens for r in test]), true)
    const = prediction_log_rmse(
        np.full(len(true), np.exp(np.mean(np.log(true)))), true)
    assert rmse > 0.9 * const
    # prompt-less layers: the constant training-marginal fallback
    fb = p.predict(0, true, None)
    assert np.isfinite(fb).all() and (fb == fb[0]).all()
    fresh = PromptFeaturePredictor()         # unfitted: still safe
    assert np.isfinite(fresh.predict(0, true, None)).all()


# ----------------------------------------------------------------------------
# Satellite: the controller's replicas/router axis
# ----------------------------------------------------------------------------

def test_controller_recommends_fleet_axis():
    from repro.core.control import AdaptiveController
    from repro.core.latency_model import LatencyModel
    single = LatencyModel(0.0212, 1.79)
    batch = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)

    def feed(ctrl, dist, lam):
        rng = np.random.default_rng(0)
        t = 0.0
        for x in dist.sample(rng, 512):
            t += rng.exponential(1.0 / lam)
            ctrl.observe_arrival(t)
            ctrl.observe_completion(int(max(x, 1)))

    c = AdaptiveController(single, batch, max_replicas=16, min_samples=32)
    feed(c, LN, 8.0)
    rec = c.recommendation()
    assert rec.replicas > 1
    assert rec.router == "least_work"          # heavy tail: length-aware
    assert rec.router in ROUTERS
    assert rec.predictor is not None           # actionable with estimator
    assert rec.replicas == recommend_replicas(
        rec.lam_hat, c.empirical_dist().clip(rec.n_max), batch)
    # light traffic / default construction keep the legacy single server
    c1 = AdaptiveController(single, batch, min_samples=32)
    feed(c1, UNI, 0.01)
    rec1 = c1.recommendation()
    assert rec1.replicas == 1 and rec1.router is None
