"""The length-predictor subsystem, across all four layers.

Contract (ISSUE 4):
  * the ORACLE predictor is a no-op: SRPT / multi-bin trajectories are
    bit-equal to the pre-predictor (PR 3) behavior, on the reference
    oracle AND the fast kernels (which must also stay trajectory-equal to
    each other under noisy predictors);
  * prediction-INSENSITIVE policies never see the predicted column: their
    trajectories are bit-identical under any predictor;
  * mean wait degrades monotonically as prediction noise sigma grows
    (``fastsim.sweep_noise``, whose sigma=0 column must equal the plain
    kernel — also pinning the vmapped lanes against the single-cell
    path);
  * the learned head beats the raw noisy observation at matched
    per-feature error on held-out workloads — in prediction error AND in
    downstream SRPT delay;
  * the scheduler and engine layers accept predictors and follow the same
    predicted-vs-true convention.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.distributions import LogNormalTokens, UniformTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    DynamicPolicy, MultiBinPolicy, SRPTPolicy, WaitPolicy, single_from_batch)
from repro.core.predictors import (
    PREDICTORS, AdditiveNoisePredictor, BucketPredictor, LearnedPredictor,
    LogNormalNoisePredictor, OraclePredictor, get_predictor,
    prediction_log_rmse, predictor_from_spec)
from repro.core.simulate import simulate_policy
from repro.core.fastsim import simulate_policy_fast, sweep_noise
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.scheduler import ModelClock

UNI = UniformTokens(1000)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
LN = LogNormalTokens(7.0, 0.7)
HT = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
CLOCK = ModelClock(single_from_batch(LAT), LAT)


def test_registry_covers_shipped_predictors():
    assert {"oracle", "lognormal_noise", "additive_noise", "bucket",
            "learned"} <= set(PREDICTORS)
    assert isinstance(get_predictor("oracle"), OraclePredictor)
    p = predictor_from_spec({"kind": "lognormal_noise", "sigma": 0.7})
    assert isinstance(p, LogNormalNoisePredictor) and p.sigma == 0.7
    assert predictor_from_spec(p) is p


@pytest.mark.parametrize("plain,oracle", [
    (SRPTPolicy(b_max=8), SRPTPolicy(b_max=8, predictor=OraclePredictor())),
    (SRPTPolicy(b_max=8), SRPTPolicy(b_max=8, predictor="oracle")),
    (MultiBinPolicy(num_bins=4),
     MultiBinPolicy(num_bins=4, predictor="oracle")),
], ids=["srpt-inst", "srpt-name", "multibin"])
def test_oracle_predictor_bit_equal_to_pr3(plain, oracle):
    """The oracle predictor must not move a single bit relative to the
    predictor-less PR 3 policies — on the reference oracle and the fast
    kernel, which must in turn agree with each other."""
    for lam in (0.05, 0.2):
        r_plain = simulate_policy(plain, lam, UNI, LAT,
                                  num_requests=15_000, seed=7)
        r_orcl = simulate_policy(oracle, lam, UNI, LAT,
                                 num_requests=15_000, seed=7)
        np.testing.assert_array_equal(r_orcl["waits"], r_plain["waits"])
        f_plain = simulate_policy_fast(plain, lam, UNI, LAT,
                                       num_requests=15_000, seed=7)
        f_orcl = simulate_policy_fast(oracle, lam, UNI, LAT,
                                      num_requests=15_000, seed=7)
        np.testing.assert_array_equal(f_orcl["waits"], f_plain["waits"])
        np.testing.assert_allclose(f_orcl["waits"], r_orcl["waits"],
                                   rtol=1e-6, atol=1e-9)


def test_sigma_zero_noise_is_the_oracle():
    """lognormal_noise at sigma=0 multiplies by exp(0) exactly: bit-equal
    to the oracle predictor, not merely close."""
    pol0 = SRPTPolicy(b_max=8, predictor=LogNormalNoisePredictor(0.0))
    pol = SRPTPolicy(b_max=8)
    f0 = simulate_policy_fast(pol0, 0.2, UNI, LAT,
                              num_requests=10_000, seed=3)
    f = simulate_policy_fast(pol, 0.2, UNI, LAT, num_requests=10_000, seed=3)
    np.testing.assert_array_equal(f0["waits"], f["waits"])


@pytest.mark.parametrize("pol", [
    SRPTPolicy(b_max=8, predictor=LogNormalNoisePredictor(0.5)),
    SRPTPolicy(b_max=8, predictor=AdditiveNoisePredictor(std=120.0)),
    SRPTPolicy(b_max=8, predictor=BucketPredictor(num_buckets=8,
                                                  accuracy=0.8)),
    MultiBinPolicy(num_bins=4, predictor=LogNormalNoisePredictor(0.5)),
    MultiBinPolicy(num_bins=4, b_max=8,
                   predictor=BucketPredictor(num_buckets=4, accuracy=0.6)),
], ids=repr)
def test_noisy_predictor_oracle_vs_fast_trajectory_equal(pol):
    """The predicted column must thread identically through the reference
    loop and the compiled kernel: same salted rng stream, so per-request
    waits still match to float rounding under ANY predictor."""
    for lam in (0.05, 0.2):
        r = simulate_policy(pol, lam, UNI, LAT, num_requests=12_000, seed=7)
        f = simulate_policy_fast(pol, lam, UNI, LAT,
                                 num_requests=12_000, seed=7)
        np.testing.assert_allclose(f["waits"], r["waits"],
                                   rtol=1e-6, atol=1e-9)


def test_workload_rng_untouched_by_predictor():
    """Arrivals/tokens must be bit-identical with and without a predictor
    (the predictor draws from a salted side stream), and prediction-
    insensitive membership (dynamic, WAIT) must ignore the column."""
    noisy = LogNormalNoisePredictor(2.0)
    wl_a = SRPTPolicy(b_max=8).sample_workload(0.2, UNI, 5_000, 11)
    wl_b = SRPTPolicy(b_max=8, predictor=noisy).sample_workload(
        0.2, UNI, 5_000, 11)
    np.testing.assert_array_equal(wl_a.arrivals, wl_b.arrivals)
    np.testing.assert_array_equal(wl_a.tokens, wl_b.tokens)
    assert wl_a.predicted is None and wl_b.predicted is not None
    for mk in (lambda p: DynamicPolicy(b_max=8, predictor=p),
               lambda p: WaitPolicy(k=8, predictor=p)):
        base = simulate_policy_fast(mk(None), 0.2, UNI, LAT,
                                    num_requests=10_000, seed=3)
        pred = simulate_policy_fast(mk(noisy), 0.2, UNI, LAT,
                                    num_requests=10_000, seed=3)
        np.testing.assert_array_equal(base["waits"], pred["waits"])


def test_sweep_noise_monotone_degradation():
    """Heavy-tail SRPT at high load (λ=1: the regime where PR 3 measured
    the oracle win): mean wait rises with sigma, the sigma=0 column
    reproduces the plain kernel exactly (also pinning the vmapped lanes
    against the single-cell path), and a big-noise SRPT never beats the
    oracle."""
    sigmas = [0.0, 0.5, 1.5]
    g = sweep_noise(
        lambda s: SRPTPolicy(b_max=16, predictor=LogNormalNoisePredictor(s)),
        [1.0], sigmas, LN, HT, num_requests=20_000, seed=9)
    w = g["mean_wait"][0]
    ref = simulate_policy_fast(SRPTPolicy(b_max=16), 1.0, LN, HT,
                               num_requests=20_000, seed=9)["mean_wait"]
    assert abs(w[0] - ref) < 1e-9
    assert w[0] < w[1] < w[2], w
    # multibin: same direction via the per-cell fallback path
    gm = sweep_noise(
        lambda s: MultiBinPolicy(num_bins=4,
                                 predictor=LogNormalNoisePredictor(s)),
        [0.6], [0.0, 1.5], LN, HT, num_requests=20_000, seed=9)
    assert gm["mean_wait"][0, 0] < gm["mean_wait"][0, 1]


def test_bucket_accuracy_orders_srpt_delay():
    """A more accurate bucket classifier yields a shorter SRPT mean wait
    on the heavy tail (quantization alone costs little; misclassification
    is what hurts)."""
    waits = {}
    for acc in (1.0, 0.3):
        pol = SRPTPolicy(b_max=16, predictor=BucketPredictor(
            num_buckets=8, accuracy=acc))
        waits[acc] = simulate_policy_fast(pol, 0.6, LN, HT,
                                          num_requests=25_000,
                                          seed=9)["mean_wait"]
    assert waits[1.0] < waits[0.3], waits


def test_learned_head_beats_raw_noise_at_matched_error():
    """At matched per-observation error (feature_noise == sigma), the
    ridge head combining several noisy views wins on held-out workloads:
    lower log-RMSE AND lower downstream SRPT delay."""
    feature_noise = 0.5
    learned = LearnedPredictor(feature_noise=feature_noise).fit(
        LN, num_train=20_000, seed=0)
    raw = LogNormalNoisePredictor(sigma=feature_noise)
    rng = np.random.default_rng(123)          # held-out workload
    true = np.maximum(LN.sample(rng, 30_000).astype(np.float64), 1.0)
    rmse_l = prediction_log_rmse(learned.predict(55, true), true)
    rmse_r = prediction_log_rmse(raw.predict(55, true), true)
    assert rmse_l < 0.85 * rmse_r, (rmse_l, rmse_r)
    w_l = simulate_policy_fast(SRPTPolicy(b_max=16, predictor=learned),
                               0.6, LN, HT, num_requests=25_000,
                               seed=9)["mean_wait"]
    w_r = simulate_policy_fast(SRPTPolicy(b_max=16, predictor=raw),
                               0.6, LN, HT, num_requests=25_000,
                               seed=9)["mean_wait"]
    assert w_l < w_r, (w_l, w_r)


def test_multibin_bound_quantile_extends_heavy_tail_range():
    """ROADMAP item: the round arm's alpha~ uses max support and returns
    inf on heavy tails where the simulator is fine; the quantile envelope
    keeps it finite and still above the simulated mean there."""
    from repro.core.bulk import multibin_bound
    pol = MultiBinPolicy(num_bins=4)
    edges = pol.bin_edges(LN)
    lam = 0.5
    strict = multibin_bound(LN, HT, lam, edges)
    q = multibin_bound(LN, HT, lam, edges, quantile=0.99)
    sim = simulate_policy_fast(pol, lam, LN, HT,
                               num_requests=25_000, seed=15)
    assert np.isinf(strict["wait_round_arm"])
    assert np.isfinite(q["wait_round_arm"])
    assert q["wait_bound"] >= sim["mean_wait"]
    # quantile=1.0 is bit-identical to the strict arm
    assert multibin_bound(LN, HT, lam, edges, 1.0)["wait_round_arm"] \
        == strict["wait_round_arm"]
    # the policy surface: bound_quantile<1 downgrades analytic_kind
    pq = MultiBinPolicy(num_bins=4, bound_quantile=0.99)
    assert pq.analytic_kind == "approx"
    assert np.isfinite(pq.analytic_delay(lam, LN, HT))
    assert MultiBinPolicy(num_bins=4).analytic_kind == "bound"


def test_scheduler_layer_accepts_predictor():
    """PolicyScheduler: the oracle predictor is a bit-level no-op; a noisy
    predictor (policy-attached or passed as override) degrades SRPT on
    the virtual timeline just like the simulators say."""
    reqs = make_request_stream(8_000, lam=0.6, dist=LN, vocab=100, seed=11)
    clock = ModelClock(single_from_batch(HT), HT)
    plain = summarize(SRPTPolicy(b_max=16).scheduler(clock).run(reqs))
    orcl = summarize(SRPTPolicy(b_max=16).scheduler(
        clock, predictor="oracle").run(reqs))
    assert plain["mean_wait"] == orcl["mean_wait"]
    noisy_pol = summarize(SRPTPolicy(
        b_max=16, predictor=LogNormalNoisePredictor(1.5))
        .scheduler(clock).run(reqs))
    noisy_ovr = summarize(SRPTPolicy(b_max=16).scheduler(
        clock, predictor=LogNormalNoisePredictor(1.5)).run(reqs))
    assert noisy_ovr["mean_wait"] == noisy_pol["mean_wait"]  # same stream
    assert noisy_pol["mean_wait"] > plain["mean_wait"]


def test_controller_recommendation_names_predictor():
    """AdaptiveController: a multibin recommendation carries the length
    predictor that should feed the routing; other policies carry None."""
    from repro.core.control import AdaptiveController
    ctl = AdaptiveController(
        LatencyModel(0.0212, 1.79), HT, elastic_available=False,
        min_samples=64, length_predictor="learned")
    rng = np.random.default_rng(0)
    toks = LN.sample(rng, 512)
    t = 0.0
    for n in toks:
        t += float(rng.exponential(1.0))
        ctl.observe_arrival(t)
        ctl.observe_completion(int(n))
    rec = ctl.recommendation(force=True)
    assert rec.policy == "multibin"
    assert rec.predictor == "learned"
    ctl2 = AdaptiveController(
        LatencyModel(0.0212, 1.79), HT, elastic_available=True)
    for n in toks:
        ctl2.observe_completion(int(n))
    t = 0.0
    for _ in range(128):
        t += 1.0
        ctl2.observe_arrival(t)
    rec2 = ctl2.recommendation(force=True)
    assert rec2.policy != "multibin" and rec2.predictor is None
    with pytest.raises(AssertionError):
        AdaptiveController(LatencyModel(0.0212, 1.79), HT,
                           length_predictor="nope")


@pytest.fixture(scope="module")
def engine():
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    return Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                    prompt_bucket=16))


def test_engine_layer_runs_predicted_batches(engine):
    """run_engine_schedule with a noisy predictor: batches form on
    predictions, the engine decodes true lengths — every request is still
    served exactly once."""
    from repro.serving.scheduler import run_engine_schedule
    rng = np.random.default_rng(0)
    reqs = make_request_stream(8, lam=5.0, dist=UNI, vocab=50, seed=2)
    for r in reqs:                      # keep the smoke model's decode short
        r.target_output_tokens = int(rng.integers(2, 12))
    pol = SRPTPolicy(b_max=4)
    res = run_engine_schedule(pol, engine, reqs,
                              predictor=LogNormalNoisePredictor(0.8))
    assert np.isfinite(res.waits).all() and (res.waits >= 0).all()
    assert (res.e2e >= res.waits).all()
    assert sum(res.batch_sizes) == len(reqs)
