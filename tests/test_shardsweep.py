"""Sharded grid sweeps vs the single-device fastsim/fleet entry points.

The contract (ISSUE 7): ``repro.core.shardsweep`` spreads sweep lanes over
a "cells" device mesh with ``shard_map`` and must return BIT-equal results
(same dtype path, exact ``==``) to the vmapped single-device sweeps —
lanes are elementwise-independent and padding is inert.  On the tier-1
runner the mesh has size 1 (conftest mandates one device); the subprocess
test at the bottom forces a real 4-device CPU mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (also the CI
``kernels`` job's configuration)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import fastsim, fleet, shardsweep
from repro.core.distributions import LogNormalTokens
from repro.core.latency_model import BatchLatencyModel
from repro.core.policies import (
    DynamicPolicy, ElasticPolicy, FCFSPolicy, SRPTPolicy)
from repro.core.predictors import LogNormalNoisePredictor

LN = LogNormalTokens()
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
LAMS = [0.05, 0.1, 0.15]


def test_pad_lane_count():
    assert shardsweep.pad_lane_count(1, 1) == 2
    assert shardsweep.pad_lane_count(3, 1) == 4
    assert shardsweep.pad_lane_count(4, 4) == 4
    assert shardsweep.pad_lane_count(2, 4) == 4      # mesh >= pow2
    assert shardsweep.pad_lane_count(6, 4) == 8
    assert shardsweep.pad_lane_count(5, 3) == 9      # non-pow2 mesh
    for n in range(1, 40):
        for d in (1, 2, 4, 8):
            L = shardsweep.pad_lane_count(n, d)
            assert L >= n and L % d == 0


def test_sweep_matches_single_device():
    pols = {"dynamic": DynamicPolicy(), "elastic": ElasticPolicy(b_max=8),
            "fcfs": FCFSPolicy()}       # fcfs: per-cell fallback inside sweep
    a = fastsim.sweep(pols, LAMS, LN, LAT, num_requests=4_000, seed=0)
    b = shardsweep.sweep(pols, LAMS, LN, LAT, num_requests=4_000, seed=0)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_sweep_noise_matches_single_device():
    fac = lambda s: SRPTPolicy(b_max=16,
                               predictor=LogNormalNoisePredictor(s))
    a = fastsim.sweep_noise(fac, [0.1, 0.2], [0.0, 0.5, 1.0], LN, LAT,
                            num_requests=3_000, seed=9)
    b = shardsweep.sweep_noise(fac, [0.1, 0.2], [0.0, 0.5, 1.0], LN, LAT,
                               num_requests=3_000, seed=9)
    np.testing.assert_array_equal(a["mean_wait"], b["mean_wait"])
    np.testing.assert_array_equal(a["lams"], b["lams"])
    np.testing.assert_array_equal(a["sigmas"], b["sigmas"])


@pytest.mark.parametrize("router", ["round_robin", "least_work"])
def test_fleet_sweep_matches_single_device(router):
    a = fleet.sweep([1, 2, 3], LAMS, router, ElasticPolicy(b_max=8), LN,
                    LAT, num_requests=3_000, seed=1)
    b = shardsweep.fleet_sweep([1, 2, 3], LAMS, router,
                               ElasticPolicy(b_max=8), LN, LAT,
                               num_requests=3_000, seed=1)
    np.testing.assert_array_equal(a["mean_wait"], b["mean_wait"])
    np.testing.assert_array_equal(a["R_grid"], b["R_grid"])
    np.testing.assert_array_equal(a["lams"], b["lams"])


def test_fleet_sweep_fallback_for_non_scan_policy():
    """FCFS has no batch_scan lane -> fleet_sweep must delegate to the
    per-cell path, still returning identical numbers."""
    a = fleet.sweep([1, 2], LAMS, "random", FCFSPolicy(), LN, LAT,
                    num_requests=2_000, seed=2)
    b = shardsweep.fleet_sweep([1, 2], LAMS, "random", FCFSPolicy(), LN,
                               LAT, num_requests=2_000, seed=2)
    np.testing.assert_array_equal(a["mean_wait"], b["mean_wait"])


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.device_count()
    from repro.core import fastsim, fleet, shardsweep
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import DynamicPolicy, ElasticPolicy

    LN = LogNormalTokens()
    LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    lams = [0.05, 0.1, 0.15]
    pols = {"dynamic": DynamicPolicy(), "elastic": ElasticPolicy(b_max=8)}
    a = fastsim.sweep(pols, lams, LN, LAT, num_requests=3000, seed=0)
    b = shardsweep.sweep(pols, lams, LN, LAT, num_requests=3000, seed=0)
    for k in a:
        assert np.array_equal(a[k], b[k]), k
    fa = fleet.sweep([1, 2, 3], lams, "least_work", ElasticPolicy(b_max=8),
                     LN, LAT, num_requests=2000, seed=1)
    fb = shardsweep.fleet_sweep([1, 2, 3], lams, "least_work",
                                ElasticPolicy(b_max=8), LN, LAT,
                                num_requests=2000, seed=1)
    assert np.array_equal(fa["mean_wait"], fb["mean_wait"])
    print("OK")
""")


def test_sharded_equality_on_forced_4_device_mesh():
    """The real multi-device check: a fresh process with 4 forced CPU
    devices must reproduce the single-device sweep numbers exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
