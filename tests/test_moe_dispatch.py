"""MoE dispatch properties: dropless exactness vs a brute-force per-token
oracle, grouped-dispatch equivalence, capacity semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.sharding import NULL_CTX
from repro.models.moe import moe_block, moe_specs, _capacity
from repro.models.params import init_params

RNG = jax.random.PRNGKey(0)


def _cfg(dropless=True, groups=1, experts=4, k=2):
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, num_experts=experts, num_experts_per_tok=k, moe_groups=groups,
        capacity_factor=(float(experts) / k if dropless else 1.0))
    return cfg


def _brute_force(p, x, cfg):
    """Per-token oracle: route every token to its top-k experts, no
    capacity."""
    b, s, d = x.shape
    xt = np.asarray(x).reshape(-1, d)
    gates = xt.astype(np.float64) @ np.asarray(p["router"], np.float64)
    e = cfg.num_experts
    probs = np.exp(gates - gates.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt, dtype=np.float64)
    for t in range(xt.shape[0]):
        idx = np.argsort(-probs[t])[: cfg.num_experts_per_tok]
        w = probs[t][idx]
        w = w / w.sum()
        for j, ei in enumerate(idx):
            up = xt[t] @ np.asarray(p["w_up"][ei], np.float64)
            gate = xt[t] @ np.asarray(p["w_gate"][ei], np.float64)
            h = (gate / (1 + np.exp(-gate))) * up          # silu(gate)*up
            out[t] += w[j] * (h @ np.asarray(p["w_down"][ei], np.float64))
    return out.reshape(b, s, d)


def test_dropless_matches_bruteforce_oracle():
    cfg = _cfg(dropless=True)
    p = init_params(moe_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    out, _ = moe_block(p, x, cfg, NULL_CTX)
    ref = _brute_force(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_dispatch_equals_global(groups):
    cfg1 = _cfg(dropless=True, groups=1)
    cfgg = _cfg(dropless=True, groups=groups)
    p = init_params(moe_specs(cfg1), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, cfg1.d_model),
                          jnp.float32)
    o1, _ = moe_block(p, x, cfg1, NULL_CTX)
    og, _ = moe_block(p, x, cfgg, NULL_CTX)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(og),
                               rtol=1e-5, atol=1e-5)


def test_capacity_clamps_at_tokens():
    cfg = _cfg(dropless=True)
    assert _capacity(cfg, 16) <= 16
    assert _capacity(cfg, 10_000) >= \
        10_000 * cfg.num_experts_per_tok / cfg.num_experts


def test_capacity_drops_under_overflow():
    """With capacity_factor=0.5 some tokens must drop (output != oracle) but
    the result stays finite and bounded."""
    cfg = dataclasses.replace(_cfg(dropless=False), capacity_factor=0.25)
    p = init_params(moe_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model),
                          jnp.float32)
    out, aux = moe_block(p, x, cfg, NULL_CTX, return_aux=True)
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 1.0 - 1e-6   # load-balance loss lower bound = 1
