"""Fast (vectorized / lax.scan) simulators vs the NumPy reference oracle.

Both implementations sample with the same rng call order, so equal seeds
must give matching trajectories — means/p95s agree to float-rounding, not
just statistically."""

import numpy as np
import pytest

from repro.core.distributions import LogNormalTokens, UniformTokens
from repro.core.fastsim import (
    simulate_dynamic_batching_fast, simulate_fixed_batching_fast,
    simulate_mg1_fast, simulate_policy_sweep_fast)
from repro.core.latency_model import (
    BatchLatencyModel, PAPER_A100_LLAMA2_7B)
from repro.core.simulate import (
    simulate_dynamic_batching, simulate_fixed_batching, simulate_mg1,
    simulate_policy_sweep)

UNI = UniformTokens(1000)
LN = LogNormalTokens(7.0, 0.7)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
N = 40_000
TOL = 1e-6


def _close(a, b, tol=TOL):
    assert abs(a - b) <= tol * max(1.0, abs(a), abs(b)), (a, b)


def test_mg1_no_impatience_identical():
    r = simulate_mg1(0.02, LN, PAPER_A100_LLAMA2_7B, n_max=1600,
                     num_requests=N, seed=3)
    f = simulate_mg1_fast(0.02, LN, PAPER_A100_LLAMA2_7B, n_max=1600,
                          num_requests=N, seed=3)
    np.testing.assert_allclose(f["waits"], r["waits"], rtol=1e-9)


@pytest.mark.parametrize("tau", [30.0, 120.0])
def test_mg1_impatience_matches_reference(tau):
    kw = dict(n_max=1600, tau=tau, num_requests=N, seed=3)
    r = simulate_mg1(1 / 40, LN, PAPER_A100_LLAMA2_7B, **kw)
    f = simulate_mg1_fast(1 / 40, LN, PAPER_A100_LLAMA2_7B, **kw)
    _close(r["mean_wait"], f["mean_wait"])
    _close(r["p95_wait"], f["p95_wait"])
    _close(r["loss_frac"], f["loss_frac"])
    _close(r["mean_wait_served"], f["mean_wait_served"])


@pytest.mark.parametrize("kw", [
    dict(),
    dict(elastic=True),
    dict(b_max=8),
    dict(elastic=True, b_max=4),
    dict(n_max=500),
])
def test_dynamic_batching_matches_reference(kw):
    r = simulate_dynamic_batching(0.2, UNI, LAT, num_requests=N, seed=3, **kw)
    f = simulate_dynamic_batching_fast(0.2, UNI, LAT, num_requests=N,
                                       seed=3, **kw)
    _close(r["mean_wait"], f["mean_wait"])
    _close(r["p95_wait"], f["p95_wait"])
    _close(r["mean_batch"], f["mean_batch"])


@pytest.mark.parametrize("b", [4, 16])
def test_fixed_batching_matches_reference(b):
    r = simulate_fixed_batching(0.3, b, UNI, LAT, num_requests=N, seed=5)
    f = simulate_fixed_batching_fast(0.3, b, UNI, LAT, num_requests=N, seed=5)
    _close(r["mean_wait"], f["mean_wait"])
    _close(r["p95_wait"], f["p95_wait"])


def test_fixed_batching_custom_batch_time_delegates():
    bt = lambda ns: 1.0 + 0.01 * float(np.max(ns))
    r = simulate_fixed_batching(0.3, 4, UNI, batch_time=bt,
                                num_requests=8_000, seed=1)
    f = simulate_fixed_batching_fast(0.3, 4, UNI, batch_time=bt,
                                     num_requests=8_000, seed=1)
    _close(r["mean_wait"], f["mean_wait"])


def test_policy_sweep_matches_reference():
    policies = {
        "dyn": dict(kind="dynamic"),
        "dyn8": dict(kind="dynamic", b_max=8),
        "ela": dict(kind="elastic"),
        "fix4": dict(kind="fixed", b=4),
    }
    r = simulate_policy_sweep([0.1, 0.4], UNI, LAT, policies,
                              num_requests=20_000, seed=0)
    f = simulate_policy_sweep_fast([0.1, 0.4], UNI, LAT, policies,
                                   num_requests=20_000, seed=0)
    for name in policies:
        np.testing.assert_allclose(f[name], r[name], rtol=TOL)
