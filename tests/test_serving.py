"""Serving engine + schedulers + adaptive control plane."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.control import AdaptiveController
from repro.core.distributions import LogNormalTokens, UniformTokens
from repro.core.latency_model import (
    BatchLatencyModel, LatencyModel, fit_batch_latency_model,
    fit_latency_model, linear_fit_r2)
from repro.core.simulate import simulate_dynamic_batching, simulate_mg1
from repro.data.pipeline import make_request_stream
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import summarize
from repro.serving.scheduler import (
    ContinuousBatchScheduler, DynamicBatchScheduler, ElasticBatchScheduler,
    FCFSScheduler, FixedBatchScheduler, ModelClock)

LAT1 = LatencyModel(a=0.0212, c=1.79)
LATB = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
CLOCK = ModelClock(LAT1, LATB)


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, num_layers=2)
    return Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                    prompt_bucket=16))


def test_engine_generates_requested_tokens(engine):
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    res = engine.generate(prompts, [8, 3, 5])
    assert list(res["produced"]) == [8, 3, 5]
    # padded mode: everyone completes at batch end
    assert np.allclose(res["completion_seconds"],
                       res["completion_seconds"].max())


def test_engine_elastic_early_exit(engine):
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    res = engine.generate(prompts, [16, 2, 6], elastic=True)
    assert list(res["produced"]) == [16, 2, 6]
    c = res["completion_seconds"]
    assert c[1] < c[2] < c[0]          # short replies exit earlier


def test_engine_elastic_same_tokens_as_padded(engine):
    """Elastic scheduling must not change WHAT is generated."""
    prompts = [np.arange(6, dtype=np.int32)]
    r1 = engine.generate(prompts, [12])
    r2 = engine.generate(prompts, [12], elastic=True)
    assert list(r1["produced"]) == list(r2["produced"])


def test_engine_nmax_clipping(engine):
    prompts = [np.arange(4, dtype=np.int32)]
    res = engine.generate(prompts, [20], n_max=5)
    assert list(res["produced"]) == [5]


def test_scheduler_matches_simulator():
    """DynamicBatchScheduler on the model clock == core.simulate (same
    logic, independent implementations)."""
    uni = UniformTokens(1000)
    reqs = make_request_stream(30_000, lam=0.1, dist=uni, vocab=100, seed=11)
    s = summarize(DynamicBatchScheduler(CLOCK).run(reqs), warmup_frac=0.1)
    sim = simulate_dynamic_batching(0.1, uni, LATB,
                                    num_requests=30_000, seed=11)
    assert abs(s["mean_wait"] - sim["mean_wait"]) / sim["mean_wait"] < 0.02


def test_fcfs_scheduler_matches_mg1_sim():
    ln = LogNormalTokens(6.0, 0.5, support=4096)
    reqs = make_request_stream(30_000, lam=0.05, dist=ln, vocab=100, seed=3)
    s = summarize(FCFSScheduler(CLOCK, n_max=800).run(reqs), warmup_frac=0.1)
    sim = simulate_mg1(0.05, ln, LAT1, n_max=800,
                       num_requests=30_000, seed=3)
    assert abs(s["mean_wait"] - sim["mean_wait"]) / max(sim["mean_wait"], 0.1) < 0.25


def test_policy_ordering_elastic_continuous():
    """elastic <= dynamic; continuous crushes queueing delay."""
    uni = UniformTokens(1000)
    reqs = make_request_stream(20_000, lam=0.3, dist=uni, vocab=100, seed=7)
    w_dyn = summarize(DynamicBatchScheduler(CLOCK).run(reqs))["mean_wait"]
    w_ela = summarize(ElasticBatchScheduler(CLOCK).run(reqs))["mean_wait"]
    w_con = summarize(ContinuousBatchScheduler(CLOCK, slots=64).run(reqs))["mean_wait"]
    assert w_ela <= w_dyn * 1.02
    assert w_con < w_ela


def test_controller_recommends_clip_and_policy():
    ctrl = AdaptiveController(LAT1, LATB, theta=119 / 120,
                              elastic_available=True, min_samples=64)
    rng = np.random.default_rng(0)
    ln = LogNormalTokens(7.0, 0.7)
    t = 0.0
    for n in ln.sample(rng, 512):
        t += rng.exponential(40.0)
        ctrl.observe_arrival(t)
        ctrl.observe_completion(int(n))
    rec = ctrl.recommendation(force=True)
    assert rec.policy == "elastic"
    assert rec.heavy_tailed
    assert 800 <= rec.n_max <= 3200         # paper-range optimum
    assert rec.b_max is None or rec.b_max >= 1


def test_controller_warmup_passthrough():
    ctrl = AdaptiveController(LAT1, LATB, min_samples=64)
    rec = ctrl.recommendation()
    assert rec.n_max is None and rec.details["reason"] == "warmup"


def test_calibration_fits():
    n = np.array([32, 64, 128, 256, 512])
    t = 0.02 * n + 0.6 + np.random.default_rng(0).normal(0, 1e-3, 5)
    lat = fit_latency_model(n, t)
    assert abs(lat.a - 0.02) < 1e-3 and abs(lat.c - 0.6) < 0.05
    assert linear_fit_r2(n, t) > 0.999
    bs = np.array([1, 2, 4, 8, 1, 2, 4, 8], np.float64)
    ls = np.array([100, 100, 100, 100, 300, 300, 300, 300], np.float64)
    tt = 0.03 * bs + 0.4 + (2e-4 * bs + 0.01) * ls
    blat = fit_batch_latency_model(bs, ls, tt)
    assert abs(blat.k3 - 2e-4) < 5e-5
    assert abs(blat.k4 - 0.01) < 2e-3
