"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_reference, flash_attention
from repro.kernels.ragged_decode_attention import (
    decode_attention_reference, ragged_decode_attention)
from repro.kernels.rmsnorm import fused_rmsnorm, rmsnorm_reference

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,win", [
    (2, 256, 4, 4, 64, None),
    (1, 512, 8, 2, 128, None),
    (2, 256, 4, 2, 128, 128),
    (1, 128, 2, 1, 256, None),
    (1, 384, 6, 3, 64, 96),
])
def test_flash_attention_sweep(b, s, hq, hkv, d, win, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, window=win, block_q=64, block_kv=64)
    ref = attention_reference(q, k, v, window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (4, 512, 8, 2, 64),
    (2, 256, 4, 4, 128),
    (3, 1024, 16, 8, 128),
    (1, 128, 2, 2, 256),
])
def test_ragged_decode_sweep(b, s, hq, hkv, d, dtype):
    ks = jax.random.split(RNG, 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s)
    out = ragged_decode_attention(q, kc, vc, lens, block_kv=128)
    ref = decode_attention_reference(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_ragged_agrees_with_dense_model_path(hq, hkv):
    """The ragged kernel vs the model's DENSE decode attention
    (``layers.decode_attention``, the ``decode_attention_impl="dense"``
    branch) — the two implementations the ModelConfig default switches
    between must agree in fp32 across GQA group shapes (incl. MQA) and
    ragged per-slot lengths."""
    from repro.models.layers import decode_attention
    b, s, d = 4, 256, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    lens = jnp.array([1, 64, 200, s], jnp.int32)
    ragged = ragged_decode_attention(q, kc, vc, lens, block_kv=64)
    dense = decode_attention(q[:, None], kc, vc, lens, window=None)[:, 0]
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_impl_auto_resolution():
    """ModelConfig defaults to impl="auto": ragged on TPU, dense
    elsewhere; explicit settings pass through untouched."""
    import dataclasses
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2.5-3b")
    assert cfg.decode_attention_impl == "auto"
    expected = "ragged" if jax.default_backend() == "tpu" else "dense"
    assert cfg.resolved_decode_attention_impl == expected
    for forced in ("ragged", "dense"):
        c = dataclasses.replace(cfg, decode_attention_impl=forced)
        assert c.resolved_decode_attention_impl == forced


def test_default_interpret_tracks_backend():
    """kernels.default_interpret centralizes the interpret-mode default:
    compiled on TPU, interpret everywhere else; explicit flags win."""
    from repro.kernels import default_interpret, resolve_interpret
    assert default_interpret() == (jax.default_backend() != "tpu")
    assert resolve_interpret(None) == default_interpret()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_ragged_decode_ignores_stale_cache():
    """Entries beyond lengths must not affect the output (elastic batching:
    a freed slot can hold garbage)."""
    b, s, h, d = 2, 256, 4, 64
    ks = jax.random.split(RNG, 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    lens = jnp.array([64, 192], jnp.int32)
    out1 = ragged_decode_attention(q, kc, vc, lens, block_kv=64)
    kc2 = kc.at[0, 64:].set(1e4)
    vc2 = vc.at[0, 64:].set(-1e4)
    out2 = ragged_decode_attention(q, kc2, vc2, lens, block_kv=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 64, 256), (1, 128, 512), (4, 32, 128)])
def test_fused_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(RNG, 3)
    x = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    r = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    w = jax.random.normal(ks[2], (shape[-1],), jnp.float32) * 0.1
    res, nrm = fused_rmsnorm(x, r, w, block_rows=32)
    res_ref, nrm_ref = rmsnorm_reference(x, r, w)
    np.testing.assert_allclose(
        np.asarray(res, np.float32), np.asarray(res_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(
        np.asarray(nrm, np.float32), np.asarray(nrm_ref, np.float32), **_tol(dtype))
