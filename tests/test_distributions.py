"""Token-distribution properties (paper Eqs 2-3, 23), incl. hypothesis
property tests on the clipping/order-statistic invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.distributions import (
    DeterministicTokens, EmpiricalTokens, GeometricTokens, LogNormalTokens,
    TruncGaussianTokens, UniformTokens)


def test_lognormal_moments():
    d = LogNormalTokens(7.0, 0.7)
    # E[N] = exp(mu + sigma^2/2)
    assert abs(d.mean() - np.exp(7 + 0.7 ** 2 / 2)) / d.mean() < 0.01


def test_clipped_moments_match_bruteforce():
    d = LogNormalTokens(6.0, 0.5, support=4096)
    for n_max in (100, 500, 2000):
        m1, m2 = d.clipped_moments(n_max)
        clipped = np.minimum(d.support, n_max)
        b1 = (clipped * d.pmf).sum()
        b2 = (clipped.astype(float) ** 2 * d.pmf).sum()
        assert abs(m1 - b1) < 1e-6 * max(b1, 1)
        assert abs(m2 - b2) < 1e-6 * max(b2, 1)


def test_clip_distribution_consistent_with_moments():
    d = TruncGaussianTokens(800, 200)
    c = d.clip(900)
    m1, m2 = d.clipped_moments(900)
    assert abs(c.mean() - m1) < 1e-6 * m1
    assert abs(c.second_moment() - m2) < 1e-6 * m2


def test_max_order_stat_uniform_closed_form():
    m = 1000
    d = UniformTokens(m)
    for b in (1, 2, 8, 32):
        # E[max of b uniforms on 0..m] ~ m*b/(b+1)  (paper SIV-B1)
        el = d.max_order_stat_mean(b)
        assert abs(el - m * b / (b + 1)) < 2.0


def test_max_order_stat_monte_carlo():
    d = LogNormalTokens(5.0, 0.6, support=2048)
    rng = np.random.default_rng(0)
    for b in (4, 16):
        samples = d.sample(rng, (20000, b)).max(axis=1)
        el = d.max_order_stat_mean(b)
        assert abs(el - samples.mean()) / el < 0.03


@settings(max_examples=25, deadline=None)
@given(n_max=st.integers(min_value=1, max_value=4000),
       mu=st.floats(min_value=4.0, max_value=7.0),
       sigma=st.floats(min_value=0.2, max_value=1.0))
def test_clipping_reduces_moments(n_max, mu, sigma):
    d = LogNormalTokens(mu, sigma, support=8192)
    m1, m2 = d.clipped_moments(n_max)
    assert m1 <= d.mean() + 1e-9
    assert m2 <= d.second_moment() + 1e-9
    assert m1 <= n_max and m2 <= n_max ** 2


@settings(max_examples=20, deadline=None)
@given(b1=st.integers(min_value=1, max_value=30),
       b2=st.integers(min_value=31, max_value=200))
def test_order_stat_monotone_in_batch(b1, b2):
    d = TruncGaussianTokens(500, 150)
    assert d.max_order_stat_mean(b1) <= d.max_order_stat_mean(b2) + 1e-9


@settings(max_examples=20, deadline=None)
@given(n_max=st.integers(min_value=1, max_value=3000))
def test_utility_bounds_and_monotone(n_max):
    d = LogNormalTokens(6.5, 0.7, support=8192)
    u = d.utility_after_clip(n_max)
    assert 0.0 <= u <= 1.0
    assert d.utility_after_clip(n_max + 200) >= u - 1e-9


def test_empirical_roundtrip():
    rng = np.random.default_rng(1)
    src = LogNormalTokens(5.5, 0.5, support=2048)
    samples = src.sample(rng, 50_000)
    emp = EmpiricalTokens(samples)
    assert abs(emp.mean() - src.mean()) / src.mean() < 0.02


def test_deterministic_and_geometric():
    d = DeterministicTokens(100)
    assert d.mean() == 100 and d.var() < 1e-9
    g = GeometricTokens(50.0)
    assert abs(g.mean() - 50.0) / 50 < 0.02
