import os
import sys

import pytest

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS inside its own process; never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--runregret", action="store_true", default=False,
        help="run the multi-seed autoscale regret sweeps (slow; the CI "
             "autoscale job passes this, tier-1 does not)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runregret"):
        return
    skip = pytest.mark.skip(reason="needs --runregret")
    for item in items:
        if "regret" in item.keywords:
            item.add_marker(skip)
