"""Training substrate: optimizer numerics, grad accumulation equivalence,
loss-goes-down smoke, checkpoint round-trip + fault-tolerance semantics,
gradient compression."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models.model import param_specs
from repro.models.params import init_params
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import (
    compress_tree, decompress_tree, dequantize_int8, quantize_int8)
from repro.training.optimizer import (
    AdamWConfig, adamw_init, adamw_update, global_norm)
from repro.training.train_step import TrainConfig, make_train_step

RNG = jax.random.PRNGKey(0)


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10 ** 9,
                      min_lr_frac=1.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st = adamw_init(p, cfg)
    p2, st2, _ = adamw_update(p, g, st, cfg)
    # numpy AdamW step 1
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    ref = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)


def test_grad_clip_and_norm():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    assert abs(float(global_norm(g)) - 200.0) < 1e-3
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0)
    _, _, metrics = adamw_update(p, g, adamw_init(p, cfg), cfg)
    assert float(metrics["grad_norm"]) > 100


def test_microbatch_equivalence():
    """num_microbatches=4 must produce (nearly) the same update as m=1."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    ds = SyntheticLMDataset(cfg, seq_len=32, global_batch=8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    outs = {}
    for m in (1, 4):
        c = dataclasses.replace(cfg, num_microbatches=m)
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=0))
        step = make_train_step(c, tcfg)
        opt = adamw_init(params, tcfg.adamw)
        p2, _, metrics = jax.jit(step)(params, opt, batch)
        outs[m] = (p2, float(metrics["loss"]))
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])))
    assert d < 5e-5, d
    assert abs(outs[1][1] - outs[4][1]) < 5e-4


def test_loss_decreases_on_learnable_data():
    cfg = get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, num_layers=2)
    params = init_params(param_specs(cfg), RNG, jnp.float32)
    # lr calibration: early grad norms are ~5 so grad_clip=1.0 scales the
    # update by ~1/5, and total_steps must match the 30 steps actually run
    # or the cosine tail cuts lr ~40% mid-smoke — lr=3e-3/total=60 only
    # dropped ~0.48 nats; lr=1e-2/total=30 drops ~1.4 across init seeds
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2, warmup_steps=2,
                                         total_steps=30))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = adamw_init(params, tcfg.adamw)
    ds = SyntheticLMDataset(cfg, seq_len=64, global_batch=8, seed=1)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


# ----------------------------------------------------------------------------
# Checkpointing / fault tolerance
# ----------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_write=False)
    state = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,))}}
    mgr.save(7, state, extra={"data_index": 123})
    restored, step, extra = mgr.restore(state)
    assert step == 7 and extra["data_index"] == 123
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_keep_last_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_write=False)
    state = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((2,), float(s))})
    assert mgr.list_steps() == [3, 4]
    restored, step, _ = mgr.restore(state)
    assert step == 4 and float(restored["w"][0]) == 4.0


def test_incomplete_checkpoint_never_latest(tmp_path):
    """Crash-mid-write must not corrupt restore (manifest commits last)."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3, async_write=False)
    state = {"w": jnp.ones((2,))}
    mgr.save(1, state)
    # simulate a torn write: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    np.save(tmp_path / "step_00000002" / "leaf_0.npy", np.zeros(2))
    assert mgr.latest_step() == 1
    _, step, _ = mgr.restore(state)
    assert step == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(5, {"w": jnp.ones((1000, 100))})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restart_resumes_data_position(tmp_path):
    """Exactly-once sample semantics across restart."""
    cfg = get_smoke_config("internlm2-1.8b")
    ds = SyntheticLMDataset(cfg, 16, 4, seed=3)
    b0, b1 = ds.batch(10), ds.batch(11)
    ds2 = SyntheticLMDataset(cfg, 16, 4, seed=3)
    np.testing.assert_array_equal(ds2.batch(10)["tokens"], b0["tokens"])
    np.testing.assert_array_equal(ds2.batch(11)["tokens"], b1["tokens"])


# ----------------------------------------------------------------------------
# Gradient compression
# ----------------------------------------------------------------------------

def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, (1000,)), jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert err.max() <= float(np.abs(x).max()) / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the running sum of dequantized grads tracks the
    true running sum (unbiased to first order)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.normal(0, 1, (513,)), jnp.float32)
              for _ in range(20)]
    errors = None
    acc_q = np.zeros(513)
    acc_t = np.zeros(513)
    for g in g_true:
        (qs, scales, errors) = compress_tree({"g": g},
                                             errors if errors else None)
        deq = decompress_tree(qs, scales, {"g": g})["g"]
        acc_q += np.asarray(deq)
        acc_t += np.asarray(g)
    # residual carried forward is bounded by one quantization step
    assert np.abs(acc_q - acc_t).max() < 0.1
