"""End-to-end behaviour tests for the paper's system: workload in ->
analytics-steered serving out, plus the distributed/dry-run machinery in a
subprocess with fake devices."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_end_to_end_policy_pipeline():
    """Workload -> controller -> scheduler: the recommended configuration
    must not be worse than the unconfigured default on the same stream."""
    from repro.core.control import AdaptiveController
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import (
        BatchLatencyModel, PAPER_A100_LLAMA2_7B)
    from repro.data.pipeline import make_request_stream
    from repro.serving.metrics import summarize
    from repro.serving.scheduler import (
        DynamicBatchScheduler, ElasticBatchScheduler, ModelClock)

    dist = LogNormalTokens(7.0, 0.7)
    blat = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-4, k4=0.002)
    clock = ModelClock(PAPER_A100_LLAMA2_7B, blat)
    reqs = make_request_stream(30_000, lam=0.5, dist=dist, vocab=100, seed=0)

    ctrl = AdaptiveController(PAPER_A100_LLAMA2_7B, blat, theta=119 / 120,
                              elastic_available=True, min_samples=64)
    for r in reqs[:512]:
        ctrl.observe_arrival(r.arrival)
        ctrl.observe_completion(r.target_output_tokens)
    rec = ctrl.recommendation(force=True)
    assert rec.policy == "elastic" and rec.n_max is not None

    base = summarize(DynamicBatchScheduler(clock).run(reqs))
    tuned = summarize(ElasticBatchScheduler(
        clock, n_max=rec.n_max, b_max=rec.b_max).run(reqs))
    # controller-tuned serving strictly reduces e2e latency and queue wait
    assert tuned["mean_e2e"] < base["mean_e2e"]
    assert tuned["mean_wait"] <= base["mean_wait"] * 1.05


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_small_mesh():
    """Lower + compile + RUN a sharded train step on an 8-device fake mesh;
    loss must match the single-device value (GSPMD correctness)."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.model import param_specs
from repro.models.params import init_params
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step
from repro.distributed.sharding import ShardCtx, DEFAULT_RULES
from repro.data.pipeline import SyntheticLMDataset

cfg = get_smoke_config("internlm2-1.8b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=0))
params = init_params(param_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
opt = adamw_init(params, tcfg.adamw)
ds = SyntheticLMDataset(cfg, 32, 8, seed=0)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

ref_step = jax.jit(make_train_step(cfg, tcfg))
_, _, ref_metrics = ref_step(params, opt, batch)

ctx = ShardCtx(mesh=mesh, rules=dict(DEFAULT_RULES))
step = make_train_step(cfg, tcfg, ctx)
with mesh:
    batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    p2, o2, metrics = jax.jit(step)(params, opt, batch_sh)
err = abs(float(metrics["loss"]) - float(ref_metrics["loss"]))
assert err < 5e-4, err
print("OK", float(metrics["loss"]))
"""
    assert "OK" in _run_sub(code)


def test_checkpoint_reshard_restore():
    """Save on a (2,4) mesh, restore onto (4,2) — elastic scaling path."""
    code = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.checkpoint import CheckpointManager

d = tempfile.mkdtemp()
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                             NamedSharding(mesh1, P("data", "model")))}
mgr = CheckpointManager(d, async_write=False)
mgr.save(3, state)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))
tgt_shard = NamedSharding(mesh2, P("model", "data"))
restored, step, _ = mgr.restore(state, shardings={"w": tgt_shard})
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
print("OK")
"""
    assert "OK" in _run_sub(code)


def test_degraded_mesh_lowering():
    """The same serve step lowers + compiles on a degraded (1,8) mesh —
    lose-half-the-hosts elasticity at dry-run fidelity.

    (Root cause of the former seed failure: the lowering always succeeded,
    but ``compiled.cost_analysis()`` returns a LIST of per-partition dicts
    on newer jax — the old ``["flops"]`` indexing raised TypeError.  Same
    API drift test_hlo_cost.py normalizes via _xla_flops.)"""
    code = """
import jax
from repro.configs import get_config
from repro.launch.specs import build_cell

cfg = get_config("qwen2.5-3b")
mesh = jax.make_mesh((1, 8), ("data", "model"))
cell = build_cell(cfg, "decode_32k", mesh)
with mesh:
    compiled = jax.jit(cell.step_fn,
                       donate_argnums=cell.donate).lower(*cell.args).compile()
ca = compiled.cost_analysis()
flops = (float(ca["flops"]) if isinstance(ca, dict)
         else float(sum(d.get("flops", 0.0) for d in ca)))
print("OK", flops > 0)
"""
    out = _run_sub(code)
    assert "OK True" in out


def test_dryrun_artifacts_complete():
    """All 40 (arch x shape) cells x both meshes are present and ok/skipped
    (the sweep is run by scripts/run_dryruns.sh; this asserts its outcome)."""
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(out_dir):
        pytest.skip("dry-run artifacts not generated in this checkout")
    from repro.configs import ARCH_IDS, SHAPE_IDS
    missing, bad = [], []
    for arch in ARCH_IDS:
        for shape in SHAPE_IDS:
            for mesh in ("single", "multi"):
                p = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, mesh))
                    continue
                rec = json.load(open(p))
                if rec["status"] not in ("ok", "skipped_by_design"):
                    bad.append((arch, shape, mesh, rec["status"]))
    assert not missing, missing
    assert not bad, bad
