"""PR 8 conformance harness, traffic half: modulated arrival processes.

Pins the three load-bearing invariants of ``repro.core.traffic``:

1. **Stationary conformance** — every registered model at zero
   modulation (and ``traffic=None``) reproduces the historical PR 5/6/7
   trajectories BIT-exactly at every layer: ``make_request_stream``,
   ``simulate_policy`` (oracle), ``simulate_policy_fast``,
   ``route_oracle`` and ``simulate_fleet_fast``.
2. **Cross-layer equality under modulation** — oracle and fastsim see
   the same warped arrivals, so their trajectories stay equal under
   every (traffic model x router x policy) cell.
3. **Stream isolation** — the traffic PRNG lane never perturbs the
   workload/predictor/fault streams: tokens are bit-equal between
   stationary and modulated runs, and the warp itself is deterministic
   in (model, seed).
"""

import numpy as np
import pytest

from repro.core.distributions import LogNormalTokens
from repro.core.fastsim import simulate_fleet_fast, simulate_policy_fast
from repro.core.fleet import route_oracle
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import DynamicPolicy, ElasticPolicy, FCFSPolicy
from repro.core.simulate import simulate_policy
from repro.core.traffic import (MMPPTraffic, SinusoidTraffic,
                                StationaryTraffic, TRAFFIC, TraceTraffic,
                                TrafficModel, _traffic_rng, default_traffic,
                                get_traffic, null_traffic, traffic_from_spec,
                                warp_workload)
from repro.data.pipeline import make_request_stream

LN = LogNormalTokens(5.0, 0.6)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
SINGLE = LatencyModel(a=0.0205, c=0.55)


# ---------------------------------------------------------------------------
# registry / spec round-trip
# ---------------------------------------------------------------------------

def test_registry_contents():
    for name in ("stationary", "sinusoid", "mmpp", "trace"):
        assert name in TRAFFIC
        tm = get_traffic(name)
        assert isinstance(tm, TrafficModel)
        assert tm.name == name


def test_traffic_from_spec_forms():
    assert isinstance(traffic_from_spec(None), StationaryTraffic)
    assert isinstance(traffic_from_spec("sinusoid"), SinusoidTraffic)
    tm = traffic_from_spec({"name": "sinusoid", "amplitude": 0.25,
                            "period": 100.0})
    assert tm.amplitude == 0.25 and tm.period == 100.0
    inst = MMPPTraffic()
    assert traffic_from_spec(inst) is inst
    with pytest.raises(KeyError):
        traffic_from_spec("no_such_model")


def test_default_and_null_sets_cover_registry():
    assert set(default_traffic()) == set(TRAFFIC)
    nulls = null_traffic()
    assert set(nulls) == set(TRAFFIC)
    for name, tm in nulls.items():
        assert tm.is_null, name


# ---------------------------------------------------------------------------
# 1: stationary conformance — bit-equality to the PR 5/6/7 paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRAFFIC))
def test_null_models_pin_make_request_stream(name):
    tm = null_traffic()[name]
    base = make_request_stream(200, lam=3.0, dist=LN, vocab=256, seed=7)
    mod = make_request_stream(200, lam=3.0, dist=LN, vocab=256, seed=7,
                              traffic=tm)
    for a, b in zip(base, mod):
        assert a.arrival == b.arrival
        assert a.target_output_tokens == b.target_output_tokens
        assert np.array_equal(a.prompt_tokens, b.prompt_tokens)


@pytest.mark.parametrize("name", sorted(TRAFFIC))
def test_null_models_pin_simulators(name):
    tm = null_traffic()[name]
    pol = DynamicPolicy(8)
    base_o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=3)
    null_o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=3,
                             traffic=tm)
    assert np.array_equal(base_o["waits"], null_o["waits"])
    base_f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400,
                                  seed=3)
    null_f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400,
                                  seed=3, traffic=tm)
    assert np.array_equal(base_f["waits"], null_f["waits"])


@pytest.mark.parametrize("name", sorted(TRAFFIC))
def test_null_models_pin_fleet(name):
    tm = null_traffic()[name]
    for router in ("least_work", "random"):
        base = simulate_fleet_fast(router, DynamicPolicy(8), 3.0, 2, LN,
                                   LAT, num_requests=400, seed=5)
        null = simulate_fleet_fast(router, DynamicPolicy(8), 3.0, 2, LN,
                                   LAT, num_requests=400, seed=5,
                                   traffic=tm)
        assert np.array_equal(base["replica_of"], null["replica_of"])
        assert base["mean_wait"] == null["mean_wait"]


def test_warp_workload_null_returns_same_object():
    pol = DynamicPolicy(8)
    wl = pol.sample_workload(2.0, LN, 300, seed=0)
    for tm in null_traffic().values():
        assert warp_workload(wl, tm, 0) is wl
    assert warp_workload(wl, None, 0) is wl


# ---------------------------------------------------------------------------
# 2: oracle == fastsim under every (traffic x router x policy) cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRAFFIC))
@pytest.mark.parametrize("pol", [FCFSPolicy(), DynamicPolicy(8),
                                 ElasticPolicy()],
                         ids=["fcfs", "dynamic", "elastic"])
def test_oracle_equals_fastsim_single(name, pol):
    tm = default_traffic()[name]
    o = simulate_policy(pol, 2.0, LN, LAT, num_requests=400, seed=11,
                        traffic=tm)
    f = simulate_policy_fast(pol, 2.0, LN, LAT, num_requests=400, seed=11,
                             traffic=tm)
    np.testing.assert_allclose(o["waits"], f["waits"], rtol=0, atol=1e-9)


@pytest.mark.parametrize("name", sorted(TRAFFIC))
@pytest.mark.parametrize("router", ["round_robin", "least_work", "random"])
def test_oracle_equals_fastsim_fleet(name, router):
    tm = default_traffic()[name]
    o = route_oracle(router, DynamicPolicy(8), 3.0, 2, LN, LAT,
                     num_requests=400, seed=13, traffic=tm)
    f = simulate_fleet_fast(router, DynamicPolicy(8), 3.0, 2, LN, LAT,
                            num_requests=400, seed=13, traffic=tm)
    assert np.array_equal(o["replica_of"], f["replica_of"])
    np.testing.assert_allclose(o["mean_wait"], f["mean_wait"], atol=1e-9)


# ---------------------------------------------------------------------------
# 3: warp correctness + stream isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRAFFIC))
def test_warp_inverts_cumulative(name):
    tm = default_traffic()[name]
    rng = np.random.default_rng(0)
    u = np.sort(rng.exponential(1.0, 500)).cumsum()
    a = tm.warp(u, seed=4)
    assert np.all(np.diff(a) > 0), "warp must preserve strict order"
    back = tm.cumulative(a, seed=4)
    np.testing.assert_allclose(back, u, rtol=1e-7, atol=1e-7)


@pytest.mark.parametrize("name", sorted(TRAFFIC))
def test_warp_deterministic_in_seed(name):
    tm = default_traffic()[name]
    u = np.cumsum(np.random.default_rng(1).exponential(0.5, 300))
    assert np.array_equal(tm.warp(u, seed=9), tm.warp(u, seed=9))


def test_modulation_never_touches_token_stream():
    base = make_request_stream(300, lam=3.0, dist=LN, vocab=256, seed=2)
    mod = make_request_stream(300, lam=3.0, dist=LN, vocab=256, seed=2,
                              traffic=SinusoidTraffic(amplitude=0.8,
                                                      period=40.0))
    arr_b = np.array([r.arrival for r in base])
    arr_m = np.array([r.arrival for r in mod])
    assert not np.array_equal(arr_b, arr_m), "modulation must move arrivals"
    for a, b in zip(base, mod):
        assert a.target_output_tokens == b.target_output_tokens
        assert np.array_equal(a.prompt_tokens, b.prompt_tokens)


def test_workload_tokens_survive_warp():
    pol = DynamicPolicy(8)
    wl = pol.sample_workload(2.0, LN, 300, seed=6)
    warped = warp_workload(wl, MMPPTraffic(rates=(0.25, 4.0)), 6)
    assert np.array_equal(wl.tokens, warped.tokens)
    assert not np.array_equal(wl.arrivals, warped.arrivals)
    np.testing.assert_allclose(np.cumsum(warped.inter), warped.arrivals)


def test_traffic_rng_is_salted_lane():
    # the traffic lane must be disjoint from the workload generator:
    # same seed, different streams
    a = _traffic_rng(0).random(8)
    b = np.random.default_rng(0).random(8)
    assert not np.array_equal(a, b)
    assert np.array_equal(_traffic_rng(3, 5).random(4),
                          _traffic_rng(3, 5).random(4))


def test_mean_rate_normalized_to_one():
    # long-run time-average of the multiplier is 1 for every model, so
    # modulation preserves the offered load lam
    t = np.linspace(0.0, 10_000.0, 200_001)
    for name, tm in default_traffic().items():
        m = tm.rate(t, seed=8)
        assert abs(float(np.mean(m)) - 1.0) < 0.05, (name, float(np.mean(m)))


def test_trace_period_mass_exact():
    tm = TraceTraffic(times=(0.0, 30.0, 70.0), rates=(1.0, 3.0, 0.5),
                      period=100.0)
    # normalized multipliers integrate to exactly one period per period
    assert abs(tm.cumulative(np.array([100.0]))[0] - 100.0) < 1e-9
    assert abs(tm.cumulative(np.array([300.0]))[0] - 300.0) < 1e-9


# ---------------------------------------------------------------------------
# property tests (hypothesis optional — the CI property job installs it;
# tier-1 skips only this section, never the conformance tests above)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container image ships without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), amp=st.floats(0.1, 0.95),
           period=st.floats(20.0, 500.0))
    def test_sinusoid_counts_match_integrated_rate(seed, amp, period):
        # N(T) is Poisson with mean lam * P(T): check within 5 sigma
        lam, n = 4.0, 2_000
        tm = SinusoidTraffic(amplitude=amp, period=period)
        rng = np.random.default_rng(seed)
        u = np.cumsum(rng.exponential(1.0 / lam, n))
        a = tm.warp(u, seed=seed)
        T = float(a[-1])
        mean = lam * float(tm.cumulative(np.array([T]), seed=seed)[0])
        assert abs(n - mean) < 5.0 * np.sqrt(max(mean, 1.0)) + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_mmpp_counts_match_integrated_rate(seed):
        lam, n = 4.0, 2_000
        tm = MMPPTraffic(rates=(0.5, 2.5), mean_dwell=(80.0, 40.0))
        rng = np.random.default_rng(seed)
        u = np.cumsum(rng.exponential(1.0 / lam, n))
        a = tm.warp(u, seed=seed)
        T = float(a[-1])
        mean = lam * float(tm.cumulative(np.array([T]), seed=seed)[0])
        assert abs(n - mean) < 5.0 * np.sqrt(max(mean, 1.0)) + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), period=st.floats(10.0, 1000.0))
    def test_zero_amplitude_is_identity(seed, period):
        tm = SinusoidTraffic(amplitude=0.0, period=period)
        u = np.cumsum(np.random.default_rng(seed).exponential(1.0, 200))
        assert tm.is_null
        assert np.array_equal(tm.warp(u, seed=seed), u)
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI property job "
                             "installs it)")
    def test_property_suite_requires_hypothesis():
        pass
