"""Cross-layer agreement for the unified batching-policy core.

For EVERY policy in the registry (via ``default_policies``):
  * oracle vs fast simulator: trajectory equality on equal seeds (the two
    layers sample with the same rng call order, so waits must match to
    float rounding, not just statistically);
  * oracle vs analytics: mean-delay agreement at low/medium load, with the
    acceptance shaped by ``analytic_kind`` — 'exact' closed forms must
    match tightly, 'bound' must dominate without being vacuous, 'approx'
    within a loose band;
  * scheduler adapter vs oracle: same discipline driven through
    ``PolicyScheduler`` + ``ModelClock`` agrees statistically;
  * engine layer: ``run_engine_schedule`` executes a policy's batches on
    the real engine (multi-bin included).
"""

import dataclasses

import numpy as np
import pytest

from repro.core.distributions import UniformTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    REGISTRY, BatchPolicy, ContinuousPolicy, DynamicPolicy, ElasticPolicy,
    MultiBinPolicy, SRPTPolicy, WaitPolicy, default_policies, get_policy,
    policy_from_spec, single_from_batch)
from repro.core.simulate import simulate_policy
from repro.core.fastsim import simulate_policy_fast, sweep
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.scheduler import ModelClock, run_engine_schedule

UNI = UniformTokens(1000)
LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
# single-request law = H(1, n), so FCFS sees the same service law on the
# scheduler layer as the oracle/analytic layers derive from LAT
CLOCK = ModelClock(single_from_batch(LAT), LAT)

POLICIES = default_policies()
# (low, medium) arrival rates per policy, inside each stability region
# (FCFS serves one at a time: E[S] ~ 10.8s => lam < 0.093)
LAMS = {"fcfs": (0.03, 0.06)}
_DEFAULT_LAMS = (0.05, 0.2)


def _lams(name):
    return LAMS.get(name, _DEFAULT_LAMS)


def test_registry_covers_all_disciplines():
    assert {"fcfs", "dynamic", "elastic", "fixed", "multibin",
            "wait", "srpt", "continuous"} <= set(REGISTRY)
    assert set(REGISTRY) == {type(p).name for p in POLICIES.values()}


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_oracle_vs_fast_trajectory_equal(name):
    pol = POLICIES[name]
    n = 3_000 if isinstance(pol, ContinuousPolicy) else 30_000
    for lam in _lams(name):
        r = simulate_policy(pol, lam, UNI, LAT, num_requests=n, seed=7)
        f = simulate_policy_fast(pol, lam, UNI, LAT, num_requests=n, seed=7)
        np.testing.assert_allclose(f["waits"], r["waits"],
                                   rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_oracle_vs_analytic_mean_delay(name):
    pol = POLICIES[name]
    if pol.analytic_kind is None:
        ana = pol.analytic_delay(_lams(name)[0], UNI, LAT)
        assert ana is None
        pytest.skip(f"{name}: no analytic form (by design)")
    for lam in _lams(name):
        ana = pol.analytic_delay(lam, UNI, LAT)
        sim = simulate_policy_fast(pol, lam, UNI, LAT,
                                   num_requests=150_000, seed=11)
        mean = sim["mean_wait"]
        assert np.isfinite(ana)
        if pol.analytic_kind == "exact":
            assert abs(ana - mean) / max(mean, 1e-9) < 0.08, (lam, ana, mean)
        elif pol.analytic_kind == "bound":
            assert ana >= mean * 0.98, (lam, ana, mean)       # dominates
            assert ana <= max(mean * 4.0, 1.0), (lam, ana, mean)  # not vacuous
        else:  # 'approx'
            assert abs(ana - mean) / max(mean, 1e-9) < 0.35, (lam, ana, mean)


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_scheduler_adapter_matches_oracle(name):
    pol = POLICIES[name]
    lam = _lams(name)[1]
    n = 4_000 if isinstance(pol, ContinuousPolicy) else 30_000
    reqs = make_request_stream(n, lam=lam, dist=UNI, vocab=100, seed=11)
    s = summarize(pol.scheduler(CLOCK).run(reqs), warmup_frac=0.1)
    sim = simulate_policy(pol, lam, UNI, LAT, num_requests=n, seed=11)
    # independent arrival/token draws => statistical agreement only
    assert abs(s["mean_wait"] - sim["mean_wait"]) / \
        max(sim["mean_wait"], 0.1) < 0.15, (s["mean_wait"], sim["mean_wait"])


def test_sweep_covers_mixed_policy_kinds():
    grid = sweep({"dyn": DynamicPolicy(), "ela": ElasticPolicy(),
                  "fix": get_policy("fixed", b=4),
                  "mb": MultiBinPolicy(num_bins=4),
                  "wait": WaitPolicy(k=4), "srpt": SRPTPolicy(b_max=8),
                  "legacy": {"kind": "dynamic", "b_max": 8}},
                 [0.1, 0.4], UNI, LAT, num_requests=20_000, seed=0)
    for name, waits in grid.items():
        assert waits.shape == (2,) and np.isfinite(waits).all(), name
        assert (waits >= 0).all()
    # elastic <= dynamic on the same seeds (paper §IV-D)
    assert (grid["ela"] <= grid["dyn"] * 1.02).all()


def test_fcfs_policy_exposes_token_limit_optimum():
    """policy_opt's closed form behind the policy surface (paper V1)."""
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import PAPER_A100_LLAMA2_7B
    from repro.core.policies import FCFSPolicy
    n = FCFSPolicy().optimize_n_max(1 / 40, LogNormalTokens(7.0, 0.7),
                                    PAPER_A100_LLAMA2_7B, theta=119 / 120)
    assert 1100 <= n <= 2200        # paper §V-B: n_max* ~ 1600


def test_policy_from_spec_legacy_kinds():
    assert isinstance(policy_from_spec({"kind": "elastic", "b_max": 4}),
                      ElasticPolicy)
    assert policy_from_spec({"kind": "fixed", "b": 8}).b == 8
    assert policy_from_spec({"kind": "multibin", "num_bins": 3}).num_bins == 3
    with pytest.raises(ValueError):
        policy_from_spec({"kind": "nope"})


def test_multibin_beats_padded_dynamic_heavy_tail_high_load():
    """The Guldogan et al. effect, end-to-end through the policy core:
    binning by output length rescues padded batching once max-token padding
    dominates (heavy-tail outputs, Fig-6b latency constants)."""
    from repro.core.distributions import LogNormalTokens
    ln = LogNormalTokens(7.0, 0.7)
    ht = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
    lam = 1.0
    dyn = simulate_policy_fast(DynamicPolicy(), lam, ln, ht,
                               num_requests=40_000, seed=15)["mean_wait"]
    ela = simulate_policy_fast(ElasticPolicy(), lam, ln, ht,
                               num_requests=40_000, seed=15)["mean_wait"]
    mb = simulate_policy_fast(MultiBinPolicy(num_bins=4), lam, ln, ht,
                              num_requests=40_000, seed=15)["mean_wait"]
    assert mb < 0.1 * dyn           # crushes padded dynamic batching
    assert ela <= mb                # paper: elastic is still optimal


@pytest.mark.parametrize("pol", [
    WaitPolicy(k=8, timeout=10.0),
    WaitPolicy(k=8, b_max=4),
    WaitPolicy(k=50, timeout=5.0, b_max=16),
    SRPTPolicy(b_max=3),
    SRPTPolicy(b_max=8, n_max=500),
], ids=repr)
def test_wait_srpt_variant_trajectories_equal(pol):
    """The timeout / b_max / n_max arms of the WAIT and SRPT kernels exist
    in both the oracle formation and the jitted kernel; pin them
    trajectory-equal (the default-instance suite above only covers the
    plain parameterizations)."""
    for lam in (0.05, 0.2):
        r = simulate_policy(pol, lam, UNI, LAT, num_requests=15_000, seed=7)
        f = simulate_policy_fast(pol, lam, UNI, LAT,
                                 num_requests=15_000, seed=7)
        np.testing.assert_allclose(f["waits"], r["waits"],
                                   rtol=1e-6, atol=1e-9)


def test_multibin_analytic_bound_dominates_simulation():
    """The two-arm envelope bound (bulk.multibin_bound): dominates the
    simulator across loads, with the singleton-padding arm active at low
    load and the clearing-round arm at high load."""
    from repro.core.bulk import multibin_bound
    pol = MultiBinPolicy(num_bins=4)
    assert pol.analytic_kind == "bound"
    edges = pol.bin_edges(UNI)
    for lam in (0.05, 0.1, 0.4, 0.8):
        sim = simulate_policy_fast(pol, lam, UNI, LAT,
                                   num_requests=120_000, seed=11)
        d = multibin_bound(UNI, LAT, lam, edges)
        assert d["stable"]
        assert np.isfinite(d["wait_bound"])
        assert d["wait_bound"] >= sim["mean_wait"] * 0.98, (lam, d, sim)
        assert d["wait_bound"] <= max(sim["mean_wait"] * 4.0, 1.0), (lam, d)
    # a batch cap breaks the serve-all-waiting envelope: no analytic form
    assert MultiBinPolicy(num_bins=4, b_max=8).analytic_kind is None
    assert MultiBinPolicy(num_bins=4, b_max=8).analytic_delay(
        0.2, UNI, LAT) is None


def test_srpt_analytic_bound_dominates_simulation():
    """The size-interval envelope (bulk.srpt_bound) closes the SRPT
    analytic debt: ``analytic_kind`` is 'bound', and the bound dominates
    the simulator across loads without being vacuous."""
    from repro.core.bulk import srpt_bound
    pol = SRPTPolicy(b_max=8)
    assert pol.analytic_kind == "bound"
    for lam in (0.05, 0.1, 0.2):
        sim = simulate_policy_fast(pol, lam, UNI, LAT,
                                   num_requests=120_000, seed=11)
        d = srpt_bound(UNI, LAT, lam, b_max=8)
        assert d["stable"]
        assert np.isfinite(d["wait_bound"])
        ana = pol.analytic_delay(lam, UNI, LAT)
        assert ana == pytest.approx(d["wait_bound"])
        assert d["wait_bound"] >= sim["mean_wait"] * 0.98, (lam, d, sim)
        assert d["wait_bound"] <= max(sim["mean_wait"] * 4.0, 1.0), (lam, d)
    # b_max=None serves everyone waiting: the size-interval split
    # degenerates to the one-class dynamic envelope
    from repro.core.bulk import dynamic_batching_bound
    d = srpt_bound(UNI, LAT, 0.1, b_max=None)
    assert d["wait_bound"] == pytest.approx(
        dynamic_batching_bound(UNI, LAT, 0.1)["wait_bound"])
    # a predictor-routed SRPT ranks on noisy lengths: no analytic form
    assert SRPTPolicy(
        b_max=8, predictor="lognormal_noise").analytic_kind is None


def test_wait_threshold_holds_and_amortizes():
    """WAIT (Dai et al. 2025): holding until k are buffered forms batches
    of >= k (up to end-of-stream stragglers), paying queueing delay at low
    load for amortized service."""
    lam = 0.05
    dyn = simulate_policy_fast(DynamicPolicy(), lam, UNI, LAT,
                               num_requests=20_000, seed=3)
    wait = simulate_policy_fast(WaitPolicy(k=8), lam, UNI, LAT,
                                num_requests=20_000, seed=3)
    assert wait["mean_batch"] >= 7.9          # ~every batch holds k=8
    assert wait["mean_wait"] > dyn["mean_wait"]   # holding is not free
    # the head of each batch waits at least until the k-th arrival: with
    # lam=0.05 that alone is (k-1)/(2*lam) ~ 70s on average
    assert wait["mean_wait"] > 30.0


def test_wait_timeout_caps_holding():
    """The timer arm of the WAIT trigger: a head request never holds the
    batch longer than ``timeout`` at low load."""
    lam = 0.05
    pure = simulate_policy_fast(WaitPolicy(k=50), lam, UNI, LAT,
                                num_requests=20_000, seed=5)
    timed = simulate_policy_fast(WaitPolicy(k=50, timeout=10.0), lam, UNI,
                                 LAT, num_requests=20_000, seed=5)
    assert timed["mean_wait"] < 0.2 * pure["mean_wait"]


def test_srpt_beats_fcfs_order_on_heavy_tail():
    """Shortest-predicted-first: under a heavy tail and a batch cap, the
    capped batch of SHORTEST waiting requests both de-queues short replies
    early and shrinks the padded max — mean delay drops vs FCFS-ordered
    dynamic batching with the same cap."""
    from repro.core.distributions import LogNormalTokens
    ln = LogNormalTokens(7.0, 0.7)
    ht = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
    lam, b = 0.6, 16
    dyn = simulate_policy_fast(DynamicPolicy(b_max=b), lam, ln, ht,
                               num_requests=40_000, seed=9)["mean_wait"]
    srpt = simulate_policy_fast(SRPTPolicy(b_max=b), lam, ln, ht,
                                num_requests=40_000, seed=9)["mean_wait"]
    assert srpt < dyn, (srpt, dyn)


@pytest.fixture(scope="module")
def engine():
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    return Engine(cfg, EngineConfig(max_batch=4, max_seq=128,
                                    prompt_bucket=16))


@pytest.mark.parametrize("policy", [
    DynamicPolicy(b_max=4),
    MultiBinPolicy(edges=(6.0,), b_max=4),
    ElasticPolicy(b_max=4),
    WaitPolicy(k=3, b_max=4),
    SRPTPolicy(b_max=4),
])
def test_engine_layer_runs_policy_batches(engine, policy):
    """Any batch-formation policy executes on the REAL engine: multi-bin
    works in the engine layer with no policy-specific engine code."""
    rng = np.random.default_rng(0)
    reqs = make_request_stream(8, lam=5.0, dist=UNI, vocab=50, seed=2)
    for r in reqs:                      # keep the smoke model's decode short
        r.target_output_tokens = int(rng.integers(2, 12))
    res = run_engine_schedule(policy, engine, reqs)
    assert np.isfinite(res.waits).all() and (res.waits >= 0).all()
    assert (res.e2e >= res.waits).all()
    assert sum(res.batch_sizes) == len(reqs)
    assert res.makespan > 0
