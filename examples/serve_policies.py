"""Scenario: compare every registered serving discipline at paper scale on
the virtual clock — one loop over the policy registry, no per-policy wiring.

  fcfs (M/G/1) | dynamic | dynamic+b_max | fixed b* | elastic | multibin |
  wait | srpt | continuous

Each policy comes from ``repro.core.policies`` (defined once, shared with
the oracle/fast simulators and the engine) and is bound to a ``ModelClock``
via ``policy.scheduler(clock)``.  Policies with a closed form also print
their analytic delay next to the scheduler measurement.

Run:  PYTHONPATH=src python examples/serve_policies.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.bulk import optimal_fixed_batch
from repro.core.distributions import LogNormalTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    ContinuousPolicy, DynamicPolicy, ElasticPolicy, FCFSPolicy, FixedPolicy,
    MultiBinPolicy, SRPTPolicy, WaitPolicy)
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.scheduler import ModelClock


def main():
    dist = LogNormalTokens(7.0, 0.7)
    single = LatencyModel(a=0.0212, c=1.79)
    batch = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-4, k4=0.002)
    clock = ModelClock(single, batch)
    lam = 0.5
    n_max = 1600                               # paper's V1 optimum
    reqs = make_request_stream(60_000, lam, dist, vocab=100, seed=0)

    fb = optimal_fixed_batch(dist.clip(n_max), batch, lam, b_max=48,
                             method="paper")
    b_star = fb["b_star"]

    policies = {
        "fcfs (M/G/1)": FCFSPolicy(n_max=n_max),
        "dynamic (unbounded)": DynamicPolicy(n_max=n_max),
        f"dynamic b_max={b_star}": DynamicPolicy(n_max=n_max, b_max=b_star),
        f"fixed b={b_star}": FixedPolicy(b=b_star, n_max=n_max),
        "elastic": ElasticPolicy(n_max=n_max),
        "multibin (4 bins)": MultiBinPolicy(num_bins=4, n_max=n_max),
        f"wait k={b_star} (Dai et al.)": WaitPolicy(k=b_star, n_max=n_max),
        f"srpt b_max={b_star}": SRPTPolicy(b_max=b_star, n_max=n_max),
        "continuous (beyond paper)": ContinuousPolicy(slots=64, n_max=n_max),
    }
    print(f"lam={lam} req/s, lognormal(7,0.7) clipped at n_max={n_max}, "
          f"b*={b_star}\n")
    print(f"{'policy':28s} {'mean wait':>10s} {'p95 wait':>10s} "
          f"{'mean E2E':>10s} {'analytic':>10s}")
    for name, pol in policies.items():
        s = summarize(pol.scheduler(clock).run(reqs))
        ana = pol.analytic_delay(lam, dist, batch)
        ana_s = f"{ana:10.2f}" if ana is not None and np.isfinite(ana) \
            else f"{'-':>10s}"
        print(f"{name:28s} {s['mean_wait']:10.2f} {s['p95_wait']:10.2f} "
              f"{s['mean_e2e']:10.2f} {ana_s}")

    print("\npaper's conclusions visible above: elastic <= dynamic for any "
          "distribution;\nmulti-bin batching narrows the padding gap without "
          "early exits; continuous\nbatching (iteration-level) goes further; "
          "FCFS without batching saturates first.")


if __name__ == "__main__":
    main()
