"""Scenario: compare all five serving disciplines at paper scale on the
virtual clock, then validate the ordering on the real engine.

  FCFS (M/G/1)  |  dynamic  |  dynamic+b_max  |  elastic  |  continuous

Run:  PYTHONPATH=src python examples/serve_policies.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.bulk import optimal_fixed_batch
from repro.core.distributions import LogNormalTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.data.pipeline import make_request_stream
from repro.serving.metrics import summarize
from repro.serving.scheduler import (
    ContinuousBatchScheduler, DynamicBatchScheduler, ElasticBatchScheduler,
    FCFSScheduler, ModelClock)


def main():
    dist = LogNormalTokens(7.0, 0.7)
    single = LatencyModel(a=0.0212, c=1.79)
    batch = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-4, k4=0.002)
    clock = ModelClock(single, batch)
    lam = 0.5
    n_max = 1600                               # paper's V1 optimum
    reqs = make_request_stream(60_000, lam, dist, vocab=100, seed=0)

    fb = optimal_fixed_batch(dist.clip(n_max), batch, lam, b_max=48,
                             method="paper")
    b_star = fb["b_star"]

    policies = {
        "FCFS (M/G/1)": FCFSScheduler(clock, n_max=n_max),
        "dynamic (unbounded)": DynamicBatchScheduler(clock, n_max=n_max),
        f"dynamic b_max={b_star}": DynamicBatchScheduler(
            clock, n_max=n_max, b_max=b_star),
        "elastic": ElasticBatchScheduler(clock, n_max=n_max),
        "continuous (beyond paper)": ContinuousBatchScheduler(
            clock, slots=64, n_max=n_max),
    }
    print(f"lam={lam} req/s, lognormal(7,0.7) clipped at n_max={n_max}, "
          f"b*={b_star}\n")
    print(f"{'policy':28s} {'mean wait':>10s} {'p95 wait':>10s} "
          f"{'mean E2E':>10s}")
    for name, sch in policies.items():
        s = summarize(sch.run(reqs))
        print(f"{name:28s} {s['mean_wait']:10.2f} {s['p95_wait']:10.2f} "
              f"{s['mean_e2e']:10.2f}")

    print("\npaper's conclusions visible above: elastic <= dynamic for any "
          "distribution;\ncontinuous batching (iteration-level) goes further; "
          "FCFS without batching saturates first.")


if __name__ == "__main__":
    main()
