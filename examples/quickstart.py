"""Quickstart: the paper's pipeline in one page.

1. Pick an output-token distribution (heavy-tailed lognormal, the paper's
   running example) and the A100-scale latency constants.
2. Analyze FCFS serving with the M/G/1 model; find the optimal max-token
   limit (Eqs 1-5, 10-13).
3. Compare batching policies analytically and by event simulation (Eqs 14-26).
4. Run a REAL tiny model on the batched engine and watch elastic batching
   return short replies early.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.distributions import LogNormalTokens
from repro.core.latency_model import (
    PAPER_A100_LLAMA2_7B, BatchLatencyModel)
from repro.core.mg1 import mg1_wait
from repro.core.policy_opt import optimize_token_limit_v1
from repro.core.simulate import simulate_dynamic_batching, simulate_mg1


def main():
    dist = LogNormalTokens(7.0, 0.7)          # paper §V: log mean 7, std 0.7
    lat = PAPER_A100_LLAMA2_7B                # S = 0.0212 * n + 1.79 seconds
    lam = 1 / 40                              # arrivals per second

    print("== 1. M/G/1 with max-token clipping (paper Eqs 1-5)")
    for n_max in (1000, 1600, 3000):
        r = mg1_wait(dist, lat, lam, n_max)
        sim = simulate_mg1(lam, dist, lat, n_max=n_max,
                           num_requests=100_000)["mean_wait"]
        print(f"   n_max={n_max:5d}: rho={r.rho:.2f}  E[W]={r.wait:6.2f}s "
              f"(simulated {sim:6.2f}s)")

    print("== 2. optimal max-token limit (paper Eq 10, theta=119/120)")
    best = optimize_token_limit_v1(dist, lat, lam, theta=119 / 120,
                                   grid=np.arange(200, 4001, 50))
    print(f"   n_max* = {best.n_max}  E[W]={best.wait:.1f}s "
          f"utility={best.utility:.3f}   (paper: 1600, 23s)")

    print("== 3. batching policies (paper §IV)")
    blat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    for lam_b in (0.1, 0.4):
        d = simulate_dynamic_batching(lam_b, dist, blat, n_max=best.n_max,
                                      num_requests=60_000)
        e = simulate_dynamic_batching(lam_b, dist, blat, n_max=best.n_max,
                                      elastic=True, num_requests=60_000)
        print(f"   lam={lam_b}: dynamic E[W]={d['mean_wait']:7.2f}s   "
              f"elastic E[W]={e['mean_wait']:7.2f}s   "
              f"(elastic always <=, paper §IV-D)")

    print("== 4. real engine: elastic batching returns short replies early")
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, EngineConfig(max_batch=4, max_seq=128, prompt_bucket=16))
    prompts = [np.arange(6, dtype=np.int32) + i for i in range(3)]
    res = eng.generate(prompts, [24, 4, 10], elastic=True)
    for i, (tok, t) in enumerate(zip(res["produced"],
                                     res["completion_seconds"])):
        print(f"   request {i}: {tok} tokens, completed at {t*1e3:7.1f} ms")


if __name__ == "__main__":
    main()
