"""Scenario: the adaptive control plane reacting to a workload shift.

Phase 1: light-tailed outputs (truncated Gaussian) -> controller leaves the
         batch size unbounded (paper: larger batches only help).
Phase 2: the workload turns heavy-tailed (lognormal) -> controller clips at
         the V1-optimal n_max and caps the batch at b* (paper §IV-C),
         keeping elastic batching on (paper §IV-D).

Run:  PYTHONPATH=src python examples/adaptive_control.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.control import AdaptiveController
from repro.core.distributions import LogNormalTokens, TruncGaussianTokens
from repro.core.latency_model import BatchLatencyModel, LatencyModel


def main():
    ctrl = AdaptiveController(
        LatencyModel(a=0.0212, c=1.79),
        BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-4, k4=0.002),
        theta=119 / 120, elastic_available=True,
        window=512, min_samples=64, heavy_tail_scv=0.4)
    rng = np.random.default_rng(0)

    phases = [
        ("light-tailed: truncGauss(800, 40)", TruncGaussianTokens(800, 40)),
        ("heavy-tailed: lognormal(7, 0.7)", LogNormalTokens(7.0, 0.7)),
    ]
    t = 0.0
    for name, dist in phases:
        for n in dist.sample(rng, 512):
            t += rng.exponential(40.0)     # lam = 1/40 (paper's Fig 4 rate)
            ctrl.observe_arrival(t)
            ctrl.observe_completion(int(n))
        rec = ctrl.recommendation(force=True)
        print(f"\n== {name}")
        print(f"   heavy_tailed={rec.heavy_tailed}  policy={rec.policy}")
        print(f"   n_max={rec.n_max}  b_max={rec.b_max}")
        print(f"   scv={rec.details['scv']:.2f}  "
              f"expected wait={rec.details['expected_wait']:.1f}s")

    print("\nThe controller flips from unbounded batching to clip+cap when "
          "the tail appears —\nexactly the paper's §IV-C/§III-C prescription, "
          "computed live from its formulas.")


if __name__ == "__main__":
    main()
