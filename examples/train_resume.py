"""Scenario: fault-tolerant training end-to-end on a 100M-class model.

Trains a reduced config for a few hundred steps on synthetic LM data,
writing async checkpoints; a failure is injected mid-run and the supervisor
restores (exactly-once data semantics) and finishes. This is the CPU-scale
rehearsal of the cluster driver in repro.launch.train.

Run:  PYTHONPATH=src python examples/train_resume.py [--steps 120]
"""

import argparse
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()

    ckpt = "/tmp/repro_example_ckpt"
    subprocess.run(["rm", "-rf", ckpt], check=False)
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--global-batch", "8", "--seq-len", "64",
        "--ckpt-dir", ckpt, "--ckpt-every", "25",
        "--simulate-failure-at", str(args.steps // 2),
    ]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    print("running:", " ".join(cmd))
    r = subprocess.run(cmd, env=env, cwd=ROOT)
    raise SystemExit(r.returncode)


if __name__ == "__main__":
    main()
