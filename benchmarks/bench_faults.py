"""PR 6: fault-tolerant serving — delay under failures, hedging, shedding.

Three robustness questions:

1. **MTBF/MTTR grid**: mean wait vs availability on the fault-injected
   fleet (crash/repair, fast path) — the delay-vs-availability surface,
   with the ``bulk.breakdown_wait`` envelope for context.  Lower
   availability must cost delay; accounting must close on every cell.
2. **Hedging under stragglers**: serving-layer fleet with slowdown
   episodes, with and without hedged duplicate dispatch
   (``hedge_slo``) — hedges must fire, win sometimes, and never break
   exactly-once completion.
3. **Shed sweep**: admission shedding probability vs served-tail
   latency — load shedding buys tail latency with throughput, the
   graceful-degradation tradeoff the controller's ``shed_probability``
   recommendation walks.

Recorded as the ``pr6_faults`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — pr1..pr5 keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer


def main(quick: bool = False):
    from repro.core.bulk import breakdown_wait
    from repro.core.distributions import LogNormalTokens
    from repro.core.faults import CrashRepair, Slowdown, simulate_fleet_faulty
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import DynamicPolicy, single_from_batch
    from repro.data.pipeline import make_request_stream
    from repro.serving.router import FleetScheduler, summarize_fleet
    from repro.serving.scheduler import ModelClock

    ln = LogNormalTokens(7.0, 0.7)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    clock = ModelClock(single_from_batch(lat), lat)
    n_req = 2_000 if quick else 5_000
    lam, R, seed = 4.0, 3, 3

    derived = {}
    with timer() as t_all:
        # ------ 1: MTBF/MTTR grid (crash faults, fast fleet path) ------
        t0 = time.perf_counter()
        grid = []
        for mtbf, mttr in [(400.0, 5.0), (200.0, 10.0), (100.0, 15.0),
                           (60.0, 20.0)]:
            fault = CrashRepair(mtbf=mtbf, mttr=mttr)
            res = simulate_fleet_faulty(
                "least_work", DynamicPolicy(16), lam, R, ln, lat, fault,
                num_requests=n_req, seed=seed, fast=True)
            assert (res["n_served"] + res["shed"] + res["failed"]
                    + res["unserved"] == res["n_arrived"])
            env = breakdown_wait(ln, lat, lam, mtbf, mttr, R=R,
                                 policy=DynamicPolicy(16))
            grid.append({
                "mtbf": mtbf, "mttr": mttr,
                "availability": fault.capacity(),
                "mean_wait": float(res["mean_wait"]),
                "p99_wait": float(res["p99_wait"]),
                "retries": int(res["retries"]),
                "failed": int(res["failed"]),
                "envelope_wait": env["wait"]})
            derived[f"crash_a{fault.capacity():.3f}"] = grid[-1]["mean_wait"]
        t_grid = time.perf_counter() - t0
        # losing availability must cost delay across the grid extremes
        assert grid[-1]["mean_wait"] > grid[0]["mean_wait"], grid
        assert grid[-1]["retries"] > 0

        # ------ 2: hedging win under stragglers (serving layer) ------
        reqs = make_request_stream(min(n_req, 800), lam=8.0, dist=ln,
                                   vocab=512, seed=seed)
        strag = Slowdown(mtbf=40.0, duration=15.0, factor=4.0)
        plain = FleetScheduler("random", DynamicPolicy(16), clock, R,
                               faults=strag, seed=seed).run(reqs)
        hedged = FleetScheduler("random", DynamicPolicy(16), clock, R,
                                faults=strag, seed=seed,
                                hedge_slo=0.05).run(reqs)
        sp, sh = summarize_fleet(plain), summarize_fleet(hedged)
        assert sh["hedged"] > 0, "hedges must fire under stragglers"
        assert sh["served"] == len(reqs)       # exactly-once preserved
        derived["straggler_p99_plain"] = sp["p99_wait"]
        derived["straggler_p99_hedged"] = sh["p99_wait"]
        derived["hedged"] = sh["hedged"]
        derived["hedge_wins"] = sh["hedge_wins"]

        # ------ 3: shed sweep (graceful degradation) ------
        shed_rows = []
        for p in [0.0, 0.1, 0.25, 0.5]:
            res = FleetScheduler("jsq", DynamicPolicy(16), clock, R,
                                 faults=CrashRepair(mtbf=80.0, mttr=10.0),
                                 shed_prob=p, seed=seed).run(reqs)
            s = summarize_fleet(res)
            shed_rows.append({"shed_prob": p, "served": s["served"],
                              "shed": s["shed"],
                              "mean_wait": s["mean_wait_served"],
                              "p99_wait": s["p99_wait"]})
            derived[f"shed_p{p}"] = s["mean_wait_served"]
        # shedding trades throughput for latency: strictly fewer served,
        # and the heaviest shed level beats the unshedded tail
        served_seq = [r["served"] for r in shed_rows]
        assert served_seq[0] > served_seq[-1], served_seq
        assert (shed_rows[-1]["mean_wait"] <= shed_rows[0]["mean_wait"]
                * 1.05), shed_rows

    emit_bench("simulators", {
        "workload": f"lognormal(7,0.7) lam={lam} R={R} dynamic b16; "
                    f"{n_req} requests (grid), {min(n_req, 800)} serving",
        "crash_grid": grid,
        "straggler_hedging": {
            "plain": {k: sp[k] for k in ("mean_wait", "p95_wait",
                                         "p99_wait")},
            "hedged": {k: sh[k] for k in ("mean_wait", "p95_wait",
                                          "p99_wait")},
            "hedged_count": sh["hedged"], "hedge_wins": sh["hedge_wins"],
            "availability": sh["availability"]},
        "shed_sweep": shed_rows,
        "grid_s": t_grid,
    }, key="pr6_faults")
    emit("fault_tolerance", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
