"""Paper Fig 3: E[L] (max order statistic), H^[b] and mu^[b] vs batch size
for uniform / truncated-Gaussian / lognormal output-token distributions.

Validates the paper's central observation: light-tailed distributions give
monotonically increasing inference rate mu^[b]; heavy-tailed (lognormal)
gives an interior optimum batch size.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    from repro.core.distributions import (
        LogNormalTokens, TruncGaussianTokens, UniformTokens)
    from repro.core.latency_model import BatchLatencyModel

    # paper Fig 3b setup: uniform(0,2000), truncGauss(800,20), lognormal(7,0.7)
    dists = {
        "uniform_0_2000": UniformTokens(2000),
        "truncgauss_800_20": TruncGaussianTokens(800, 20),
        "lognormal_7_0.7": LogNormalTokens(7.0, 0.7),
    }
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-5, k4=0.002)
    bs = np.arange(1, 65)

    derived = {}
    with timer() as t_all:
        for name, d in dists.items():
            el = d.max_order_stat_mean(bs)
            mu = lat.service_rate(d, bs)
            bstar = int(bs[np.argmax(mu)])
            derived[f"{name}_EL_b1"] = float(np.atleast_1d(el)[0])
            derived[f"{name}_EL_b64"] = float(np.atleast_1d(el)[-1])
            derived[f"{name}_mu_argmax_b"] = bstar
            derived[f"{name}_mu_monotone"] = bool(
                np.all(np.diff(mu) > -1e-12))
        # truncated-Gaussian E[L] plateaus quickly (paper Fig 3a):
        tg = dists["truncgauss_800_20"]
        el = np.atleast_1d(tg.max_order_stat_mean(np.array([1, 8, 64])))
        derived["tg_plateau_ratio"] = float((el[2] - el[1]) / (el[1] - el[0]))

    emit("fig3_order_stats", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
