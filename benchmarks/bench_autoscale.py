"""PR 8: closed-loop autoscaling under non-stationary traffic.

Three control questions:

1. **Regret vs clairvoyant**: on a bursty sinusoidal workload
   (amplitude 0.9, period 2000 s at lam=8) the adaptive controller
   re-picks ``(replicas, router, shed_prob)`` every window from its own
   observed delay/backlog.  The benchmark compares its cost-aware
   objective (mean wait + replica-hours + shed penalty) against every
   static power-of-two ``(R, router)`` configuration AND against the
   clairvoyant per-window optimum.  Acceptance: adaptive strictly beats
   the best static config; regret = adaptive - clairvoyant is recorded.
2. **Traffic model sweep**: mean wait of a fixed fleet under every
   registered traffic model at matched long-run rate — burstiness must
   cost delay relative to stationary arrivals (the modulation analogue
   of the paper's variance penalty).
3. **Action trace**: the adaptive replica trajectory is recorded so
   regressions in controller behavior (e.g. stuck at max_replicas) are
   visible in the artifact, not just the scalar.

Recorded as the ``pr8_autoscale`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — pr1..pr7 keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer


def main(quick: bool = False):
    from repro.core.distributions import LogNormalTokens
    from repro.core.fastsim import run_controlled, simulate_fleet_fast
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import ElasticPolicy
    from repro.core.traffic import SinusoidTraffic, default_traffic

    dist = LogNormalTokens(5.0, 0.8)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    policy = ElasticPolicy()
    lam, seed, max_r = 8.0, 0, 8
    # quick mode shrinks horizon 4x; scale period/window with it so the
    # run still covers two full bursts with the same windows-per-period
    n_req, window, period = ((8_000, 50.0, 500.0) if quick
                             else (32_000, 200.0, 2000.0))
    traffic = SinusoidTraffic(amplitude=0.9, period=period)
    cost = dict(replica_cost=5.0, shed_cost=0.0)
    kw = dict(traffic=traffic, num_requests=n_req, seed=seed, window=window,
              max_replicas=max_r, **cost)

    derived = {}
    with timer() as t_all:
        # ------ 1: adaptive vs static grid vs clairvoyant ------
        t0 = time.perf_counter()
        adaptive = run_controlled(
            policy, lam, dist, lat,
            controller_kwargs={"replica_target_util": 0.4}, **kw)
        static_rows = []
        for R in (1, 2, 4, 8):
            for router in ("round_robin", "least_work"):
                res = run_controlled(policy, lam, dist, lat,
                                     fixed=(R, router), **kw)
                static_rows.append({"replicas": R, "router": router,
                                    "mean_wait": res.mean_wait,
                                    "objective": res.objective})
        best_static = min(static_rows, key=lambda r: r["objective"])
        clair = run_controlled(policy, lam, dist, lat, clairvoyant=True,
                               **kw)
        t_ctrl = time.perf_counter() - t0

        regret = adaptive.objective - clair.objective
        derived["adaptive_objective"] = adaptive.objective
        derived["best_static_objective"] = best_static["objective"]
        derived["clairvoyant_objective"] = clair.objective
        derived["regret"] = regret
        # acceptance: the time-sliced controller strictly beats the best
        # static (R, router) on this bursty workload.  The clairvoyant
        # picks each window's (R, router) with the realized arrivals in
        # hand but is myopic about backlog carried into later windows,
        # so regret is a benchmark, not a sign-definite bound — it only
        # has to be finite and small relative to the static gap.
        assert adaptive.objective < best_static["objective"], (
            adaptive.objective, best_static)
        assert np.isfinite(regret)
        assert abs(regret) < best_static["objective"], (regret, best_static)

        # ------ 2: traffic model sweep at matched mean rate ------
        sweep = []
        for name, tm in default_traffic().items():
            res = simulate_fleet_fast("least_work", policy, lam, 4, dist,
                                      lat, num_requests=min(n_req, 16_000),
                                      seed=seed, traffic=tm)
            sweep.append({"traffic": name,
                          "mean_wait": float(res["mean_wait"])})
            derived[f"wait_{name}"] = sweep[-1]["mean_wait"]
        by_name = {r["traffic"]: r["mean_wait"] for r in sweep}
        # burstiness costs delay vs stationary arrivals at equal rate
        # (sinusoid at amplitude 0.6 is burst-dominant at any seed; the
        # milder mmpp/trace defaults must at least visibly modulate)
        assert by_name["sinusoid"] > by_name["stationary"], by_name
        for name in ("mmpp", "trace"):
            assert by_name[name] != by_name["stationary"], by_name

    emit_bench("simulators", {
        "workload": f"lognormal(5,0.8) lam={lam} elastic; sinusoid "
                    f"amp=0.9 period={period}; {n_req} requests, "
                    f"window={window}, max_replicas={max_r}, "
                    f"replica_cost={cost['replica_cost']}",
        "adaptive": {"mean_wait": adaptive.mean_wait,
                     "avg_replicas": adaptive.avg_replicas,
                     "objective": adaptive.objective,
                     "shed": adaptive.shed},
        "static_grid": static_rows,
        "best_static": best_static,
        "clairvoyant": {"mean_wait": clair.mean_wait,
                        "avg_replicas": clair.avg_replicas,
                        "objective": clair.objective},
        "regret": regret,
        "replica_trace": [a.replicas for a in adaptive.actions],
        "traffic_sweep": sweep,
        "control_s": t_ctrl,
    }, key="pr8_autoscale")
    emit("autoscale_regret", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
