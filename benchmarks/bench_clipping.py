"""Paper Fig 4: max-token-limit clipping.

4a: E[W] vs n_max (lam=1/40), analytic M/G/1 (Eqs 1-5) vs event simulation.
4b/4c: with impatience (lam=1/25, tau=60): E[Wqs] and loss pi(tau) vs n_max,
De Kok-Tijms (Eqs 6-9) + exact level-crossing vs simulation.
4d: optimal n_max via V1 (theta=119/120) and V2 (theta=0.95, loss_cost=4) —
the paper reports n_max*=1600 (patient; E[W]~23s, -58.9% vs n_max=3000) and
n_max*=1300 (impatient; pi=0.12, -56.4% vs n_max=3000).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    from repro.core.distributions import LogNormalTokens
    from repro.core.impatience import exact_impatience
    from repro.core.latency_model import PAPER_A100_LLAMA2_7B as LAT
    from repro.core.mg1 import mg1_wait
    from repro.core.policy_opt import (
        optimize_token_limit_v1, optimize_token_limit_v2)
    from repro.core.fastsim import simulate_mg1_fast
    from repro.core.simulate import simulate_mg1

    ln = LogNormalTokens(7.0, 0.7)
    n_req = 120_000 if quick else 400_000
    grid = [800, 1300, 1600, 2200, 3000]

    derived = {}
    with timer() as t_all:
        # ---- Fig 4a: patient users
        lam = 1 / 40
        errs = []
        for n in grid:
            ana = mg1_wait(ln, LAT, lam, n).wait
            sim = simulate_mg1(lam, ln, LAT, n_max=n,
                               num_requests=n_req, seed=1)["mean_wait"]
            errs.append(abs(ana - sim) / max(sim, 1e-9))
            derived[f"fig4a_EW_n{n}"] = ana
        derived["fig4a_max_rel_err_vs_sim"] = float(max(errs))

        # ---- Fig 4b/4c: impatient users (lax.scan workload recursion;
        # one cell re-run on the NumPy oracle as a cross-check)
        lam2, tau = 1 / 25, 60.0
        oracle = simulate_mg1(lam2, ln, LAT, n_max=1300, tau=tau,
                              num_requests=min(n_req, 60_000), seed=2)
        check = simulate_mg1_fast(lam2, ln, LAT, n_max=1300, tau=tau,
                                  num_requests=min(n_req, 60_000), seed=2)
        assert abs(oracle["mean_wait"] - check["mean_wait"]) < 1e-6
        errs_pi, errs_w = [], []
        for n in (1300, 2000, 3000):
            ex = exact_impatience(ln, LAT, lam2, tau, n)
            sim = simulate_mg1_fast(lam2, ln, LAT, n_max=n, tau=tau,
                                    num_requests=n_req, seed=2)
            errs_pi.append(abs(ex.pi - sim["loss_frac"]))
            errs_w.append(abs(ex.wq_all - sim["mean_wait"]) /
                          max(sim["mean_wait"], 1e-9))
            derived[f"fig4c_pi_n{n}"] = ex.pi
        derived["fig4c_max_abs_pi_err"] = float(max(errs_pi))
        derived["fig4b_max_rel_wq_err"] = float(max(errs_w))

        # ---- Fig 4d: optimal tradeoff
        v1 = optimize_token_limit_v1(ln, LAT, lam, theta=119 / 120,
                                     grid=np.arange(200, 4001, 50))
        v2 = optimize_token_limit_v2(ln, LAT, lam2, theta=0.95, tau=tau,
                                     loss_cost=4.0,
                                     grid=np.arange(200, 4001, 100),
                                     solver="exact")
        w3000 = mg1_wait(ln, LAT, lam, 3000).wait
        pi3000 = exact_impatience(ln, LAT, lam2, tau, 3000).pi
        derived.update({
            "v1_nmax_star": v1.n_max,
            "v1_EW_at_star": v1.wait,
            "v1_EW_reduction_vs_3000": 1 - v1.wait / w3000,
            "v2_nmax_star": v2.n_max,
            "v2_loss_at_star": v2.loss_frac,
            "v2_loss_reduction_vs_3000": 1 - v2.loss_frac / pi3000,
            "paper_claims": "n*~1600 (23s, -58.9%); n*~1300 (pi 0.12, -56.4%)",
        })

    emit("fig4_clipping", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
