"""PR 10: prefill/decode tandem queue under a KV-memory budget.

Three memory questions (docs/memory.md):

1. **Budget sweep**: mean wait of the serve-all tandem
   (``DynamicPolicy(None)``) as the per-replica KV capacity M tightens,
   multi-seed, with the occupancy ledger (``kv_peak``, ``utilization``,
   ``blocked_batches``, ``deferred_requests``) and the analytic
   ``tandem_bound`` arms recorded per cell.  Acceptance: every finite
   budget costs latency over the null (infinite) budget, and the
   tightest budget costs more than the loosest (no strict monotonicity
   across intermediate budgets — fragmentation, docs/memory.md).
2. **Memory-aware control**: at the gated cell (λ=0.1, M=4000.25) the
   budget-blind recommendation is serve-all elastic — whose prefill
   stage races ahead of decode, fills the budget, and fragments
   admission into small poorly-amortized batches (~36 s).  The
   memory-aware controller sees the tandem bound's memory arm dominate
   its slack arm and throttles formation to a count-triggered ``fixed``
   batch sized so two batches in flight fit (b ≤ b(M)/2, ~8.4 s).
   Acceptance (ISSUE 10): the aware recommendation beats the blind one
   under the same budget, per seed.  Both recommendations are organic —
   the same observation stream is fed to both controllers and the
   deployed policies are built from the ``Recommendation`` fields.
3. **Null conformance timing**: a null budget (``None`` / ``inf``) must
   short-circuit to the exact pre-existing code path — bit-equal waits.

Recorded as the ``pr10_memory`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — pr1..pr9 keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer

LAM = 0.1
BUDGETS = (2000.25, 4000.25, 8000.25, None)
M_GATE = 4000.25


def _policy_from_rec(rec):
    """Deploy a controller Recommendation as a batching policy (the
    knobs the memory axis acts on: formation discipline + batch cap)."""
    from repro.core.policies import DynamicPolicy, ElasticPolicy, FixedPolicy
    if rec.policy == "fixed":
        return FixedPolicy(b=rec.b_max)
    if rec.policy == "elastic":
        return ElasticPolicy(b_max=rec.b_max)
    return DynamicPolicy(b_max=rec.b_max)


def _fed_controller(single, batch_lat, memory=None, n_obs=1500):
    """Feed a controller the gated cell's organic stream (Poisson(λ=0.1)
    arrivals, uniform 1..1000 output tokens) and return its forced
    recommendation.  theta=1.0 = utility-only token limit: capacity is
    the batching layer's job here, so the single-server M/G/1 clip
    (which would be load-bound at λ=0.1) is not exercised."""
    from repro.core.control import AdaptiveController
    ctrl = AdaptiveController(single, batch_lat, theta=1.0, memory=memory)
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(n_obs):
        t += rng.exponential(1.0 / LAM)
        ctrl.observe_arrival(t)
        ctrl.observe_completion(int(rng.integers(1, 1001)))
    return ctrl.recommendation(force=True)


def main(quick: bool = False):
    from repro.core.bulk import tandem_bound
    from repro.core.distributions import UniformTokens
    from repro.core.fastsim import simulate_policy_fast
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.core.memory import MemoryBudget
    from repro.core.policies import DynamicPolicy

    dist = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    single = LatencyModel(a=0.0212, c=1.79)
    pol = DynamicPolicy(None)          # serve-all formation, no count cap
    n_req, seeds = ((3000, (1, 2)) if quick else (20_000, (1, 2, 3)))

    derived = {}
    with timer() as t_all:
        # ------ 1: budget sweep on the serve-all tandem, multi-seed ------
        t0 = time.perf_counter()
        sweep = []
        for M in BUDGETS:
            waits, rows = [], []
            for seed in seeds:
                res = simulate_policy_fast(pol, LAM, dist, lat,
                                           num_requests=n_req, seed=seed,
                                           memory=M)
                waits.append(float(res["mean_wait"]))
                if M is not None:
                    mem = res["memory"]
                    rows.append({k: float(mem[k]) for k in
                                 ("kv_peak", "utilization",
                                  "blocked_batches", "deferred_requests")})
                    assert mem["kv_peak"] <= M, (M, mem)
            cell = {"memory": M, "mean_wait": float(np.mean(waits)),
                    "per_seed_wait": waits}
            if M is not None:
                cell["occupancy"] = rows
                tb = tandem_bound(dist, lat, LAM, memory=M)
                cell["tandem_bound"] = {k: float(tb[k]) for k in
                                        ("wait_bound", "memory_arm",
                                         "slack_arm", "b_mem")}
            sweep.append(cell)
            derived[f"wait_M{M}"] = cell["mean_wait"]
        t_sweep = time.perf_counter() - t0
        by_m = {c["memory"]: c["mean_wait"] for c in sweep}
        # acceptance: every finite budget costs latency (null is fastest)
        # and the tightest budget costs more than the loosest.  No strict
        # monotonicity across intermediate budgets: in the fragmentation
        # regime (docs/memory.md) a looser budget admits LARGER ragged
        # batches whose padding can outweigh the extra headroom.
        assert all(by_m[m] > by_m[None] for m in BUDGETS[:-1]), by_m
        assert by_m[2000.25] > by_m[8000.25], by_m

        # ------ 2: memory-aware controller vs budget-blind static ------
        blind = _fed_controller(single, lat)
        aware = _fed_controller(single, lat, memory=M_GATE)
        # the gate binds: the aware controller switched to the
        # count-throttled fixed batch under b(M)/2 (docs/memory.md)
        assert aware.details["memory_binding"], aware
        assert aware.policy == "fixed", aware
        assert aware.memory_budget == M_GATE
        assert 1 <= aware.b_max <= max(1, aware.details["b_mem"] // 2)
        assert not blind.details.get("memory_binding"), blind
        pol_blind, pol_aware = _policy_from_rec(blind), _policy_from_rec(aware)
        ctl = []
        for seed in seeds:
            kw = dict(num_requests=n_req, seed=seed, memory=M_GATE)
            w_blind = float(simulate_policy_fast(
                pol_blind, LAM, dist, lat, **kw)["mean_wait"])
            w_aware = float(simulate_policy_fast(
                pol_aware, LAM, dist, lat, **kw)["mean_wait"])
            ctl.append({"seed": seed, "blind_wait": w_blind,
                        "aware_wait": w_aware})
            # acceptance (ISSUE 10): the recommendation pays, per seed
            assert w_aware < w_blind, (seed, w_aware, w_blind)
        derived["blind_wait"] = float(np.mean([c["blind_wait"] for c in ctl]))
        derived["aware_wait"] = float(np.mean([c["aware_wait"] for c in ctl]))
        derived["control_speedup"] = derived["blind_wait"] / derived[
            "aware_wait"]

        # ------ 3: null budget short-circuits (bit-equal, ~free) ------
        base = simulate_policy_fast(pol, LAM, dist, lat,
                                    num_requests=n_req, seed=1)
        for spec in (None, np.inf, MemoryBudget()):
            null = simulate_policy_fast(pol, LAM, dist, lat,
                                        num_requests=n_req, seed=1,
                                        memory=spec)
            assert np.array_equal(base["waits"], null["waits"]), spec

    emit_bench("simulators", {
        "workload": f"uniform(1..1000) lam={LAM} dynamic(b_max=None); "
                    f"{n_req} requests x {len(seeds)} seeds",
        "budget_sweep": sweep,
        "control": {"cell": {"lam": LAM, "memory": M_GATE},
                    "blind": {"policy": blind.policy, "b_max": blind.b_max},
                    "aware": {"policy": aware.policy, "b_max": aware.b_max,
                              "b_mem": aware.details["b_mem"]},
                    "per_seed": ctl},
        "sweep_s": t_sweep,
    }, key="pr10_memory")
    emit("memory_tandem", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
