"""PR 5: fleet routing across parallel batched replicas.

Three fleet-level questions, all on the fast path
(``fastsim.simulate_fleet_fast`` / ``fleet.sweep``):

1. **Scaling curve**: mean wait vs replica count R at fixed TOTAL arrival
   rate (uniform outputs, capped dynamic batching behind jsq) — the
   'how many replicas do I need' surface, with the pooled M/G/R Erlang-C
   floor (``fleet.mgr_whitt_wait``) for context.
2. **Router comparison under heavy-tail lengths** (lognormal(7, 0.7),
   Fig-6b constants, SRPT replicas): random vs round_robin vs power-of-d
   vs jsq vs least_work at matched load — where prediction-aware dispatch
   (least_work) wins over length-blind balancing.
3. **Predictor-noise sensitivity of least_work**: the router's work
   estimate driven by a multiplicative lognormal predictor of noise σ;
   σ=0 must reproduce the oracle least_work fleet exactly (salted
   predictor stream), growing σ erodes the win back toward random.

Recorded as the ``pr5_fleet`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — pr1..pr4 keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer


def main(quick: bool = False):
    from repro.core.distributions import LogNormalTokens, UniformTokens
    from repro.core.fastsim import simulate_fleet_fast
    from repro.core.fleet import (
        LeastWorkRouter, default_routers, mgr_whitt_wait, sweep)
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import DynamicPolicy, SRPTPolicy, \
        single_from_batch
    from repro.core.predictors import LogNormalNoisePredictor

    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    ln = LogNormalTokens(7.0, 0.7)
    ht = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
    n_req = 20_000 if quick else 40_000
    seed = 3

    derived = {}
    with timer() as t_all:
        # ------ 1: delay vs R at fixed total lambda ------
        R_grid = [1, 2, 4, 8]
        lam_tot = 0.8
        t0 = time.perf_counter()
        scal = sweep(R_grid, [lam_tot], "jsq", DynamicPolicy(b_max=8),
                     uni, lat, num_requests=n_req, seed=seed)
        t_sweep = time.perf_counter() - t0
        mw = scal["mean_wait"][:, 0]
        assert (np.diff(mw) < 0).all(), "more replicas must cut delay"
        for ri, R in enumerate(R_grid):
            derived[f"scaling_R{R}"] = float(mw[ri])

        # analytic cell: jsq + FCFS replicas, where both the QNA split
        # approximation and the pooled M/G/R Erlang-C floor are defined —
        # sim must sit between the floor and ~the approximation
        from repro.core.fleet import fleet_analytic_delay
        from repro.core.policies import FCFSPolicy
        lam_f, R_f = 0.25, 3
        single = single_from_batch(lat)
        es, es2 = single.moments(uni, None)
        fcfs_sim = simulate_fleet_fast("jsq", FCFSPolicy(), lam_f, R_f,
                                       uni, lat, num_requests=n_req,
                                       seed=seed)["mean_wait"]
        qna = fleet_analytic_delay("jsq", FCFSPolicy(), lam_f, R_f, uni,
                                   lat)
        floor = mgr_whitt_wait(lam_f, R_f, es, es2)
        assert floor < fcfs_sim            # pooling dominates any router
        derived["jsq_fcfs_sim"] = float(fcfs_sim)
        derived["jsq_fcfs_qna"] = float(qna)
        derived["mgr_pooled_floor"] = float(floor)

        # ------ 2: router comparison, heavy tail, SRPT replicas ------
        lam_ht, R_ht = 1.6, 4
        routers = default_routers()
        comp = {}
        for name, router in routers.items():
            comp[name] = simulate_fleet_fast(
                router, SRPTPolicy(b_max=16), lam_ht, R_ht, ln, ht,
                num_requests=n_req, seed=seed)["mean_wait"]
            derived[f"router_{name}_ht"] = float(comp[name])
        # prediction-aware dispatch beats every length-blind router
        assert comp["least_work"] < min(
            v for k, v in comp.items() if k != "least_work"), comp

        # ------ 3: least_work predictor-noise sensitivity ------
        sigmas = [0.0, 0.5, 1.0, 2.0]
        noise_w = []
        for s in sigmas:
            router = LeastWorkRouter(
                predictor=LogNormalNoisePredictor(sigma=s))
            noise_w.append(simulate_fleet_fast(
                router, SRPTPolicy(b_max=16), lam_ht, R_ht, ln, ht,
                num_requests=n_req, seed=seed)["mean_wait"])
            derived[f"least_work_sigma{s}"] = float(noise_w[-1])
        # sigma=0 is the oracle fleet exactly (salted predictor stream)
        assert abs(noise_w[0] - comp["least_work"]) < 1e-9
        # noise erodes the routing win at the heavy-tail operating point
        assert noise_w[-1] > noise_w[0]

        # ------ 4: serving-layer tail observability ------
        # the full summarize_fleet surface (p50/p95/p99 + resilience
        # accounting) on a fault-injected serving fleet, so the tracked
        # record carries tail latency and retry/shed/availability fields
        from repro.core.faults import CrashRepair
        from repro.core.policies import DynamicPolicy as _Dyn
        from repro.data.pipeline import make_request_stream
        from repro.serving.router import FleetScheduler, summarize_fleet
        from repro.serving.scheduler import ModelClock
        clock = ModelClock(single, lat)
        sreqs = make_request_stream(800, lam=0.4, dist=uni, vocab=512,
                                    seed=seed)
        tail = summarize_fleet(FleetScheduler(
            "jsq", _Dyn(b_max=8), clock, 2,
            faults=CrashRepair(mtbf=120.0, mttr=10.0), seed=seed).run(
            sreqs))
        serving_tail = {k: tail[k] for k in (
            "p50_wait", "p95_wait", "p99_wait", "mean_wait", "served",
            "shed", "failed", "retries", "hedged", "hedge_wins",
            "kill_events", "availability")}
        assert tail["served"] + tail["shed"] + tail["failed"] == len(sreqs)
        derived["serving_p99_wait"] = tail["p99_wait"]
        derived["serving_retries"] = tail["retries"]

        # ------ 5: per-replica KV budgets across the fleet ------
        # each replica owns its HBM (docs/memory.md): the aggregate rolls
        # up the max peak / summed blocking across replicas, and the
        # occupancy ledger must close at drain
        M_fleet = 4000.25
        mem_res = simulate_fleet_fast(
            "round_robin", DynamicPolicy(None), 0.2, 2, uni, lat,
            num_requests=min(n_req, 6_000), seed=seed, memory=M_fleet)
        fleet_mem = mem_res["memory"]
        assert fleet_mem["capacity"] == M_fleet
        assert fleet_mem["kv_peak"] <= M_fleet
        assert fleet_mem["allocated"] == fleet_mem["freed"]
        derived["fleet_kv_peak"] = float(fleet_mem["kv_peak"])
        derived["fleet_blocked_batches"] = int(fleet_mem["blocked_batches"])

    emit_bench("simulators", {
        "workload": f"scaling: uniform(0,1000) lam={lam_tot} over R={R_grid}"
                    f"; routers: lognormal(7,0.7) heavy tail lam={lam_ht} "
                    f"R={R_ht} SRPT b16; {n_req} requests",
        "scaling_mean_wait": {str(R): float(v)
                              for R, v in zip(R_grid, mw)},
        "jsq_fcfs_analytic_cell": {
            "lam": lam_f, "R": R_f, "sim": float(fcfs_sim),
            "qna_approx": float(qna), "mgr_pooled_floor": float(floor)},
        "router_mean_wait_ht": {k: float(v) for k, v in comp.items()},
        "least_work_noise": {"sigmas": sigmas,
                             "mean_wait": [float(v) for v in noise_w]},
        "serving_tail": serving_tail,
        "fleet_memory": {"capacity": M_fleet,
                         **{k: float(v) for k, v in fleet_mem.items()
                            if k != "capacity"}},
        "sweep_s": t_sweep,
    }, key="pr5_fleet")
    emit("fleet_routing", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
