"""PR 7: sharded sweeps + fused serving, the scale benchmark.

Two tracked records, both under the ``pr7_scale`` key:

1. **BENCH_simulators.json**: the sharded fleet sweep
   (``shardsweep.fleet_sweep``, every replica sub-stream of every (R, λ)
   cell a lane of one ``shard_map`` dispatch) against the per-cell
   ``fleet.sweep`` path of PR 5/6, on a forced 4-CPU-device mesh
   (``XLA_FLAGS=--xla_force_host_platform_device_count=4``, run in a
   subprocess so the parent's single-device JAX config is untouched).
   The grid simulates ~1M total requests in quick mode (~10M full); the
   sharded result must be BIT-equal and the round_robin grid must clear a
   2x sweep-throughput gain.
2. **BENCH_engine.json**: dense vs ragged decode attention µs/step in
   interpret mode (honest CPU-interpret numbers — the ragged kernel only
   wins compiled on TPU, which is exactly why ``decode_attention_impl=
   "auto"`` resolves to dense off-TPU), plus elastic-generate compaction
   accounting: fused (Pallas gather, device-resident keep) vs host
   recompaction, identical tokens, host_syncs(fused) == host_syncs(host)
   minus one per compaction event.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer

_WORKER = textwrap.dedent("""
    import json, sys, time
    import numpy as np
    import jax
    from repro.core import fleet, shardsweep
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import ElasticPolicy

    n_req = int(sys.argv[1])
    LN = LogNormalTokens()
    LAT = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    R_grid = [2, 4, 8]
    lams = [0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85]
    pol = ElasticPolicy(b_max=8)
    total = len(R_grid) * len(lams) * n_req

    def best_of(fn, reps=3):
        fn()                                   # warm the compile caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    res = {"devices": jax.device_count(), "n_req_per_cell": n_req,
           "cells": len(R_grid) * len(lams), "total_requests": total,
           "R_grid": R_grid, "lams": lams}
    for router in ("round_robin", "least_work"):
        ts, a = best_of(lambda: fleet.sweep(
            R_grid, lams, router, pol, LN, LAT, num_requests=n_req, seed=3))
        th, b = best_of(lambda: shardsweep.fleet_sweep(
            R_grid, lams, router, pol, LN, LAT, num_requests=n_req, seed=3))
        assert np.array_equal(a["mean_wait"], b["mean_wait"]), router
        res[router] = {
            "single_device_s": ts, "sharded_s": th, "speedup": ts / th,
            "single_req_per_s": total / ts, "sharded_req_per_s": total / th,
            "bit_equal": True}
    print(json.dumps(res))
""")


def _sharded_record(quick: bool) -> dict:
    """Run the forced-4-device sweep comparison in a fresh process."""
    n_req = 42_000 if quick else 420_000
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _WORKER, str(n_req)],
                       env=env, capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"sharded sweep worker failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _decode_attention_record(quick: bool) -> dict:
    """Dense vs ragged decode attention, interpret mode (CPU-honest)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ragged_decode_attention import ragged_decode_attention
    from repro.models.layers import decode_attention

    b, s, hq, hkv, d = 8, 512, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    lens = jnp.asarray(np.linspace(1, s, b).astype(np.int32))

    dense = jax.jit(lambda: decode_attention(
        q[:, None], kc, vc, lens, window=None)[:, 0])
    ragged = lambda: ragged_decode_attention(q, kc, vc, lens, block_kv=128)
    np.testing.assert_allclose(np.asarray(ragged()), np.asarray(dense()),
                               atol=2e-5, rtol=2e-5)
    reps = 5 if quick else 20
    out = {"batch": b, "max_seq": s, "heads": f"{hq}q/{hkv}kv",
           "interpret_mode": jax.default_backend() != "tpu",
           "resolved_default": "ragged" if jax.default_backend() == "tpu"
           else "dense"}
    for name, fn in (("dense", dense), ("ragged", ragged)):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) / reps
        out[f"{name}_us_per_step"] = dt * 1e6
        out[f"{name}_tok_per_s"] = b / dt
    return out


def _compaction_record(quick: bool) -> dict:
    """Elastic generate under both compaction impls: fused must match the
    host path token-for-token while paying zero syncs per compaction."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.serving.engine import Engine, EngineConfig

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    ecfg = EngineConfig(max_batch=4, max_seq=256, prompt_bucket=16)
    prompts = [np.arange(6, dtype=np.int32) + i for i in range(3)]
    targets = [25, 4, 13] if quick else [90, 10, 45]
    runs = {}
    for impl in ("fused", "host"):
        eng = Engine(cfg, dataclasses.replace(ecfg, compact_impl=impl))
        t0 = time.perf_counter()
        r = eng.generate(prompts, targets, elastic=True, chunk=4,
                         return_tokens=True, temperature=0.8, seed=11)
        dt = time.perf_counter() - t0
        ev = [e for e in eng.step_log if e["kind"] == "compact"]
        runs[impl] = {"wall_s": dt, "host_syncs": r["host_syncs"],
                      "compaction_events": len(ev),
                      "syncs_per_compaction": (
                          sum(e["syncs"] for e in ev) / max(len(ev), 1)),
                      "tokens": r["tokens"]}
    assert runs["fused"]["tokens"] == runs["host"]["tokens"]
    assert runs["fused"]["syncs_per_compaction"] == 0.0
    assert runs["fused"]["host_syncs"] == (
        runs["host"]["host_syncs"] - runs["host"]["compaction_events"])
    for v in runs.values():
        del v["tokens"]
    return {"impls": runs, "tokens_identical": True,
            "target_tokens": sum(targets)}


def main(quick: bool = False):
    derived = {}
    with timer() as t_all:
        sharded = _sharded_record(quick)
        rr = sharded["round_robin"]
        assert rr["speedup"] >= 2.0, \
            f"sharded sweep below the 2x bar: {rr['speedup']:.2f}x"
        derived["sweep_speedup_rr"] = rr["speedup"]
        derived["sweep_speedup_lw"] = sharded["least_work"]["speedup"]
        derived["sweep_total_requests"] = sharded["total_requests"]
        derived["sharded_req_per_s"] = rr["sharded_req_per_s"]

        attn = _decode_attention_record(quick)
        derived["dense_decode_us"] = attn["dense_us_per_step"]
        derived["ragged_decode_us"] = attn["ragged_us_per_step"]

        comp = _compaction_record(quick)
        derived["fused_syncs_per_compaction"] = \
            comp["impls"]["fused"]["syncs_per_compaction"]
        derived["host_syncs_saved"] = \
            comp["impls"]["host"]["compaction_events"]

    emit_bench("simulators", {
        "workload": f"fleet grid R={sharded['R_grid']} x "
                    f"{len(sharded['lams'])} lams x "
                    f"{sharded['n_req_per_cell']} reqs/cell "
                    f"({sharded['total_requests']} total), elastic b8, "
                    f"forced {sharded['devices']}-device CPU mesh",
        "devices": sharded["devices"],
        "total_requests": sharded["total_requests"],
        "round_robin": sharded["round_robin"],
        "least_work": sharded["least_work"],
    }, key="pr7_scale")
    emit_bench("engine", {
        "decode_attention": attn,
        "compaction": comp,
    }, key="pr7_scale")
    emit("scale", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
