"""Paper Table I + Fig 2a: inference latency vs (input, output) tokens, and
the linear fit S = a*n + c.

Measured on the real jitted engine (tiny same-family model on CPU), then the
A100-scale constants are back-derived from the paper's own Table I, and
TPU-v5e analytic constants are derived from the decode roofline (dry-run):
a_v5e ~ per-token decode time = max(mem, comp, coll) roofline terms of the
decode cell / batch.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    import dataclasses
    import jax
    from repro.configs import get_smoke_config
    from repro.core.latency_model import (
        PAPER_A100_LLAMA2_7B, fit_latency_model, linear_fit_r2)
    from repro.serving.engine import Engine, EngineConfig

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, EngineConfig(max_batch=2, max_seq=512, prompt_bucket=32))

    # Table I analogue: latency grid over (input, output) tokens
    table = {}
    with timer() as t_all:
        for inp, out in [(16, 16), (16, 32), (16, 64), (16, 128),
                         (8, 64), (32, 64), (64, 64), (128, 64)]:
            prompts = [np.arange(inp, dtype=np.int32)]
            res = eng.generate(prompts, [out])
            res = eng.generate(prompts, [out])   # warm second run
            table[(inp, out)] = res["batch_seconds"]

    # Fig 2a: linear fit over output tokens at fixed input
    ns = np.array([16, 32, 64, 128])
    ts = np.array([table[(16, int(n))] for n in ns])
    lat = fit_latency_model(ns, ts)
    r2 = linear_fit_r2(ns, ts)

    # input-token insensitivity (Table I right half)
    t_in = np.array([table[(i, 64)] for i in (8, 32, 64, 128)])
    input_spread = float(t_in.max() - t_in.min()) / float(t_in.mean())

    # v5e analytic constant from the decode roofline (gemma decode cell)
    a_v5e = None
    try:
        rec = json.load(open("results/dryrun/gemma-7b__decode_32k__single.json"))
        from benchmarks.roofline import analyze_record
        a = analyze_record(rec)
        a_v5e = a["step_time_bound_s"] / 128.0   # per token per request row
    except Exception:
        pass

    derived = {
        "engine_a_s_per_tok": lat.a,
        "engine_c_s": lat.c,
        "fig2a_linear_r2": r2,
        "input_token_spread_frac": input_spread,
        "paper_a100_a": PAPER_A100_LLAMA2_7B.a,
        "paper_a100_c": PAPER_A100_LLAMA2_7B.c,
        "v5e_decode_bound_s_per_tok_row": a_v5e,
    }
    emit("table1_fig2a_latency_model", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
