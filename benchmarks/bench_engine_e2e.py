"""Beyond-paper: end-to-end policy comparison on the REAL jitted engine
(tiny model, wall clock) + the adaptive control plane choosing the policy.

Demonstrates that the paper's analytic ordering (elastic <= dynamic; clip
reduces tail) holds on actual executables, and that the controller's
recommendation agrees with the analytics.

Also records the fused chunked-decode speedup (ISSUE 1): the same batches
generated with chunk=1 (per-step reference: one host sync per token) vs the
fused lax.scan chunks (one sync per chunk), identical tokens asserted, wall
time and sync counts written to ``benchmarks/BENCH_engine.json``."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, emit_bench, timer


def main(quick: bool = False):
    import jax
    from repro.configs import get_smoke_config
    from repro.core.control import AdaptiveController
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import (
        BatchLatencyModel, LatencyModel, fit_batch_latency_model)
    from repro.serving.engine import Engine, EngineConfig

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, EngineConfig(max_batch=8, max_seq=256, prompt_bucket=16))
    rng = np.random.default_rng(0)
    # scaled-down heavy-tail workload (token counts 1..96)
    dist = LogNormalTokens(3.0, 0.7, support=96)
    n_batches = 3 if quick else 6

    derived = {}
    with timer() as t_all:
        # ------ chunked vs per-step decode (BENCH_engine.json) ------
        chunk = eng.ecfg.decode_chunk
        bench_batches = []
        for i in range(n_batches):
            prompts = [np.arange(8, dtype=np.int32) + j for j in range(8)]
            targets = [int(max(t, 1)) for t in dist.sample(rng, 8)]
            bench_batches.append((prompts, targets))
        # warm both paths so the record is steady-state, not compile: a
        # 2*chunk target walks every power-of-two tail executable
        # (chunk, chunk/2, ..., 1) that later batches can hit
        warm_prompts = bench_batches[0][0]
        eng.generate(warm_prompts, [2 * chunk] * len(warm_prompts), chunk=1)
        eng.generate(warm_prompts, [2 * chunk] * len(warm_prompts),
                     chunk=chunk)
        step_s, step_syncs, chunk_s, chunk_syncs = 0.0, 0, 0.0, 0
        for prompts, targets in bench_batches:
            t0 = time.perf_counter()
            r1 = eng.generate(prompts, targets, chunk=1, return_tokens=True)
            step_s += time.perf_counter() - t0
            step_syncs += r1["host_syncs"]
            t0 = time.perf_counter()
            rc = eng.generate(prompts, targets, chunk=chunk,
                              return_tokens=True)
            chunk_s += time.perf_counter() - t0
            chunk_syncs += rc["host_syncs"]
            assert r1["tokens"] == rc["tokens"]
            assert list(r1["produced"]) == list(rc["produced"])
        derived["chunked_decode_speedup"] = step_s / max(chunk_s, 1e-9)
        derived["host_syncs_per_step"] = step_syncs
        derived["host_syncs_chunked"] = chunk_syncs
        emit_bench("engine", {
            "workload": f"{n_batches} batches x 8 reqs, lognormal targets "
                        f"<=96 tokens, decode_chunk={chunk}",
            "per_step_s": step_s,
            "chunked_s": chunk_s,
            "speedup": step_s / max(chunk_s, 1e-9),
            "host_syncs_per_step": step_syncs,
            "host_syncs_chunked": chunk_syncs,
            "sync_reduction": step_syncs / max(chunk_syncs, 1),
        })

        pad_time, ela_time = 0.0, 0.0
        pad_tail, ela_tail = [], []
        for prompts, targets in bench_batches:
            rp = eng.generate(prompts, targets, elastic=False)
            re_ = eng.generate(prompts, targets, elastic=True)
            pad_time += rp["batch_seconds"]
            ela_time += re_["batch_seconds"]
            pad_tail.extend(rp["completion_seconds"])
            ela_tail.extend(re_["completion_seconds"])
            assert list(rp["produced"]) == list(re_["produced"])
        derived["padded_total_s"] = pad_time
        derived["elastic_total_s"] = ela_time
        derived["elastic_mean_completion_gain"] = float(
            np.mean(pad_tail) / max(np.mean(ela_tail), 1e-9))

        # calibrate the engine and let the controller recommend
        cal = eng.calibration_log()
        dec = [(b, s) for b, s in cal["decode"]]
        bs = np.array([d[0] for d in dec], np.float64)
        ts = np.array([d[1] for d in dec], np.float64)
        k3, k4 = np.polyfit(bs, ts, 1) if len(dec) > 4 else (1e-4, 1e-2)
        blat = BatchLatencyModel(k1=5e-3, k2=5e-2,
                                 k3=float(max(k3, 1e-6)),
                                 k4=float(max(k4, 1e-4)))
        ctrl = AdaptiveController(
            LatencyModel(a=float(max(k4, 1e-4)), c=0.05), blat,
            theta=119 / 120, elastic_available=True, min_samples=32)
        t = 0.0
        for n in dist.sample(rng, 256):
            t += rng.exponential(1.0)
            ctrl.observe_arrival(t)
            ctrl.observe_completion(int(n))
        rec = ctrl.recommendation(force=True)
        derived["controller_policy"] = rec.policy
        derived["controller_nmax"] = rec.n_max
        derived["controller_heavy_tailed"] = rec.heavy_tailed
        derived["decode_k4_fit_s"] = float(k4)

    emit("engine_e2e_policies", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
