"""PR 9: re-entrant agentic sessions — the affinity-vs-balancing trade.

Three feedback questions:

1. **Affinity vs load balancing**: under geometric feedback
   (p=0.5, exponential think) a fleet routes every turn of a session
   either to its home replica (``session_affinity`` — sticky hashing
   that earns the ``prefix_discount`` γ on turns >= 2) or by backlog
   (``least_work``) or blindly (``random``).  The benchmark runs the
   {session_affinity, least_work, random} × γ ∈ {0, 0.5} grid,
   multi-seed.  Acceptance (ISSUE 9): with prefix reuse ON,
   ``session_affinity`` beats ``random`` end-to-end; with γ = 0 the
   stickiness has nothing to earn and ``least_work`` wins — both sides
   of the trade are recorded so a regression in either is visible.
2. **Feedback load amplification**: mean wait of a single server as the
   return probability p rises at fixed session rate λ — the simulated
   counterpart of λ_eff = λ·E[turns] (docs/sessions.md; the analytic
   band itself is validated in tests/test_sessions.py).
3. **Null conformance timing**: the ``single`` (null) model must add no
   measurable work — it short-circuits to the session-free path.

Recorded as the ``pr9_sessions`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — pr1..pr8 keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer

ROUTERS = ("session_affinity", "least_work", "random")


def main(quick: bool = False):
    from repro.core.distributions import LogNormalTokens
    from repro.core.fastsim import simulate_fleet_fast, simulate_policy_fast
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import DynamicPolicy
    from repro.core.sessions import GeometricSession

    dist = LogNormalTokens(5.0, 0.6)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    pol = DynamicPolicy(b_max=8)
    sessions = GeometricSession(p=0.5, think_mean=2.0)
    lam, R = 1.5, 3
    n_req, seeds = ((250, (5, 6, 7)) if quick
                    else (500, (5, 6, 7, 8, 9)))

    derived = {}
    with timer() as t_all:
        # ------ 1: router × prefix-discount grid, multi-seed ------
        t0 = time.perf_counter()
        grid = []
        for gamma in (0.0, 0.5):
            for router in ROUTERS:
                waits, e2es = [], []
                for seed in seeds:
                    res = simulate_fleet_fast(
                        router, pol, lam, R, dist, lat,
                        num_requests=n_req, seed=seed, sessions=sessions,
                        prefix_discount=gamma)
                    waits.append(float(res["mean_wait"]))
                    e2es.append(float(
                        res["sessions"]["mean_session_e2e"]))
                grid.append({"router": router, "prefix_discount": gamma,
                             "mean_wait": float(np.mean(waits)),
                             "mean_session_e2e": float(np.mean(e2es)),
                             "per_seed_wait": waits})
                derived[f"wait_{router}_g{gamma}"] = grid[-1]["mean_wait"]
        t_grid = time.perf_counter() - t0
        by = {(r["router"], r["prefix_discount"]): r for r in grid}
        # acceptance (ISSUE 9): with reuse ON, stickiness beats blind
        # routing end-to-end — on mean wait AND session e2e
        aff, rnd = by[("session_affinity", 0.5)], by[("random", 0.5)]
        assert aff["mean_wait"] < rnd["mean_wait"], (aff, rnd)
        assert aff["mean_session_e2e"] < rnd["mean_session_e2e"], (aff, rnd)
        # the other side of the trade: with nothing to earn (γ=0), blind
        # stickiness must NOT beat backlog-aware balancing
        assert (by[("least_work", 0.0)]["mean_wait"]
                <= by[("session_affinity", 0.0)]["mean_wait"]), by
        # reuse must pay for the sticky router itself
        assert aff["mean_wait"] < by[("session_affinity", 0.0)][
            "mean_wait"], by

        # ------ 2: feedback load amplification on a single server ------
        amp = []
        for p in (0.0, 0.3, 0.5):
            sm = GeometricSession(p=p, think_mean=2.0)
            res = simulate_policy_fast(pol, 0.4, dist, lat,
                                       num_requests=n_req, seed=3,
                                       sessions=sm)
            amp.append({"p": p, "mean_turns": sm.mean_turns(),
                        "mean_wait": float(res["mean_wait"])})
        # λ_eff = λ/(1−p) rises with p, so so must the simulated wait
        assert (amp[0]["mean_wait"] < amp[1]["mean_wait"]
                < amp[2]["mean_wait"]), amp
        derived["amp_p0"] = amp[0]["mean_wait"]
        derived["amp_p05"] = amp[2]["mean_wait"]

        # ------ 3: null model short-circuits (bit-equal, ~free) ------
        base = simulate_policy_fast(pol, 0.4, dist, lat,
                                    num_requests=n_req, seed=3)
        null = simulate_policy_fast(pol, 0.4, dist, lat,
                                    num_requests=n_req, seed=3,
                                    sessions=GeometricSession(p=0.0))
        assert np.array_equal(base["waits"], null["waits"])

    emit_bench("simulators", {
        "workload": f"lognormal(5,0.6) lam={lam} R={R} dynamic(b_max=8); "
                    f"geometric(p=0.5, think_mean=2.0); {n_req} sessions "
                    f"x {len(seeds)} seeds",
        "grid": grid,
        "feedback_amplification": amp,
        "grid_s": t_grid,
    }, key="pr9_sessions")
    emit("sessions_affinity", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
