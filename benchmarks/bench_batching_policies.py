"""Paper Fig 5: mean queueing delay of dynamic vs elastic batching over
arrival rate (uniform(0,1000) outputs), with the Inoue-style upper bound
(Eq 16 via the Eq 20/26 linearizations). Also runs the policies end-to-end
through the serving schedulers (same virtual-timeline discipline the real
engine uses) — analytic bound vs simulation vs scheduler must agree.

The λ-grid itself runs on the vectorized fast simulators (one vmapped
per-request scan over every (λ, policy) lane — repro.core.fastsim); a
reference-vs-fast timing section at 200k requests records the speedup to
``benchmarks/BENCH_simulators.json`` so the perf trajectory is tracked in
git. The NumPy reference loops stay the cross-checked oracle: the bench
asserts fast == reference on one (λ, policy) cell every run."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_bench, timer


def _time_reference_loops(lams, uni, lat, n_req):
    from repro.core.simulate import simulate_dynamic_batching
    t0 = time.perf_counter()
    out = {}
    for lam in lams:
        out[("dyn", lam)] = simulate_dynamic_batching(
            lam, uni, lat, num_requests=n_req, seed=3)["mean_wait"]
        out[("ela", lam)] = simulate_dynamic_batching(
            lam, uni, lat, elastic=True, num_requests=n_req,
            seed=3)["mean_wait"]
    return out, time.perf_counter() - t0


def _time_fast_sweep(lams, uni, lat, n_req):
    from repro.core.fastsim import simulate_policy_sweep_fast
    policies = {"dyn": dict(kind="dynamic"), "ela": dict(kind="elastic")}
    # cold call includes XLA compile; the warm call is the steady-state
    # throughput every later sweep in the process enjoys
    t0 = time.perf_counter()
    res = simulate_policy_sweep_fast(lams, uni, lat, policies,
                                     num_requests=n_req, seed=3)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = simulate_policy_sweep_fast(lams, uni, lat, policies,
                                     num_requests=n_req, seed=3)
    t_warm = time.perf_counter() - t0
    return res, t_cold, t_warm


def main(quick: bool = False):
    from repro.core.bulk import dynamic_batching_bound, elastic_batching_bound
    from repro.core.distributions import UniformTokens
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.data.pipeline import make_request_stream
    from repro.serving.metrics import summarize
    from repro.serving.scheduler import (
        DynamicBatchScheduler, ElasticBatchScheduler, ModelClock)

    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    clock = ModelClock(LatencyModel(0.0212, 1.79), lat)
    n_req = 60_000 if quick else 150_000
    lams = [0.05, 0.1, 0.2, 0.4, 0.8]

    derived = {}
    gaps = []
    with timer() as t_all:
        # ------ ref-vs-fast perf record (acceptance: fast >= 10x ref) ------
        # always at 200k requests; quick/CI mode trims the lambda grid so
        # the reference-loop half doesn't dominate the quick run
        n_perf = 200_000
        perf_lams = [0.2, 0.8] if quick else lams
        ref_waits, t_ref = _time_reference_loops(perf_lams, uni, lat, n_perf)
        fast_waits, t_cold, t_warm = _time_fast_sweep(perf_lams, uni, lat,
                                                      n_perf)
        for li, lam in enumerate(perf_lams):
            # fast must agree with the oracle on the same seed
            assert abs(fast_waits["dyn"][li] - ref_waits[("dyn", lam)]) < 1e-6
            assert abs(fast_waits["ela"][li] - ref_waits[("ela", lam)]) < 1e-6
        derived["sim_speedup_cold"] = t_ref / t_cold
        derived["sim_speedup_warm"] = t_ref / t_warm
        emit_bench("simulators", {
            "workload": f"{len(perf_lams)} lambdas x (dynamic, elastic), "
                        f"{n_perf} requests each",
            "reference_loops_s": t_ref,
            "fast_sweep_cold_s": t_cold,   # includes one-time XLA compile
            "fast_sweep_warm_s": t_warm,
            "speedup_cold": t_ref / t_cold,
            "speedup_warm": t_ref / t_warm,
        })

        # ------ Fig 5 grid on the fast path (oracle-checked above) ------
        if n_req == n_perf and perf_lams == lams:
            grid = fast_waits
        else:
            from repro.core.fastsim import simulate_policy_sweep_fast
            grid = simulate_policy_sweep_fast(
                lams, uni, lat,
                {"dyn": dict(kind="dynamic"), "ela": dict(kind="elastic")},
                num_requests=n_req, seed=3)
        for li, lam in enumerate(lams):
            d_mean = float(grid["dyn"][li])
            e_mean = float(grid["ela"][li])
            db = dynamic_batching_bound(uni, lat, lam)["wait_bound"]
            eb = elastic_batching_bound(uni, lat, lam)["wait_bound"]
            derived[f"dyn_sim_lam{lam}"] = d_mean
            derived[f"ela_sim_lam{lam}"] = e_mean
            derived[f"dyn_bound_lam{lam}"] = db
            gaps.append(d_mean - e_mean)
            assert db >= d_mean * 0.98, "bound violated"
            assert eb >= e_mean * 0.98, "bound violated"
        derived["elastic_advantage_grows_with_lam"] = bool(
            gaps[-1] > gaps[0])

        # scheduler cross-check at lam=0.2
        reqs = make_request_stream(min(n_req, 60_000), lam=0.2, dist=uni,
                                   vocab=100, seed=3)
        sd = summarize(DynamicBatchScheduler(clock).run(reqs))
        se = summarize(ElasticBatchScheduler(clock).run(reqs))
        derived["scheduler_dyn_lam0.2"] = sd["mean_wait"]
        derived["scheduler_ela_lam0.2"] = se["mean_wait"]

    emit("fig5_dynamic_vs_elastic", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
