"""Paper Fig 5: mean queueing delay of dynamic vs elastic batching over
arrival rate (uniform(0,1000) outputs), with the Inoue-style upper bound
(Eq 16 via the Eq 20/26 linearizations). Also runs the policies end-to-end
through the serving schedulers (same virtual-timeline discipline the real
engine uses) — analytic bound vs simulation vs scheduler must agree."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    from repro.core.bulk import dynamic_batching_bound, elastic_batching_bound
    from repro.core.distributions import UniformTokens
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.core.simulate import simulate_dynamic_batching
    from repro.data.pipeline import make_request_stream
    from repro.serving.metrics import summarize
    from repro.serving.scheduler import (
        DynamicBatchScheduler, ElasticBatchScheduler, ModelClock)

    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    clock = ModelClock(LatencyModel(0.0212, 1.79), lat)
    n_req = 60_000 if quick else 150_000
    lams = [0.05, 0.1, 0.2, 0.4, 0.8]

    derived = {}
    gaps = []
    with timer() as t_all:
        for lam in lams:
            d = simulate_dynamic_batching(lam, uni, lat,
                                          num_requests=n_req, seed=3)
            e = simulate_dynamic_batching(lam, uni, lat, elastic=True,
                                          num_requests=n_req, seed=3)
            db = dynamic_batching_bound(uni, lat, lam)["wait_bound"]
            eb = elastic_batching_bound(uni, lat, lam)["wait_bound"]
            derived[f"dyn_sim_lam{lam}"] = d["mean_wait"]
            derived[f"ela_sim_lam{lam}"] = e["mean_wait"]
            derived[f"dyn_bound_lam{lam}"] = db
            gaps.append(d["mean_wait"] - e["mean_wait"])
            assert db >= d["mean_wait"] * 0.98, "bound violated"
            assert eb >= e["mean_wait"] * 0.98, "bound violated"
        derived["elastic_advantage_grows_with_lam"] = bool(
            gaps[-1] > gaps[0])

        # scheduler cross-check at lam=0.2
        reqs = make_request_stream(min(n_req, 60_000), lam=0.2, dist=uni,
                                   vocab=100, seed=3)
        sd = summarize(DynamicBatchScheduler(clock).run(reqs))
        se = summarize(ElasticBatchScheduler(clock).run(reqs))
        derived["scheduler_dyn_lam0.2"] = sd["mean_wait"]
        derived["scheduler_ela_lam0.2"] = se["mean_wait"]

    emit("fig5_dynamic_vs_elastic", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
