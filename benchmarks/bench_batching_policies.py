"""Paper Fig 5 + the policy registry, end-to-end.

Three jobs since the batching-policy refactor:

1. **Registry coverage** (CI gate): every policy registered in
   ``repro.core.policies`` must run end-to-end through the fast simulator
   AND the scheduler adapter — ``registry_coverage()`` raises if any
   discipline broke, and the GitHub Actions benchmark step fails with it.
2. **Fig 5**: mean queueing delay of dynamic vs elastic batching over
   arrival rate (uniform(0,1000) outputs) with the Inoue-style upper bound
   (Eq 16 via the Eq 20/26 linearizations), all through the uniform
   ``fastsim.sweep`` entry point; the NumPy oracle cross-checks one cell
   per run and the ref-vs-fast timing extends ``BENCH_simulators.json``
   (keyed runs — earlier PRs' numbers stay in the file).
3. **Multi-bin batching** (Guldogan et al. 2024): delay vs dynamic /
   capped-dynamic / elastic under the paper's heavy-tail workload
   (lognormal(7, 0.7), Fig-6b latency constants) where max-token padding
   dominates — the regime multi-bin was designed for.
4. **PR 3 disciplines** under the same heavy-tail workload: WAIT
   threshold admission (Dai et al. 2025), SRPT shortest-predicted-first,
   and multi-bin with load-optimized boundaries
   (``bulk.optimize_bin_edges``) — recorded as the
   ``pr3_wait_srpt_multibin`` key of ``BENCH_simulators.json``."""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer


def _load_check_docs():
    """The docs gate lives once, in scripts/check_docs.py (not a package);
    load it by path so this bench and the CI docs job share one
    implementation."""
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "check_docs.py")
    spec = importlib.util.spec_from_file_location("check_docs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _time_reference_loops(lams, uni, lat, n_req):
    from repro.core.simulate import simulate_dynamic_batching
    t0 = time.perf_counter()
    out = {}
    for lam in lams:
        out[("dyn", lam)] = simulate_dynamic_batching(
            lam, uni, lat, num_requests=n_req, seed=3)["mean_wait"]
        out[("ela", lam)] = simulate_dynamic_batching(
            lam, uni, lat, elastic=True, num_requests=n_req,
            seed=3)["mean_wait"]
    return out, time.perf_counter() - t0


def _time_fast_sweep(lams, uni, lat, n_req):
    from repro.core.fastsim import sweep
    from repro.core.policies import DynamicPolicy, ElasticPolicy
    policies = {"dyn": DynamicPolicy(), "ela": ElasticPolicy()}
    # cold call includes XLA compile; the warm call is the steady-state
    # throughput every later sweep in the process enjoys
    t0 = time.perf_counter()
    res = sweep(policies, lams, uni, lat, num_requests=n_req, seed=3)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = sweep(policies, lams, uni, lat, num_requests=n_req, seed=3)
    t_warm = time.perf_counter() - t0
    return res, t_cold, t_warm


def registry_coverage(n_req: int = 4_000) -> dict:
    """Run EVERY registered policy end-to-end (fast simulator + scheduler
    adapter) on a small workload; raise if any discipline broke.  The CI
    benchmark step calls this, so a policy that stops running fails the
    build.  Also gates the docs: every registered policy must be mentioned
    in docs/equations.md, every registered length predictor in
    docs/predictors.md, and every registered fleet router in docs/fleet.md
    (same checks as scripts/check_docs.py), so a new discipline, predictor
    or router cannot land undocumented.  Every registered predictor
    additionally runs end-to-end behind SRPT membership (the most
    prediction-sensitive discipline) on both the fast simulator and the
    scheduler adapter, and every registered router runs a small fleet
    end-to-end on both the fast fleet simulator and ``FleetScheduler``.
    Every registered fault model (docs/faults.md) runs the fault-injected
    fleet on both layers with closed accounting, and every registered
    traffic model (docs/traffic.md) runs both simulator layers with
    oracle == fastsim equality and bit-exact stationary conformance, and
    every registered session model (docs/sessions.md) runs both layers
    with oracle == fastsim equality and a bit-exact null (single-turn)
    short-circuit.  Every registered batch-formation policy
    (docs/memory.md) additionally runs memory-gated (KV budget) on both
    layers with oracle == fastsim equality and a bit-exact null
    (infinite-budget) short-circuit, and the non-batch disciplines must
    keep REFUSING a budget (``check_policy_supports_memory``)."""
    from repro.core.distributions import UniformTokens
    from repro.core.fastsim import simulate_fleet_fast, simulate_policy_fast
    from repro.core.fleet import ROUTERS, default_routers
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.core.policies import (
        DynamicPolicy, REGISTRY, SRPTPolicy, default_policies)
    from repro.core.predictors import (
        PREDICTORS, LearnedPredictor, PromptFeaturePredictor)
    from repro.data.pipeline import make_request_stream
    from repro.serving.metrics import summarize
    from repro.serving.router import FleetScheduler, summarize_fleet
    from repro.serving.scheduler import ModelClock

    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    clock = ModelClock(LatencyModel(0.0212, 1.79), lat)
    reqs = make_request_stream(min(n_req, 4_000), lam=0.2, dist=uni,
                               vocab=100, seed=3)
    policies = default_policies()
    missing = set(REGISTRY) - {type(p).name for p in policies.values()}
    assert not missing, f"default_policies() misses registered: {missing}"
    routers = default_routers()
    missing_r = set(ROUTERS) - {type(r).name for r in routers.values()}
    assert not missing_r, f"default_routers() misses registered: {missing_r}"
    docs = _load_check_docs()
    doc_errors = (docs.check_policy_docs() + docs.check_predictor_docs()
                  + docs.check_router_docs() + docs.check_fault_docs()
                  + docs.check_traffic_docs() + docs.check_session_docs()
                  + docs.check_memory_docs())
    assert not doc_errors, doc_errors
    out = {}
    for name, pol in policies.items():
        sim = simulate_policy_fast(pol, 0.2, uni, lat,
                                   num_requests=n_req, seed=3)
        sch = summarize(pol.scheduler(clock).run(reqs))
        assert np.isfinite(sim["mean_wait"]), (name, "fast sim")
        assert np.isfinite(sch["mean_wait"]), (name, "scheduler")
        ana = pol.analytic_delay(0.2, uni, lat)
        out[name] = {"sim": sim["mean_wait"], "sched": sch["mean_wait"],
                     "analytic": ana}
    for pname, pcls in PREDICTORS.items():
        if pcls is LearnedPredictor:
            pred = LearnedPredictor().fit(uni, num_train=4_000, seed=0)
        elif pcls is PromptFeaturePredictor:
            pred = PromptFeaturePredictor.fitted_on(reqs)
        else:
            pred = pcls()
        pol = SRPTPolicy(b_max=8, predictor=pred)
        sim = simulate_policy_fast(pol, 0.2, uni, lat,
                                   num_requests=n_req, seed=3)
        sch = summarize(pol.scheduler(clock).run(reqs))
        assert np.isfinite(sim["mean_wait"]), (pname, "fast sim")
        assert np.isfinite(sch["mean_wait"]), (pname, "scheduler")
        out[f"predictor:{pname}"] = {"sim": sim["mean_wait"],
                                     "sched": sch["mean_wait"]}
    for rname, router in routers.items():
        sim = simulate_fleet_fast(router, DynamicPolicy(b_max=8), 0.4, 2,
                                  uni, lat, num_requests=n_req, seed=3)
        sch = summarize_fleet(FleetScheduler(
            router, DynamicPolicy(b_max=8), clock, 2).run(reqs))
        assert np.isfinite(sim["mean_wait"]), (rname, "fast fleet")
        assert np.isfinite(sch["mean_wait"]), (rname, "fleet scheduler")
        out[f"router:{rname}"] = {"sim": sim["mean_wait"],
                                  "sched": sch["mean_wait"]}
    # every registered fault model runs the fault-injected fleet
    # end-to-end on BOTH layers, and accounting must close — so a fault
    # model that stops running (or leaks requests) fails the build
    from repro.core.faults import default_faults, simulate_fleet_faulty
    for fname, fault in default_faults().items():
        for fast in (False, True):
            res = simulate_fleet_faulty(
                "round_robin", DynamicPolicy(b_max=8), 0.4, 2, uni, lat,
                fault, num_requests=min(n_req, 1_000), seed=3, fast=fast)
            assert np.isfinite(res["mean_wait"]), (fname, fast)
            assert (res["n_served"] + res["shed"] + res["failed"]
                    + res["unserved"] == res["n_arrived"]), (fname, fast)
        out[f"fault:{fname}"] = {"sim": res["mean_wait"],
                                 "served": res["n_served"]}
    # every registered traffic model (docs/traffic.md) runs both
    # simulator layers with oracle == fastsim trajectories, and its null
    # (zero-modulation) instance must stay bit-equal to the stationary
    # path — so a traffic model that stops running, diverges across
    # layers, or breaks stationary conformance fails the build
    from repro.core.simulate import simulate_policy
    from repro.core.traffic import default_traffic, null_traffic
    nulls = null_traffic()
    for tname, tm in default_traffic().items():
        o = simulate_policy(DynamicPolicy(b_max=8), 0.4, uni, lat,
                            num_requests=min(n_req, 1_000), seed=3,
                            traffic=tm)
        fsim = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                    num_requests=min(n_req, 1_000), seed=3,
                                    traffic=tm)
        np.testing.assert_allclose(o["waits"], fsim["waits"], atol=1e-9,
                                   err_msg=tname)
        base = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                    num_requests=min(n_req, 1_000), seed=3)
        null = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                    num_requests=min(n_req, 1_000), seed=3,
                                    traffic=nulls[tname])
        assert np.array_equal(base["waits"], null["waits"]), tname
        out[f"traffic:{tname}"] = {"sim": fsim["mean_wait"]}
    # every registered session model (docs/sessions.md) runs both
    # simulator layers with oracle == fastsim trajectories, and its NULL
    # (single-turn) instance must stay bit-equal to the session-free
    # path — so a feedback law that stops running, diverges across
    # layers, or breaks the null short-circuit fails the build
    from repro.core.sessions import default_sessions, null_sessions
    s_nulls = null_sessions()
    n_sess = min(n_req, 500)
    s_base = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                  num_requests=n_sess, seed=3)
    for sname, sm in default_sessions().items():
        o = simulate_policy(DynamicPolicy(b_max=8), 0.4, uni, lat,
                            num_requests=n_sess, seed=3, sessions=sm)
        fsim = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                    num_requests=n_sess, seed=3,
                                    sessions=sm)
        np.testing.assert_allclose(o["waits"], fsim["waits"], atol=1e-9,
                                   err_msg=sname)
        null = simulate_policy_fast(DynamicPolicy(b_max=8), 0.4, uni, lat,
                                    num_requests=n_sess, seed=3,
                                    sessions=s_nulls[sname])
        assert np.array_equal(s_base["waits"], null["waits"]), sname
        # null models short-circuit to the session-free result shape
        # (no "sessions" key) — that IS the conformance property
        sess = fsim.get("sessions")
        out[f"session:{sname}"] = {
            "sim": fsim["mean_wait"],
            "turns": n_sess if sess is None else sess["turns_arrived"]}
    # every registered batch-formation policy runs memory-gated (KV
    # budget, docs/memory.md) on both layers with oracle == fastsim
    # trajectories and a bit-exact infinite-budget short-circuit; the
    # non-batch disciplines must keep refusing a budget — so a policy
    # whose tandem admission breaks (or silently starts accepting a
    # budget it cannot honor) fails the build
    n_mem = min(n_req, 500)
    M = 4000.25
    for name, pol in policies.items():
        if pol.oracle_kind != "batches":
            try:
                simulate_policy_fast(pol, 0.2, uni, lat,
                                     num_requests=n_mem, seed=3, memory=M)
            except ValueError:
                out[f"memory:{name}"] = {"supported": False}
                continue
            raise AssertionError(f"{name} accepted a memory budget but "
                                 f"has no batch admission point")
        o = simulate_policy(pol, 0.2, uni, lat, num_requests=n_mem,
                            seed=3, memory=M)
        fsim = simulate_policy_fast(pol, 0.2, uni, lat, num_requests=n_mem,
                                    seed=3, memory=M)
        np.testing.assert_allclose(o["waits"], fsim["waits"], rtol=1e-6,
                                   atol=1e-9, err_msg=name)
        assert o["memory"]["blocked_batches"] == fsim["memory"][
            "blocked_batches"], name
        assert fsim["memory"]["kv_peak"] <= M, name
        m_base = simulate_policy_fast(pol, 0.2, uni, lat,
                                      num_requests=n_mem, seed=3)
        m_null = simulate_policy_fast(pol, 0.2, uni, lat,
                                      num_requests=n_mem, seed=3,
                                      memory=np.inf)
        assert np.array_equal(m_base["waits"], m_null["waits"]), name
        out[f"memory:{name}"] = {"supported": True,
                                 "sim": fsim["mean_wait"],
                                 "kv_peak": float(fsim["memory"]["kv_peak"])}
    return out


def main(quick: bool = False):
    from repro.core.bulk import dynamic_batching_bound, elastic_batching_bound
    from repro.core.distributions import LogNormalTokens, UniformTokens
    from repro.core.fastsim import sweep
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.core.policies import (
        DynamicPolicy, ElasticPolicy, MultiBinPolicy)
    from repro.data.pipeline import make_request_stream
    from repro.serving.metrics import summarize
    from repro.serving.scheduler import (
        DynamicBatchScheduler, ElasticBatchScheduler, ModelClock)

    uni = UniformTokens(1000)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=0.0005, k4=0.02)
    clock = ModelClock(LatencyModel(0.0212, 1.79), lat)
    n_req = 60_000 if quick else 150_000
    lams = [0.05, 0.1, 0.2, 0.4, 0.8]

    derived = {}
    gaps = []
    with timer() as t_all:
        # ------ registry coverage (CI gate: every policy end-to-end) ------
        cov = registry_coverage()
        derived["registry_policies"] = ",".join(sorted(cov))

        # ------ ref-vs-fast perf record (acceptance: fast >= 10x ref) ------
        # always at 200k requests; quick/CI mode trims the lambda grid so
        # the reference-loop half doesn't dominate the quick run
        n_perf = 200_000
        perf_lams = [0.2, 0.8] if quick else lams
        ref_waits, t_ref = _time_reference_loops(perf_lams, uni, lat, n_perf)
        fast_waits, t_cold, t_warm = _time_fast_sweep(perf_lams, uni, lat,
                                                      n_perf)
        for li, lam in enumerate(perf_lams):
            # fast must agree with the oracle on the same seed
            assert abs(fast_waits["dyn"][li] - ref_waits[("dyn", lam)]) < 1e-6
            assert abs(fast_waits["ela"][li] - ref_waits[("ela", lam)]) < 1e-6
        derived["sim_speedup_cold"] = t_ref / t_cold
        derived["sim_speedup_warm"] = t_ref / t_warm
        # keyed under the CURRENT PR: earlier PRs' committed baselines
        # (pr1_*, pr2_*) must never be overwritten by a re-run
        emit_bench("simulators", {
            "workload": f"{len(perf_lams)} lambdas x (dynamic, elastic), "
                        f"{n_perf} requests each",
            "reference_loops_s": t_ref,
            "fast_sweep_cold_s": t_cold,   # includes one-time XLA compile
            "fast_sweep_warm_s": t_warm,
            "speedup_cold": t_ref / t_cold,
            "speedup_warm": t_ref / t_warm,
        }, key="pr3_policy_core_perf")

        # ------ Fig 5 grid on the fast path (oracle-checked above) ------
        if n_req == n_perf and perf_lams == lams:
            grid = fast_waits
        else:
            grid = sweep({"dyn": DynamicPolicy(), "ela": ElasticPolicy()},
                         lams, uni, lat, num_requests=n_req, seed=3)
        for li, lam in enumerate(lams):
            d_mean = float(grid["dyn"][li])
            e_mean = float(grid["ela"][li])
            db = dynamic_batching_bound(uni, lat, lam)["wait_bound"]
            eb = elastic_batching_bound(uni, lat, lam)["wait_bound"]
            derived[f"dyn_sim_lam{lam}"] = d_mean
            derived[f"ela_sim_lam{lam}"] = e_mean
            derived[f"dyn_bound_lam{lam}"] = db
            gaps.append(d_mean - e_mean)
            assert db >= d_mean * 0.98, "bound violated"
            assert eb >= e_mean * 0.98, "bound violated"
        derived["elastic_advantage_grows_with_lam"] = bool(
            gaps[-1] > gaps[0])

        # ------ multi-bin batching vs dynamic/elastic (heavy tail) ------
        # lognormal(7,0.7) + Fig-6b constants: max-token padding dominates,
        # unbounded dynamic batching runs away at high load, and binning by
        # output length recovers most of elastic's win without early exits
        ln = LogNormalTokens(7.0, 0.7)
        ht = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
        mb_pols = {"dyn": DynamicPolicy(), "dyn_b32": DynamicPolicy(b_max=32),
                   "ela": ElasticPolicy(),
                   "multibin4": MultiBinPolicy(num_bins=4)}
        mb_lams = [0.5, 1.0]
        mb = sweep(mb_pols, mb_lams, ln, ht,
                   num_requests=30_000 if quick else 60_000, seed=15)
        for li, lam in enumerate(mb_lams):
            for name in mb_pols:
                derived[f"{name}_ht_lam{lam}"] = float(mb[name][li])
        # the Guldogan et al. effect: at high load multi-bin crushes padded
        # dynamic batching (bounded or not) and approaches elastic
        hi = len(mb_lams) - 1
        assert mb["multibin4"][hi] < 0.1 * mb["dyn"][hi]
        assert mb["multibin4"][hi] < 0.1 * mb["dyn_b32"][hi]
        derived["multibin_vs_elastic_ht_hi"] = float(
            mb["multibin4"][hi] / mb["ela"][hi])

        # ------ PR 3: WAIT / SRPT / optimized multi-bin (heavy tail) ------
        # same workload; the capped-FCFS batch (dyn_b16) goes unstable at
        # lam=1 while SRPT's shortest-first membership keeps the padded max
        # small, WAIT amortizes the per-batch overhead over >= k requests,
        # and the load-optimized boundaries trim multi-bin's tail bin
        from repro.core.bulk import optimize_bin_edges
        from repro.core.policies import SRPTPolicy, WaitPolicy
        n3 = 30_000 if quick else 60_000
        opt_edges = tuple(optimize_bin_edges(ln, ht, mb_lams[-1],
                                             num_bins=4))
        p3 = {"dyn_b16": DynamicPolicy(b_max=16),
              "wait_k16": WaitPolicy(k=16),
              "srpt_b16": SRPTPolicy(b_max=16),
              "multibin4_opt": MultiBinPolicy(edges=opt_edges)}
        t0 = time.perf_counter()
        r3 = sweep(p3, mb_lams, ln, ht, num_requests=n3, seed=15)
        t3 = time.perf_counter() - t0
        for li, lam in enumerate(mb_lams):
            for name in p3:
                derived[f"{name}_ht_lam{lam}"] = float(r3[name][li])
        # shortest-first rescues the capped batch at high load...
        assert r3["srpt_b16"][hi] < 0.1 * r3["dyn_b16"][hi]
        # ...and load-optimized boundaries don't lose to equal-mass ones
        assert r3["multibin4_opt"][hi] < mb["multibin4"][hi] * 1.02
        emit_bench("simulators", {
            "workload": f"lognormal(7,0.7) heavy tail, lams={mb_lams}, "
                        f"{n3} requests, Fig-6b latency constants",
            "policies": {name: repr(pol) for name, pol in p3.items()},
            "optimized_edges": list(opt_edges),
            "sweep_s": t3,
            "mean_wait": {name: [float(v) for v in r3[name]]
                          for name in p3},
            "mean_wait_baselines": {name: [float(v) for v in mb[name]]
                                    for name in mb_pols},
        }, key="pr3_wait_srpt_multibin")

        # scheduler cross-check at lam=0.2
        reqs = make_request_stream(min(n_req, 60_000), lam=0.2, dist=uni,
                                   vocab=100, seed=3)
        sd = summarize(DynamicBatchScheduler(clock).run(reqs))
        se = summarize(ElasticBatchScheduler(clock).run(reqs))
        derived["scheduler_dyn_lam0.2"] = sd["mean_wait"]
        derived["scheduler_ela_lam0.2"] = se["mean_wait"]

    emit("fig5_dynamic_vs_elastic", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
