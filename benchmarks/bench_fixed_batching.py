"""Paper Fig 6: fixed batching under heavy-tailed outputs (lognormal(7,0.7)).

6a: E[W] vs batch size b at lam=0.43 — paper Eq (25) as printed, our exact
    wait-until-b analysis (embedded chain + renewal-reward), and simulation.
    The transcription finding (EXPERIMENTS.md): Eq 25 tracks simulation only
    near the optimum; the exact analysis matches everywhere.
6b: dynamic batching capped at b_max vs unbounded at high arrival rate —
    the cap rescues heavy-tail runaway; elastic still beats both.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    from repro.core.bulk import (
        mdb1_wait_exact, mdb1_wait_paper, optimal_fixed_batch)
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.fastsim import (
        simulate_dynamic_batching_fast, simulate_fixed_batching_fast)
    from repro.core.simulate import simulate_fixed_batching

    ln = LogNormalTokens(7.0, 0.7)
    lat = BatchLatencyModel(k1=0.05, k2=0.5, k3=1e-5, k4=0.002)
    lam = 0.43
    n_req = 60_000 if quick else 200_000

    derived = {}
    with timer() as t_all:
        # ---- Fig 6a
        errs_exact = []
        for b in (2, 4, 8, 16, 24):
            h = float(lat.mean_batch_time(ln, b))
            exact = mdb1_wait_exact(lam, h, b)
            paper = mdb1_wait_paper(lam, h, b)
            sim = simulate_fixed_batching(
                lam, b, None, batch_time=lambda ns, hh=h: hh,
                num_requests=n_req, seed=4)["mean_wait"]
            sim_g = simulate_fixed_batching_fast(
                lam, b, ln, lat, num_requests=n_req, seed=4)["mean_wait"]
            derived[f"fig6a_b{b}_exact"] = exact
            derived[f"fig6a_b{b}_paperEq25"] = paper
            derived[f"fig6a_b{b}_sim_detH"] = sim
            derived[f"fig6a_b{b}_sim_randomH"] = sim_g
            errs_exact.append(abs(exact - sim) / max(sim, 0.2))
        derived["fig6a_exact_max_rel_err"] = float(max(errs_exact))
        fb = optimal_fixed_batch(ln, lat, lam, b_max=40, method="exact")
        derived["fig6a_b_star_exact"] = fb["b_star"]
        fb_p = optimal_fixed_batch(ln, lat, lam, b_max=40, method="paper")
        derived["fig6a_b_star_paper"] = fb_p["b_star"]

        # ---- Fig 6b: heavy-tail capping at high load
        lat2 = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
        lam_hi = 1.0
        unb = simulate_dynamic_batching_fast(lam_hi, ln, lat2,
                                             num_requests=n_req // 2, seed=5)
        cap = simulate_dynamic_batching_fast(lam_hi, ln, lat2, b_max=32,
                                             num_requests=n_req // 2, seed=5)
        ela = simulate_dynamic_batching_fast(lam_hi, ln, lat2, b_max=32,
                                             elastic=True,
                                             num_requests=n_req // 2, seed=5)
        derived["fig6b_unbounded_wait"] = unb["mean_wait"]
        derived["fig6b_capped32_wait"] = cap["mean_wait"]
        derived["fig6b_elastic32_wait"] = ela["mean_wait"]
        derived["fig6b_cap_gain"] = unb["mean_wait"] / max(cap["mean_wait"], 1e-9)
        derived["fig6b_elastic_beats_capped"] = bool(
            ela["mean_wait"] <= cap["mean_wait"] * 1.02)

    emit("fig6_fixed_batching", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
