# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (plus a roofline summary read from the dry-run artifacts).

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    from benchmarks import (
        bench_latency_model, bench_batch_scaling, bench_order_stats,
        bench_clipping, bench_batching_policies, bench_fixed_batching,
        bench_predictors, bench_fleet, bench_engine_e2e)

    print("name,us_per_call,derived")
    bench_latency_model.main(quick)       # Table I + Fig 2a
    bench_batch_scaling.main(quick)       # Fig 2b
    bench_order_stats.main(quick)         # Fig 3
    bench_clipping.main(quick)            # Fig 4
    bench_batching_policies.main(quick)   # Fig 5
    bench_fixed_batching.main(quick)      # Fig 6
    bench_predictors.main(quick)          # prediction-noise robustness
    bench_fleet.main(quick)               # fleet routing across replicas
    bench_engine_e2e.main(quick)          # beyond-paper engine E2E

    # roofline table (deliverable g) from the dry-run artifacts, if present
    try:
        from benchmarks.roofline import load_all, render_table
        rows = load_all("results/dryrun", "single")
        if rows:
            print("\n=== Roofline (single pod, baseline cells) ===")
            print(render_table(rows))
    except Exception as e:  # pragma: no cover
        print("roofline table unavailable:", e)


if __name__ == '__main__':
    main()
