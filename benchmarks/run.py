# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (plus a roofline summary read from the dry-run artifacts).

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _retry(step, quick: bool, attempts: int = 3, backoff: float = 2.0):
    """Run one bench step; in quick (CI) mode, retry transient failures
    with exponential backoff — shared-runner flakiness (timer jitter
    tripping a perf assertion, OOM from a neighbour) should not fail the
    whole suite.  Full local runs keep fail-fast semantics so a real
    regression is never masked by a retry."""
    if not quick:
        return step()
    for attempt in range(attempts):
        try:
            return step()
        except Exception as e:          # pragma: no cover - flake path
            if attempt + 1 == attempts:
                raise
            wait = backoff * (2.0 ** attempt)
            print(f"bench step {getattr(step, '__name__', step)!r} failed "
                  f"({type(e).__name__}: {e}); retry {attempt + 1}/"
                  f"{attempts - 1} in {wait:.0f}s", file=sys.stderr)
            time.sleep(wait)


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    from benchmarks import (
        bench_latency_model, bench_batch_scaling, bench_order_stats,
        bench_clipping, bench_batching_policies, bench_fixed_batching,
        bench_predictors, bench_fleet, bench_faults, bench_engine_e2e,
        bench_scale, bench_autoscale, bench_sessions, bench_memory)

    print("name,us_per_call,derived")
    steps = [
        bench_latency_model.main,       # Table I + Fig 2a
        bench_batch_scaling.main,       # Fig 2b
        bench_order_stats.main,         # Fig 3
        bench_clipping.main,            # Fig 4
        bench_batching_policies.main,   # Fig 5
        bench_fixed_batching.main,      # Fig 6
        bench_predictors.main,          # prediction-noise robustness
        bench_fleet.main,               # fleet routing across replicas
        bench_faults.main,              # fault tolerance / degradation
        bench_engine_e2e.main,          # beyond-paper engine E2E
        bench_scale.main,               # sharded sweeps + fused serving
        bench_autoscale.main,           # non-stationary traffic + control
        bench_sessions.main,            # re-entrant sessions / affinity
        bench_memory.main,              # KV budget / prefill-decode tandem
    ]
    for step in steps:
        _retry(lambda s=step: s(quick), quick)

    # roofline table (deliverable g) from the dry-run artifacts, if present
    try:
        from benchmarks.roofline import load_all, render_table
        rows = load_all("results/dryrun", "single")
        if rows:
            print("\n=== Roofline (single pod, baseline cells) ===")
            print(render_table(rows))
    except Exception as e:  # pragma: no cover
        print("roofline table unavailable:", e)


if __name__ == '__main__':
    main()
