"""PR 4: prediction-noise robustness of length-aware batching.

The SRPT and multi-bin wins measured in ``bench_batching_policies`` assume
the output length is knowable (an oracle predictor).  This bench quantifies
how those wins erode as the predictor degrades, under the paper's
heavy-tail workload (lognormal(7, 0.7) outputs, Fig-6b latency constants):

1. **Degradation curves**: mean wait over the (λ, σ) plane for SRPT and
   multi-bin driven by a multiplicative lognormal predictor of noise σ
   (``fastsim.sweep_noise``; the SRPT cells run as lanes of one vmapped
   batch-event loop).  WAIT threshold admission rides along as the
   control: its membership never reads lengths, so its curve must be flat
   in σ — any slope would mean the predictor column leaked somewhere it
   shouldn't.
2. **Learned head vs raw noisy observation**: a ridge head combining
   several noisy prompt-feature views (``predictors.LearnedPredictor``)
   against a single observation at the same per-feature noise
   (``lognormal_noise`` at σ = feature_noise) — lower log-RMSE and lower
   SRPT delay at matched observation error.
3. The σ=0 column must reproduce the oracle numbers exactly (same
   workload rng; the predictor stream is salted separately).

Recorded as the ``pr4_predictors`` key of ``BENCH_simulators.json``
(``emit_bench(..., key=...)`` — earlier PRs' keys are never replaced).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):          # direct `python bench_....py` run
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, emit_bench, timer


def main(quick: bool = False):
    from repro.core.distributions import LogNormalTokens
    from repro.core.fastsim import simulate_policy_fast, sweep_noise
    from repro.core.latency_model import BatchLatencyModel
    from repro.core.policies import MultiBinPolicy, SRPTPolicy, WaitPolicy
    from repro.core.predictors import (
        LearnedPredictor, LogNormalNoisePredictor, prediction_log_rmse)

    ln = LogNormalTokens(7.0, 0.7)
    ht = BatchLatencyModel(k1=0.05, k2=0.5, k3=2e-4, k4=0.002)
    lams = [0.6, 1.0]
    sigmas = [0.0, 0.25, 0.5, 1.0, 1.5]
    n_req = 12_000 if quick else 30_000
    seed = 15

    derived = {}
    with timer() as t_all:
        # ------ SRPT: vmapped (λ, σ) lanes of one batch-event loop ------
        t0 = time.perf_counter()
        srpt = sweep_noise(
            lambda s: SRPTPolicy(b_max=16,
                                 predictor=LogNormalNoisePredictor(s)),
            lams, sigmas, ln, ht, num_requests=n_req, seed=seed)
        t_srpt = time.perf_counter() - t0

        # ------ multi-bin: per-cell kernel dispatch (ragged bins) ------
        mb = sweep_noise(
            lambda s: MultiBinPolicy(num_bins=4,
                                     predictor=LogNormalNoisePredictor(s)),
            lams, sigmas, ln, ht, num_requests=n_req, seed=seed)

        # ------ WAIT: the prediction-INSENSITIVE control ------
        wait = sweep_noise(
            lambda s: WaitPolicy(k=16,
                                 predictor=LogNormalNoisePredictor(s)),
            lams, sigmas, ln, ht, num_requests=n_req, seed=seed)

        # σ=0 must reproduce the oracle column bit-for-bit
        for pol, grid in (("srpt", srpt), ("multibin", mb)):
            oracle_pol = (SRPTPolicy(b_max=16) if pol == "srpt"
                          else MultiBinPolicy(num_bins=4))
            for li, lam in enumerate(lams):
                ref = simulate_policy_fast(oracle_pol, lam, ln, ht,
                                           num_requests=n_req, seed=seed)
                assert abs(grid["mean_wait"][li, 0] - ref["mean_wait"]) \
                    < 1e-9, (pol, lam)
        # WAIT must be flat in σ (membership never reads lengths)
        assert np.allclose(wait["mean_wait"],
                           wait["mean_wait"][:, :1]), "WAIT saw predictions"
        # noise must cost SRPT delay at the heavy-tail operating point
        hi = len(lams) - 1
        assert srpt["mean_wait"][hi, -1] > srpt["mean_wait"][hi, 0]

        for li, lam in enumerate(lams):
            for si, s in enumerate(sigmas):
                derived[f"srpt_lam{lam}_sig{s}"] = float(
                    srpt["mean_wait"][li, si])
            derived[f"multibin_lam{lam}_sig{sigmas[-1]}"] = float(
                mb["mean_wait"][li, -1])

        # ------ learned head vs raw noisy observation ------
        feature_noise = 0.5
        learned = LearnedPredictor(feature_noise=feature_noise).fit(
            ln, num_train=10_000 if quick else 20_000, seed=0)
        raw = LogNormalNoisePredictor(sigma=feature_noise)
        rng = np.random.default_rng(123)
        held_out = np.maximum(ln.sample(rng, n_req).astype(np.float64), 1.0)
        rmse_learned = prediction_log_rmse(
            learned.predict(55, held_out), held_out)
        rmse_raw = prediction_log_rmse(raw.predict(55, held_out), held_out)
        w_learned = simulate_policy_fast(
            SRPTPolicy(b_max=16, predictor=learned), lams[-1], ln, ht,
            num_requests=n_req, seed=seed)["mean_wait"]
        w_raw = simulate_policy_fast(
            SRPTPolicy(b_max=16, predictor=raw), lams[-1], ln, ht,
            num_requests=n_req, seed=seed)["mean_wait"]
        assert rmse_learned < rmse_raw
        derived["learned_log_rmse"] = rmse_learned
        derived["raw_log_rmse"] = rmse_raw
        derived["srpt_wait_learned"] = float(w_learned)
        derived["srpt_wait_raw"] = float(w_raw)

    emit_bench("simulators", {
        "workload": f"lognormal(7,0.7) heavy tail, lams={lams}, "
                    f"sigmas={sigmas}, {n_req} requests, Fig-6b constants",
        "predictor": "lognormal_noise (multiplicative, mean-preserving)",
        "srpt_b16_mean_wait": srpt["mean_wait"].tolist(),
        "multibin4_mean_wait": mb["mean_wait"].tolist(),
        "wait_k16_mean_wait": wait["mean_wait"].tolist(),
        "srpt_sweep_s": t_srpt,
        "learned_vs_raw": {
            "feature_noise": feature_noise,
            "log_rmse": {"learned": rmse_learned, "raw": rmse_raw},
            "srpt_mean_wait": {"learned": float(w_learned),
                               "raw": float(w_raw)},
        },
    }, key="pr4_predictors")
    emit("predictor_robustness", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main(quick=os.environ.get("REPRO_BENCH_QUICK", "0") == "1")
