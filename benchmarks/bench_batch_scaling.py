"""Paper Fig 2b: throughput and token-generation time vs batch size; fits
the batched latency model H[b, l] = k1*b + k2 + (k3*b + k4)*l (Eq 18)."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, timer


def main(quick: bool = False):
    from repro.configs import get_smoke_config
    from repro.core.latency_model import fit_batch_latency_model
    from repro.serving.engine import Engine, EngineConfig

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), num_layers=2)
    eng = Engine(cfg, EngineConfig(max_batch=8, max_seq=256, prompt_bucket=16))

    rows = []   # (b, l, seconds)
    thr = {}
    with timer() as t_all:
        for b in (1, 2, 4, 8):
            for l in (8, 32, 64):
                prompts = [np.arange(8, dtype=np.int32) + i for i in range(b)]
                eng.generate(prompts, [l] * b)          # warmup/compile
                res = eng.generate(prompts, [l] * b)
                rows.append((b, l, res["batch_seconds"]))
                thr[(b, l)] = b * l / res["batch_seconds"]

    bs = np.array([r[0] for r in rows], np.float64)
    ls = np.array([r[1] for r in rows], np.float64)
    ts = np.array([r[2] for r in rows], np.float64)
    blat = fit_batch_latency_model(bs, ls, ts)
    pred = blat.batch_time(bs, ls)
    rel_err = float(np.abs(pred - ts).mean() / ts.mean())

    # paper's qualitative claim: throughput increases with batch size
    thr_increasing = bool(thr[(1, 64)] < thr[(2, 64)] < thr[(4, 64)]
                          < thr[(8, 64)])

    derived = {
        "k1": blat.k1, "k2": blat.k2, "k3": blat.k3, "k4": blat.k4,
        "fit_rel_err": rel_err,
        "throughput_b1_l64": thr[(1, 64)],
        "throughput_b8_l64": thr[(8, 64)],
        "throughput_increases_with_b": thr_increasing,
    }
    emit("fig2b_batch_scaling", t_all.seconds, derived)
    return derived


if __name__ == "__main__":
    main()
