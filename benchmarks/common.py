"""Shared helpers for the paper-figure benchmarks. Each bench prints
``name,us_per_call,derived`` CSV rows (harness contract) plus a human table,
and returns a dict consumed by EXPERIMENTS.md generation."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")
BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def emit_bench(name: str, payload: dict, key: str = None) -> str:
    """Write a tracked perf record to benchmarks/BENCH_<name>.json.

    Unlike ``emit`` (results/ scratch dir), these files are committed so the
    seed-vs-PR perf trajectory is reviewable in git history. Callers should
    include the timing baseline being compared against (e.g. the reference
    simulator loops, per-step decode) and the measured speedup.

    With ``key`` the record EXTENDS the existing file instead of replacing
    it: the file becomes a {run_label: payload} map and only ``key`` is
    updated, so earlier PRs' baselines stay reviewable in the same file."""
    path = os.path.join(BENCH_DIR, f"BENCH_{name}.json")
    if key is not None:
        record = {}
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
            if existing and all(isinstance(v, dict)
                                for v in existing.values()):
                record = existing                      # already a keyed map
            else:
                record = {"pr1_baseline": existing}    # migrate legacy flat
        record[key] = payload
        payload = record
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
        f.write("\n")
    return path


def emit(name: str, seconds: float, derived: dict) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = {"name": name, "us_per_call": seconds * 1e6, "derived": derived}
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(row, f, indent=1, default=float)
    compact = ";".join(f"{k}={_fmt(v)}" for k, v in list(derived.items())[:8])
    print(f"{name},{seconds*1e6:.1f},{compact}")
    return row


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
