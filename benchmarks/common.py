"""Shared helpers for the paper-figure benchmarks. Each bench prints
``name,us_per_call,derived`` CSV rows (harness contract) plus a human table,
and returns a dict consumed by EXPERIMENTS.md generation."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")


def emit(name: str, seconds: float, derived: dict) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = {"name": name, "us_per_call": seconds * 1e6, "derived": derived}
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(row, f, indent=1, default=float)
    compact = ";".join(f"{k}={_fmt(v)}" for k, v in list(derived.items())[:8])
    print(f"{name},{seconds*1e6:.1f},{compact}")
    return row


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
