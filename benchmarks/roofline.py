"""Roofline analysis (deliverable g): three terms per (arch x shape) cell
from the dry-run artifacts in results/dryrun/.

  compute term    = per-chip HLO FLOPs / 197 TFLOP/s (bf16 MXU peak, v5e)
  memory term     = per-chip HLO bytes / 819 GB/s (HBM BW, v5e)
  collective term = per-chip wire bytes per ICI axis / (2 links x 50 GB/s)
                    (2 = bidirectional ring along one torus dimension; the
                    assignment's coarser bytes/(chips*link_bw) is also shown)

Per-chip FLOPs/bytes come from the trip-count-corrected HLO parser
(repro.utils.hlo), NOT from compiled.cost_analysis(), which counts scan
bodies once (see EXPERIMENTS.md SDry-run). MODEL_FLOPS = 6*N_active*D
(training) or 2*N_active*D (inference).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
LINKS_PER_AXIS = 2           # bidirectional ring along one torus dim


def axis_of_stride(stride: int, mesh: str) -> str:
    if mesh == "multi" and stride >= 256:
        return "pod"
    return "data" if stride >= 16 else "model"


def analyze_record(rec: dict) -> dict:
    hc = rec["hlo_cost"]
    n_dev = rec["num_devices"]
    flops_dev = hc["flops"]
    bytes_dev = hc["bytes_accessed"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    by_axis = {}
    for stride, b in hc["wire_bytes_by_stride"].items():
        ax = axis_of_stride(int(float(stride)), rec["mesh"])
        by_axis[ax] = by_axis.get(ax, 0.0) + b
    coll_s = sum(b / (LINKS_PER_AXIS * LINK_BW) for b in by_axis.values())
    coll_s_assignment = hc["collective_wire_bytes"] / LINK_BW
    model_flops_dev = rec["model_flops_total"] / n_dev
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": rec["status"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "collective_s_assignment": coll_s_assignment,
        "collective_by_axis": by_axis,
        "dominant": dominant,
        "step_time_bound_s": bound,
        "model_flops_total": rec["model_flops_total"],
        "useful_ratio": model_flops_dev / max(flops_dev, 1e-30),
        "mfu_bound": (model_flops_dev / PEAK_FLOPS) / max(bound, 1e-30),
        "temp_gib": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0) / 2 ** 30,
        "args_gib": rec.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) / 2 ** 30,
        "tokens_per_step": rec["tokens_per_step"],
    }


def load_all(out_dir: str = "results/dryrun", mesh: str = "single",
             tag: str = "") -> list:
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for f in sorted(glob.glob(os.path.join(out_dir, "*" + suffix))):
        rec = json.load(open(f))
        if tag == "" and rec.get("tag"):
            continue
        if rec["status"] == "ok":
            rows.append(analyze_record(rec))
        elif rec["status"] == "skipped_by_design":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": "skipped"})
    return rows


def render_table(rows: list) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'dominant':>10s} {'useful':>7s} {'MFU-bnd':>8s} "
           f"{'temp(GiB)':>10s}")
    lines = [hdr, "-" * len(hdr)]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["status"] == "skipped":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{'skipped (full attention @500k)':>60s}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']*1e3:9.2f} "
            f"{r['memory_s']*1e3:9.2f} {r['collective_s']*1e3:9.2f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
            f"{r['mfu_bound']:8.3f} {r['temp_gib']:10.2f}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = load_all(args.out, args.mesh, args.tag)
    print(render_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
