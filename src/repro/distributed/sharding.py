"""Logical-axis sharding rules (GSPMD via pjit/NamedSharding).

Every parameter/activation dimension in the model zoo carries a *logical*
axis name.  A rule table maps logical names onto mesh axes; resolution checks
divisibility against the actual dim size and silently falls back to
replication when a dim cannot shard (e.g. 4 KV heads on a 16-way model axis).

Rules may map one logical name onto a *tuple* of mesh axes (e.g. ``batch ->
("pod", "data")``); axes missing from the mesh are dropped, so the same rule
table serves the single-pod (data, model) and multi-pod (pod, data, model)
meshes unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis vocabulary used by the model zoo:
#   batch     request/example dim                      -> DP (pod, data)
#   seq       sequence dim of activations              -> unsharded by default
#   kv_seq    KV-cache sequence dim (decode)           -> model (flash-decoding)
#   embed     d_model dim                              -> unsharded (or data for FSDP)
#   ffn       FFN hidden dim                           -> TP (model)
#   heads     query heads                              -> TP (model)
#   kv_heads  KV heads                                 -> TP (model; replicates if < axis)
#   head_dim  per-head dim                             -> unsharded
#   vocab     vocabulary dim                           -> TP (model)
#   experts   MoE expert dim                           -> EP (model)
#   conv_dim / ssm_state / ssm_heads / ssm_inner       Mamba dims
#   layers    stacked layer-group dim                  -> never sharded

AxisRules = dict


DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",
    "embed": None,
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",
    "expert_ffn": None,
    "moe_cap": "data",        # MoE dispatch-buffer capacity dim (token-like)
    "moe_groups": ("pod", "data"),   # GShard dispatch-group dim
    "conv_dim": "model",
    "ssm_heads": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "vis_seq": None,
    "layers": None,
}

# FSDP variant for >=70B configs: weights additionally sharded over `data`
# on the embed dim, gradients reduce-scattered (ZeRO-3-ish via GSPMD).
FSDP_RULES: AxisRules = dict(
    DEFAULT_RULES,
    embed="data",
)

# Sequence-parallel variant used for very long prefill: activations shard
# their seq dim over `model` between attention blocks.
SEQPAR_RULES: AxisRules = dict(DEFAULT_RULES, seq="model")

# Sweep-cell sharding (repro.core.shardsweep): the stacked (λ, policy, σ,
# replica) lanes of a grid sweep partition over a 1-D "cells" mesh; every
# other sweep input (latency constants, shared trip counts) replicates.
SWEEP_RULES: AxisRules = {"lanes": "cells"}


def cells_mesh(devices=None) -> Mesh:
    """1-D mesh over all local devices for grid-cell data parallelism —
    the mesh ``repro.core.shardsweep`` shards sweep lanes over.  On a
    single-device host this is a size-1 mesh (the shard_map path still
    runs, bit-equal to the plain vmap); CI forces a 4-device CPU mesh via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``."""
    devs = jax.devices() if devices is None else list(devices)
    return Mesh(np.array(devs), ("cells",))


def _resolve(logical: Optional[str], rules: AxisRules, mesh: Mesh,
             dim_size: Optional[int]):
    if logical is None:
        return None
    target = rules.get(logical, None)
    if target is None:
        return None
    axes = target if isinstance(target, tuple) else (target,)
    # drop axes not present in this mesh (e.g. "pod" on the single-pod mesh)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    if dim_size is not None:
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if dim_size % total != 0:
            return None  # cannot shard evenly -> replicate
    return axes if len(axes) > 1 else axes[0]


def logical_to_spec(logical_axes, rules: AxisRules, mesh: Mesh,
                    shape=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    Guarantees no mesh axis is used twice (first occurrence wins).
    """
    used = set()
    entries = []
    for i, name in enumerate(logical_axes):
        dim = None if shape is None else shape[i]
        r = _resolve(name, rules, mesh, dim)
        if r is None:
            entries.append(None)
            continue
        axes = r if isinstance(r, tuple) else (r,)
        if any(a in used for a in axes):
            entries.append(None)
            continue
        used.update(axes)
        entries.append(r)
    # trim trailing Nones for cleanliness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def make_named_sharding(mesh: Mesh, logical_axes, rules: AxisRules = None,
                        shape=None) -> NamedSharding:
    rules = DEFAULT_RULES if rules is None else rules
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules, mesh, shape))


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Threaded through model code; applies sharding constraints when a mesh
    is present, and is a no-op in single-device smoke tests."""

    mesh: Optional[Mesh] = None
    rules: AxisRules = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    def c(self, x, *logical_axes):
        """Constrain activation ``x`` to the sharding implied by its logical axes."""
        if self.mesh is None or self.mesh.size == 1:
            return x
        assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
        spec = logical_to_spec(logical_axes, self.rules, self.mesh, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, logical_axes, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return make_named_sharding(self.mesh, logical_axes, self.rules, shape)


NULL_CTX = ShardCtx()
