from repro.distributed.sharding import (
    AxisRules,
    DEFAULT_RULES,
    ShardCtx,
    logical_to_spec,
    make_named_sharding,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "ShardCtx",
    "logical_to_spec",
    "make_named_sharding",
]
