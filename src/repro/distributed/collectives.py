"""Compressed cross-data-axis gradient reduction (shard_map).

``compressed_mean_rows``: int8-quantized tiled all_to_all (reduce-scatter
pattern) + dequant-mean + bf16 all_gather across one mesh axis. Wire bytes
per element: ~1B (int8 shards) + ~2B (bf16 gather) ~ 3B, vs 8B for a fp32
ring all-reduce — a 2.7x reduction on the DP gradient wire. Per-row scales;
the error-feedback residual is handled by ``training.compression`` at the
caller.

This is the distributed-optimization trick referenced in DESIGN.md §6,
validated numerically on a fake 8-device mesh (tests/test_collectives.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map


def _quantize_rows(x):
    """Per-row symmetric int8. x: [r, c] -> (int8 [r, c], scales [r, 1])."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_mean_rows(grads_by_device, mesh: Mesh, axis: str = "data"):
    """grads_by_device: global [n, size] array sharded P(axis) — row d is
    device d's local gradient vector (size divisible by n*128). Returns the
    same-shaped array whose every row is the cross-device mean, moved over
    the wire as int8 shards + a bf16 gather."""
    n = mesh.shape[axis]
    size = grads_by_device.shape[1]
    assert size % n == 0, (size, n)

    def body(local):                     # local: [1, size] (my gradient)
        chunks = local[0].astype(jnp.float32).reshape(n, size // n)
        q, s = _quantize_rows(chunks)
        # tiled all_to_all: chunk j of every device lands on device j
        q_t = jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
        s_t = jax.lax.all_to_all(s, axis, 0, 0, tiled=True)
        part = jnp.mean(q_t.astype(jnp.float32) * s_t, axis=0)  # [size/n]
        full = jax.lax.all_gather(part.astype(jnp.bfloat16), axis,
                                  tiled=True)                   # [size]
        return full.astype(jnp.float32)[None]

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=P(axis))(grads_by_device)
