"""Length predictors: the knowable-length assumption as a first-class knob.

Every length-aware discipline in this repo — SRPT's shortest-first
membership, multi-bin's routing, the paper's clipping analysis — assumes
the output token count of a request is knowable before it is served.  The
simulators so far realized that assumption with an *oracle*: the true
sampled length.  Multi-Bin Batching (Guldogan et al. 2024) analyzes
exactly how binning gains erode under prediction error, and AugServe
(2025) argues adaptive scheduling must be driven by *estimated* request
cost; this module makes the predictor an explicit, swappable component so
both effects are measurable.

A :class:`LengthPredictor` maps true lengths (and, on the serving layers,
prompts) to *predicted* lengths::

    predict(key, true_lengths, prompts=None) -> predicted_lengths

``key`` seeds the predictor's OWN rng stream (a salted
``np.random.SeedSequence``), deliberately separate from the workload rng:
the sampled arrivals/tokens of a :class:`repro.core.policies.Workload`
are bit-identical with or without a predictor, so the oracle predictor
reproduces the pre-predictor trajectories exactly and every layer that
derives predictions from the same ``(key, true_lengths)`` pair sees the
same predicted column.

The predicted-vs-true column convention (enforced across all four
layers — oracle, fastsim, scheduler, engine):

  * **membership / ordering** (who is in the batch, in what order, which
    bin) keys off ``predicted``;
  * **clipping and the service law** (``n_max``, ``H[b, max]`` padding,
    elastic completion) keep the TRUE lengths — the machine decodes what
    the request actually needs, not what the predictor guessed.

Registered predictors (``PREDICTORS``; docs/predictors.md is CI-gated to
mention every one):

  * ``oracle``           — predicted == true (PR 3 behavior, the default)
  * ``lognormal_noise``  — multiplicative mean-preserving lognormal error,
    the standard model for relative length-prediction error
  * ``additive_noise``   — Gaussian token-count error, floor at 1
  * ``bucket``           — quantile-bucket classifier with configurable
    accuracy (mimics the class-label predictors served in production:
    a correct bucket yields the bucket's representative length, a miss
    yields a uniformly random bucket's)
  * ``learned``          — a small learned head: ridge regression from
    noisy prompt features to log-length, trained on a sampled workload
    (``fit``).  The features are a synthetic observation model (K noisy
    views of the true log-length standing in for prompt signals); a real
    deployment would substitute an embedding of the prompt.
  * ``prompt_features``  — the real-prompt twin of ``learned``: the same
    ridge head, but over features computed from the actual prompt token
    arrays flowing through ``predict(key, true, prompts)`` (length stats
    + token-id statistics), trained on served (prompt, output-length)
    pairs (``fit_requests``).  The first predictor that never peeks at
    the true lengths.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Type

import numpy as np

# Salt for the predictor rng stream: keeps predictor noise independent of
# the workload stream sampled from the same user-facing seed.
_PRED_SALT = 0x9E3779B9

PREDICTORS: Dict[str, Type["LengthPredictor"]] = {}


def register_predictor(cls: Type["LengthPredictor"]) -> Type["LengthPredictor"]:
    PREDICTORS[cls.name] = cls
    return cls


def get_predictor(name: str, **kwargs) -> "LengthPredictor":
    return PREDICTORS[name](**kwargs)


def _key_rng(key) -> np.random.Generator:
    """Deterministic per-key rng, salted away from the workload stream.

    ``key`` is whatever the caller uses to identify the draw — the
    workload seed on the simulator layers, any int on the serving layers."""
    if isinstance(key, (tuple, list)):
        parts = [int(k) for k in key]
    else:
        parts = [int(key)]
    return np.random.default_rng(np.random.SeedSequence([_PRED_SALT] + parts))


class LengthPredictor:
    """Base predictor: ``predict`` returns one float64 predicted length per
    request.  Predictions must be positive (formation code may bin or sort
    them) but are otherwise unconstrained — they deliberately do NOT clip
    to ``n_max``; clipping belongs to the true-length column."""

    name = "base"

    def predict(self, key, true_lengths: np.ndarray,
                prompts: Optional[Sequence] = None) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items()
                if not k.startswith("_") and not isinstance(v, np.ndarray)}
        return f"{type(self).__name__}({keys})"


@register_predictor
class OraclePredictor(LengthPredictor):
    """Predicted == true.  The pre-predictor behavior of SRPT and
    multi-bin: trajectories are bit-equal to a policy with no predictor."""

    name = "oracle"

    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        return np.asarray(true_lengths, np.float64)


@register_predictor
class LogNormalNoisePredictor(LengthPredictor):
    """Multiplicative mean-preserving lognormal error:

        pred = true * exp(sigma * Z - sigma^2 / 2),   Z ~ N(0, 1)

    ``E[pred | true] = true`` for every request, so sigma moves ONLY the
    relative prediction error (log-RMSE == sigma), not the predicted
    load.  sigma=0 reproduces the oracle exactly.  ``bias`` shifts the
    log-prediction (systematic over/under-estimation)."""

    name = "lognormal_noise"

    def __init__(self, sigma: float = 0.3, bias: float = 0.0):
        self.sigma = float(sigma)
        self.bias = float(bias)

    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        true = np.asarray(true_lengths, np.float64)
        z = _key_rng(key).standard_normal(len(true))
        factor = np.exp(self.sigma * z - 0.5 * self.sigma ** 2 + self.bias)
        return np.maximum(true * factor, 1.0)


@register_predictor
class AdditiveNoisePredictor(LengthPredictor):
    """Additive Gaussian token-count error: pred = max(true + std*Z, 1).
    Unlike the multiplicative model, short requests are hit hardest in
    relative terms — the regime where SRPT's ordering is most fragile."""

    name = "additive_noise"

    def __init__(self, std: float = 50.0):
        self.std = float(std)

    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        true = np.asarray(true_lengths, np.float64)
        z = _key_rng(key).standard_normal(len(true))
        return np.maximum(true + self.std * z, 1.0)


@register_predictor
class BucketPredictor(LengthPredictor):
    """Quantile-bucket classifier with configurable accuracy.

    The request's true bucket (``num_buckets`` equal-mass buckets over the
    batch's empirical quantiles, or explicit ``edges``) is predicted with
    probability ``accuracy``; a miss predicts a uniformly random bucket.
    The predicted length is the bucket's representative (its median
    quantile), so even a perfect classifier (``accuracy=1``) quantizes —
    the granularity/accuracy trade-off of production length classifiers."""

    name = "bucket"

    def __init__(self, num_buckets: int = 8, accuracy: float = 0.9,
                 edges: Optional[Sequence[float]] = None):
        assert 0.0 <= accuracy <= 1.0
        self.num_buckets = int(num_buckets if edges is None
                               else len(edges) + 1)
        self.accuracy = float(accuracy)
        self.edges = None if edges is None else tuple(float(e) for e in edges)

    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        true = np.asarray(true_lengths, np.float64)
        B = self.num_buckets
        if self.edges is not None:
            edges = np.asarray(self.edges, np.float64)
        else:
            edges = np.quantile(true, np.arange(1, B) / B)
        # representative length per bucket: the bucket's median member
        reps = np.empty(B)
        bins_true = np.searchsorted(edges, true, side="left")
        for j in range(B):
            members = true[bins_true == j]
            if members.size:
                reps[j] = float(np.median(members))
            else:  # empty bucket: fall back to its lower edge
                reps[j] = float(edges[j - 1]) if j > 0 else 1.0
        rng = _key_rng(key)
        correct = rng.random(len(true)) < self.accuracy
        random_bin = rng.integers(0, B, len(true))
        bins = np.where(correct, bins_true, random_bin)
        return np.maximum(reps[bins], 1.0)


@register_predictor
class LearnedPredictor(LengthPredictor):
    """A small learned head: ridge regression from prompt features to
    log-length, trained on a sampled workload.

    The feature channel is a synthetic observation model standing in for
    prompt signals: ``n_features`` noisy views of the true log-length,
    each ``w_k * log(true) + feature_noise * Z`` with fixed weights
    ``w_k``, plus one pure-noise distractor.  The head never sees the
    true length at predict time — only the features — so its error floor
    is set by ``feature_noise``; combining K informative views and
    shrinking toward the training mean is what lets it beat a single
    noisy observation (``lognormal_noise`` at sigma=feature_noise) at
    matched per-feature error.  On the serving layers a real deployment
    would replace ``_features`` with an embedding of ``prompts``.

    Call :meth:`fit` (or construct via :meth:`fitted`) before predicting.
    """

    name = "learned"

    _WEIGHTS = (1.0, 0.6, 0.3)      # informative views of log(true)

    def __init__(self, feature_noise: float = 0.5, ridge: float = 1e-3):
        self.feature_noise = float(feature_noise)
        self.ridge = float(ridge)
        self._coef: Optional[np.ndarray] = None

    # ---------------- observation model ----------------
    def _features(self, true: np.ndarray, rng: np.random.Generator):
        logn = np.log(np.maximum(true, 1.0))
        cols = [np.ones_like(logn)]
        for w in self._WEIGHTS:
            cols.append(w * logn
                        + self.feature_noise * rng.standard_normal(len(logn)))
        cols.append(rng.standard_normal(len(logn)))       # distractor
        return np.stack(cols, axis=1)

    # ---------------- training ----------------
    def fit(self, dist, num_train: int = 20_000,
            seed: int = 0) -> "LearnedPredictor":
        """Train on a workload sampled from ``dist`` (a
        ``TokenDistribution``): features -> log(true), ridge-regularized."""
        rng = _key_rng((seed, 1))
        true = dist.sample(rng, num_train).astype(np.float64)
        true = np.maximum(true, 1.0)
        X = self._features(true, rng)
        y = np.log(true)
        A = X.T @ X + self.ridge * np.eye(X.shape[1])
        self._coef = np.linalg.solve(A, X.T @ y)
        return self

    @classmethod
    def fitted(cls, dist, num_train: int = 20_000, seed: int = 0,
               **kwargs) -> "LearnedPredictor":
        return cls(**kwargs).fit(dist, num_train=num_train, seed=seed)

    # ---------------- inference ----------------
    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        assert self._coef is not None, \
            "LearnedPredictor.predict before fit(); use LearnedPredictor.fitted"
        true = np.asarray(true_lengths, np.float64)
        X = self._features(np.maximum(true, 1.0), _key_rng(key))
        return np.maximum(np.exp(X @ self._coef), 1.0)


@register_predictor
class PromptFeaturePredictor(LengthPredictor):
    """A length predictor driven by REAL prompt-derived features — the
    first predictor whose ``prompts`` argument (already plumbed through
    ``predict(key, true, prompts)`` on every serving layer) is load-
    bearing.  Ridge regression from per-prompt features to log-length,
    reusing the :class:`LearnedPredictor` recipe but with an observation
    model the serving layers actually possess: the prompt token array.

    Features per prompt: [1, log1p(len), sqrt(len), mean token id (scaled)]
    — length carries the signal when the workload's prompt lengths
    correlate with output requirements
    (:func:`repro.data.pipeline.make_request_stream` with
    ``prompt_len_corr > 0``; real traces have exactly this shape), the id
    statistic is a cheap content stand-in.  Train on (prompt, observed
    output length) pairs with :meth:`fit_requests` — in production these
    are the completions the serving engine has already returned.

    ``predict`` never reads ``true_lengths`` (only their count): unlike
    the synthetic noise models, its information comes solely from the
    prompts.  Without prompts (the prompt-less simulator layers) or
    before fitting it falls back to the training marginal — a constant
    prediction, the honest no-information answer."""

    name = "prompt_features"

    def __init__(self, ridge: float = 1e-3):
        self.ridge = float(ridge)
        self._coef: Optional[np.ndarray] = None
        self._y_mean: float = np.log(256.0)     # unfitted fallback marginal

    # ---------------- observation model ----------------
    @staticmethod
    def _features(prompts) -> np.ndarray:
        lens = np.asarray([len(p) for p in prompts], np.float64)
        means = np.asarray([float(np.mean(p)) if len(p) else 0.0
                            for p in prompts], np.float64)
        return np.stack([np.ones_like(lens), np.log1p(lens), np.sqrt(lens),
                         means / 1000.0], axis=1)

    # ---------------- training ----------------
    def fit_requests(self, reqs) -> "PromptFeaturePredictor":
        """Train on served requests (``repro.data.pipeline.Request``):
        prompt features -> log observed output length."""
        X = self._features([r.prompt_tokens for r in reqs])
        y = np.log(np.maximum([r.target_output_tokens for r in reqs], 1.0))
        A = X.T @ X + self.ridge * np.eye(X.shape[1])
        self._coef = np.linalg.solve(A, X.T @ y)
        self._y_mean = float(np.mean(y))
        return self

    @classmethod
    def fitted_on(cls, reqs, **kwargs) -> "PromptFeaturePredictor":
        return cls(**kwargs).fit_requests(reqs)

    # ---------------- inference ----------------
    def predict(self, key, true_lengths, prompts=None) -> np.ndarray:
        n = len(true_lengths)
        if prompts is None or self._coef is None or len(prompts) < n:
            # no prompt signal: the training marginal (constant) — keeps
            # the prompt-less simulator layers running with honest
            # no-information predictions
            return np.full(n, max(float(np.exp(self._y_mean)), 1.0))
        return np.maximum(np.exp(self._features(prompts[:n]) @ self._coef),
                          1.0)


def prediction_log_rmse(pred: np.ndarray, true: np.ndarray) -> float:
    """Root-mean-square log error — the scale on which ``lognormal_noise``'s
    sigma lives, so predictor families are comparable at matched error."""
    pred = np.maximum(np.asarray(pred, np.float64), 1.0)
    true = np.maximum(np.asarray(true, np.float64), 1.0)
    return float(np.sqrt(np.mean((np.log(pred) - np.log(true)) ** 2)))


def predictor_from_spec(spec) -> LengthPredictor:
    """``LengthPredictor`` | name | ``{"kind": name, **params}`` -> instance."""
    if isinstance(spec, LengthPredictor):
        return spec
    if isinstance(spec, str):
        return get_predictor(spec)
    spec = dict(spec)
    return get_predictor(spec.pop("kind"), **spec)


def resolve_predictions(policy, predictor, key, true_lengths: np.ndarray,
                        prompts: Optional[Sequence] = None):
    """The predicted-length column for a request batch, resolved ONCE for
    every serving-layer consumer (``PolicyScheduler``,
    ``run_engine_schedule``, ``FleetScheduler``, ``run_fleet_schedule``):
    an explicit ``predictor`` (instance / registry name / spec dict)
    overrides the policy's own; None with no policy predictor means oracle
    semantics (formation falls back to the true lengths).  One definition
    so the layers cannot diverge on the convention."""
    if predictor is not None:
        return predictor_from_spec(predictor).predict(key, true_lengths,
                                                      prompts)
    return policy.predict_lengths(key, true_lengths, prompts)


__all__ = [
    "AdditiveNoisePredictor", "BucketPredictor", "LearnedPredictor",
    "LengthPredictor", "LogNormalNoisePredictor", "OraclePredictor",
    "PromptFeaturePredictor",
    "PREDICTORS", "get_predictor", "prediction_log_rmse",
    "predictor_from_spec", "register_predictor", "resolve_predictions",
]
