"""Non-stationary arrival processes: modulated traffic as a registry.

Every layer so far samples stationary Poisson(λ) arrivals.  Production
traffic from millions of users is diurnal and bursty; this module makes
the modulation a first-class *registered* component, mirroring the
policy / predictor / router / fault registries:

  * ``stationary`` — the null model: the historical Poisson(λ) stream,
    bit-identical to every earlier PR by construction (the warp is the
    identity and is never even applied).
  * ``sinusoid``   — diurnal rate λ(t) = λ·(1 + A·sin(2πt/period + φ)),
    |A| ≤ 1.  Amplitude 0 is the null model.
  * ``mmpp``       — Markov-modulated Poisson process: the rate
    multiplier is piecewise-constant over exponential state-dwell
    episodes (state k holds ~Exp(mean_dwell[k]), rate multiplier
    rates[k]); multipliers are normalized by the chain's stationary
    mean so the long-run rate is exactly λ.  All-equal rates is the
    null model.
  * ``trace``      — trace replay: piecewise-constant multipliers over
    explicit breakpoints, repeated cyclically with period ``period``
    and normalized by their time-average.  All-equal rates is the null
    model.

The time-rescaling construction
-------------------------------

An inhomogeneous Poisson process with rate λ(t) = λ·m(t), where the
multiplier m has long-run time-average 1, is EXACTLY a stationary
Poisson(λ) process pushed through the inverse integrated profile:

    P(t) = ∫₀ᵗ m(u) du          (slope-1 long run)
    a_i  = P⁻¹(s_i)             (s_i the stationary arrival times)

so every model here is implemented as a *warp* applied to the base
arrivals AFTER they are drawn in the exact historical rng call order.
Two consequences the conformance tests pin:

  * the workload PRNG stream is untouched — tokens / prompts /
    predictions are bit-identical with modulation on or off, only the
    arrival instants move (and not at all for a null model);
  * superposition transfers — warping R independent λ/R sub-streams
    through the SAME profile and merging is the modulated process at
    rate λ·m(t) with iid uniform replica marks, so
    ``RandomRouter.fleet_workload`` keeps its exact split construction.

Determinism: every random draw (MMPP dwell episodes) comes from
``np.random.default_rng`` on a ``SeedSequence`` salted with
``_TRAFFIC_SALT`` — a stream independent of the workload, predictor
(``_PRED_SALT``), router (``_ROUTE_SALT``) and fault (``_FAULT_SALT``)
streams.  The closed-loop controller's per-window shed draws live on
``_SHED_LANE`` of the same salt.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.policies import Workload

_TRAFFIC_SALT = 0x7AFF1C00
# key lanes inside the traffic stream, disjoint from model-internal lanes
_SHED_LANE = 2_000_003       # closed-loop admission shedding (control.py)


def _traffic_rng(seed, *lanes) -> np.random.Generator:
    parts = [int(k) for k in seed] if isinstance(seed, (tuple, list)) \
        else [int(seed)]
    return np.random.default_rng(np.random.SeedSequence(
        [_TRAFFIC_SALT] + parts + [int(x) for x in lanes]))


# ----------------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------------

TRAFFIC: Dict[str, type] = {}


def register_traffic(cls):
    TRAFFIC[cls.name] = cls
    return cls


def get_traffic(name: str, **kw) -> "TrafficModel":
    return TRAFFIC[name](**kw)


def traffic_from_spec(spec) -> "TrafficModel":
    """None -> stationary; instance passes through; registry name or
    ``{"name": ..., **params}`` dict constructs."""
    if spec is None:
        return StationaryTraffic()
    if isinstance(spec, TrafficModel):
        return spec
    if isinstance(spec, str):
        return get_traffic(spec)
    spec = dict(spec)
    return get_traffic(spec.pop("name"), **spec)


def default_traffic() -> Dict[str, "TrafficModel"]:
    """One representative instance per registered model — the set the
    conformance tests and registry-driven benchmarks iterate."""
    return {
        "stationary": StationaryTraffic(),
        "sinusoid": SinusoidTraffic(amplitude=0.6, period=400.0),
        "mmpp": MMPPTraffic(rates=(0.5, 2.0), mean_dwell=(200.0, 100.0)),
        "trace": TraceTraffic(times=(0.0, 100.0, 200.0, 300.0),
                              rates=(0.5, 1.5, 1.0, 2.0), period=400.0),
    }


def null_traffic() -> Dict[str, "TrafficModel"]:
    """A zero-modulation instance of every registered model — each must
    reproduce the stationary trajectories bit-exactly (``is_null`` short-
    circuits the warp to the identity)."""
    return {
        "stationary": StationaryTraffic(),
        "sinusoid": SinusoidTraffic(amplitude=0.0, period=100.0),
        "mmpp": MMPPTraffic(rates=(1.0, 1.0), mean_dwell=(50.0, 50.0)),
        "trace": TraceTraffic(times=(0.0, 50.0), rates=(2.0, 2.0),
                              period=100.0),
    }


# ----------------------------------------------------------------------------
# Piecewise-constant profile machinery (shared by mmpp / trace)
# ----------------------------------------------------------------------------

def _piecewise_cumulative(t: np.ndarray, starts: np.ndarray,
                          rates: np.ndarray) -> np.ndarray:
    """P(t) = ∫₀ᵗ m for a piecewise-constant multiplier: segment k is
    [starts[k], starts[k+1]) at rate rates[k] (last segment open-ended).
    ``starts[0]`` must be 0."""
    cum = np.concatenate(
        ([0.0], np.cumsum(rates[:-1] * np.diff(starts))))
    k = np.clip(np.searchsorted(starts, t, side="right") - 1,
                0, len(starts) - 1)
    return cum[k] + rates[k] * (t - starts[k])


def _piecewise_inverse(u: np.ndarray, starts: np.ndarray,
                       rates: np.ndarray) -> np.ndarray:
    """P⁻¹(u) for the same piecewise profile.  Requires rates > 0 (a
    zero-rate segment has no inverse image) and enough segments that the
    terminal cumulative mass covers max(u)."""
    cum = np.concatenate(
        ([0.0], np.cumsum(rates[:-1] * np.diff(starts))))
    k = np.clip(np.searchsorted(cum, u, side="right") - 1,
                0, len(starts) - 1)
    return starts[k] + (u - cum[k]) / rates[k]


# ----------------------------------------------------------------------------
# Models
# ----------------------------------------------------------------------------

class TrafficModel:
    """One arrival-rate modulation, defined once for every layer.

    The multiplier ``m(t)`` is normalized to long-run time-average 1, so
    the instantaneous rate is λ·m(t) and the long-run rate stays exactly
    λ — replica-count recommendations and analytic baselines keep their
    meaning.  ``warp`` is the whole integration surface: layers draw the
    historical stationary stream first, then push the arrival instants
    through ``P⁻¹`` (module docstring), touching no other rng draw."""

    name = "base"

    @property
    def is_null(self) -> bool:
        """True when the model is the stationary process (multiplier
        ≡ 1): the warp is skipped entirely, so the arrivals array is the
        SAME object the historical path produced — bit-equality to the
        PR 5/6/7 trajectories by construction."""
        raise NotImplementedError

    # -- profile (normalized multiplier units) --------------------------
    def rate(self, t, seed: int = 0) -> np.ndarray:
        """Multiplier m(t) (instantaneous arrival rate / λ)."""
        raise NotImplementedError

    def cumulative(self, t, seed: int = 0) -> np.ndarray:
        """P(t) = ∫₀ᵗ m(u) du; the expected arrival count in [0, t] is
        λ·P(t) (the property tests' integrated-rate invariant)."""
        raise NotImplementedError

    def warp(self, arrivals: np.ndarray, seed: int = 0) -> np.ndarray:
        """Map stationary Poisson arrival times onto the modulated
        process: a_i = P⁻¹(s_i).  Monotone, so order is preserved;
        identity (same object) for a null model."""
        raise NotImplementedError

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items() if v is not None}
        return f"{type(self).__name__}({keys})"


@register_traffic
class StationaryTraffic(TrafficModel):
    """The null model: plain Poisson(λ), multiplier ≡ 1."""

    name = "stationary"

    @property
    def is_null(self) -> bool:
        return True

    def rate(self, t, seed: int = 0):
        return np.ones_like(np.asarray(t, np.float64))

    def cumulative(self, t, seed: int = 0):
        return np.asarray(t, np.float64)

    def warp(self, arrivals, seed: int = 0):
        return arrivals


@register_traffic
class SinusoidTraffic(TrafficModel):
    """Diurnal modulation m(t) = 1 + A·sin(2πt/period + φ), |A| ≤ 1.

    P(t) = t − A·(period/2π)·(cos(2πt/period + φ) − cos φ) is strictly
    increasing (for |A| < 1); the warp inverts it by bisection on the
    bracket |P(t) − t| ≤ A·period/π, vectorized over all arrivals."""

    name = "sinusoid"

    def __init__(self, amplitude: float = 0.5, period: float = 200.0,
                 phase: float = 0.0):
        assert 0.0 <= amplitude <= 1.0, "need |amplitude| <= 1 (rate >= 0)"
        assert period > 0.0
        self.amplitude = float(amplitude)
        self.period = float(period)
        self.phase = float(phase)

    @property
    def is_null(self) -> bool:
        return self.amplitude == 0.0

    def rate(self, t, seed: int = 0):
        t = np.asarray(t, np.float64)
        w = 2.0 * np.pi / self.period
        return 1.0 + self.amplitude * np.sin(w * t + self.phase)

    def cumulative(self, t, seed: int = 0):
        t = np.asarray(t, np.float64)
        w = 2.0 * np.pi / self.period
        return t - (self.amplitude / w) * (np.cos(w * t + self.phase)
                                           - np.cos(self.phase))

    def warp(self, arrivals, seed: int = 0):
        if self.is_null:
            return arrivals
        u = np.asarray(arrivals, np.float64)
        slack = self.amplitude * self.period / np.pi + 1.0
        lo = u - slack
        hi = u + slack
        for _ in range(64):          # bracket/2^64 << float64 resolution
            mid = 0.5 * (lo + hi)
            below = self.cumulative(mid) < u
            lo = np.where(below, mid, lo)
            hi = np.where(below, hi, mid)
        return 0.5 * (lo + hi)


@register_traffic
class MMPPTraffic(TrafficModel):
    """Markov-modulated Poisson process.

    State k holds for ~Exp(mean_dwell[k]) (drawn on the salted traffic
    stream), during which the multiplier is rates[k]; the embedded chain
    alternates for two states and moves to a uniformly-drawn OTHER state
    for more — symmetric, so its stationary time-weights are
    ∝ mean_dwell and the normalizing constant is the dwell-weighted mean
    rate ⟨m⟩ = Σ dwell·rates / Σ dwell.  Episodes are generated lazily
    until they cover the requested time/mass horizon; a prefix is always
    reproduced bit-exactly, so one (seed) names one environment shared
    by every replica of a fleet."""

    name = "mmpp"

    def __init__(self, rates: Sequence[float] = (0.5, 2.0),
                 mean_dwell: Sequence[float] = (100.0, 100.0)):
        rates = tuple(float(r) for r in rates)
        mean_dwell = tuple(float(d) for d in mean_dwell)
        assert len(rates) == len(mean_dwell) >= 1
        assert all(r > 0.0 for r in rates), "state rates must be positive"
        assert all(d > 0.0 for d in mean_dwell)
        self.rates = rates
        self.mean_dwell = mean_dwell

    @property
    def is_null(self) -> bool:
        return max(self.rates) == min(self.rates)

    def _mean_rate(self) -> float:
        d = np.asarray(self.mean_dwell)
        return float(np.dot(d, self.rates) / d.sum())

    def _profile(self, seed: int, t_max: float, mass_max: float):
        """(starts, multipliers) covering both horizons.  One rng, one
        draw order: dwell then (K>2) next-state, per episode."""
        rng = _traffic_rng(seed)
        norm = self._mean_rate()
        K = len(self.rates)
        starts, mults = [0.0], []
        state, t, mass = 0, 0.0, 0.0
        while t <= t_max or mass <= mass_max:
            dwell = rng.exponential(self.mean_dwell[state])
            m = self.rates[state] / norm
            mults.append(m)
            t += dwell
            mass += m * dwell
            starts.append(t)
            if K == 1:
                state = 0
            elif K == 2:
                state = 1 - state
            else:
                step = int(rng.integers(1, K))
                state = (state + step) % K
        return np.asarray(starts[:-1]), np.asarray(mults)

    def rate(self, t, seed: int = 0):
        t = np.asarray(t, np.float64)
        tm = float(t.max()) if t.size else 0.0
        starts, mults = self._profile(seed, tm, 0.0)
        k = np.clip(np.searchsorted(starts, t, side="right") - 1,
                    0, len(starts) - 1)
        return mults[k]

    def cumulative(self, t, seed: int = 0):
        t = np.asarray(t, np.float64)
        tm = float(t.max()) if t.size else 0.0
        starts, mults = self._profile(seed, tm, 0.0)
        return _piecewise_cumulative(t, starts, mults)

    def warp(self, arrivals, seed: int = 0):
        if self.is_null:
            return arrivals
        u = np.asarray(arrivals, np.float64)
        um = float(u.max()) if u.size else 0.0
        starts, mults = self._profile(seed, 0.0, um)
        return _piecewise_inverse(u, starts, mults)


@register_traffic
class TraceTraffic(TrafficModel):
    """Trace replay: piecewise-constant multipliers over explicit
    breakpoints, repeated cyclically.  ``times`` are segment starts in
    [0, period) with ``times[0] == 0``; segment k runs [times[k],
    times[k+1]) at rates[k], the last to ``period``.  Multipliers are
    normalized by their time-average over one period, so replaying a
    measured rate trace preserves the configured long-run λ."""

    name = "trace"

    def __init__(self,
                 times: Sequence[float] = (0.0, 100.0, 200.0, 300.0),
                 rates: Sequence[float] = (0.5, 1.5, 1.0, 2.0),
                 period: Optional[float] = None):
        times = tuple(float(t) for t in times)
        rates = tuple(float(r) for r in rates)
        assert len(times) == len(rates) >= 1
        assert times[0] == 0.0, "trace breakpoints start at 0"
        assert all(b > a for a, b in zip(times, times[1:]))
        assert all(r > 0.0 for r in rates), "trace rates must be positive"
        if period is None:
            # last segment gets the mean breakpoint gap
            gap = (times[-1] - times[0]) / max(len(times) - 1, 1) or 1.0
            period = times[-1] + gap
        assert period > times[-1]
        self.times = times
        self.rates = rates
        self.period = float(period)

    @property
    def is_null(self) -> bool:
        return max(self.rates) == min(self.rates)

    def _norm(self):
        starts = np.asarray(self.times)
        widths = np.diff(np.concatenate((starts, [self.period])))
        mean = float(np.dot(widths, self.rates)) / self.period
        return starts, np.asarray(self.rates) / mean

    def rate(self, t, seed: int = 0):
        starts, mults = self._norm()
        frac = np.mod(np.asarray(t, np.float64), self.period)
        k = np.clip(np.searchsorted(starts, frac, side="right") - 1,
                    0, len(starts) - 1)
        return mults[k]

    def cumulative(self, t, seed: int = 0):
        starts, mults = self._norm()
        t = np.asarray(t, np.float64)
        cycles = np.floor(t / self.period)
        frac = t - cycles * self.period
        # normalized -> exactly `period` mass per cycle
        return cycles * self.period + _piecewise_cumulative(
            frac, np.concatenate((starts, [self.period])),
            np.concatenate((mults, [mults[0]])))

    def warp(self, arrivals, seed: int = 0):
        if self.is_null:
            return arrivals
        starts, mults = self._norm()
        u = np.asarray(arrivals, np.float64)
        cycles = np.floor(u / self.period)
        rem = u - cycles * self.period
        x = _piecewise_inverse(
            rem, np.concatenate((starts, [self.period])),
            np.concatenate((mults, [mults[0]])))
        return cycles * self.period + np.minimum(x, self.period)


# ----------------------------------------------------------------------------
# Workload integration
# ----------------------------------------------------------------------------

def warp_workload(wl: Workload, traffic, seed: int) -> Workload:
    """Push a sampled workload's arrivals through the traffic warp.
    Tokens and predictions are untouched (they ride separate salted
    streams); ``inter`` is recomputed from the warped arrivals.  A null
    model (or ``traffic=None``) returns ``wl`` unchanged — the SAME
    object, so stationary trajectories stay bit-equal."""
    tm = traffic_from_spec(traffic)
    if tm.is_null:
        return wl
    arr = tm.warp(wl.arrivals, seed)
    return dataclasses.replace(wl, arrivals=arr,
                               inter=np.diff(arr, prepend=0.0))


__all__ = [
    "MMPPTraffic", "SinusoidTraffic", "StationaryTraffic", "TRAFFIC",
    "TraceTraffic", "TrafficModel", "default_traffic", "get_traffic",
    "null_traffic", "register_traffic", "traffic_from_spec",
    "warp_workload",
]
