"""Output-token length distributions.

Token counts are discrete; every distribution exposes a pmf over the integer
grid ``0..support`` plus the derived quantities the paper's analysis needs:

  * clipped moments under a max-token limit ``n_max``            (Eqs 2-3)
  * the maximum order statistic E[L | batch size b]              (Eq 23)
  * sampling (for the event-driven simulator and the engine workloads)

Continuous families (lognormal / truncated Gaussian) are discretized by CDF
differences on integers, which is exactly how token counts realize them.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from scipy import stats


class TokenDistribution:
    """Base: subclasses fill ``self._pmf`` (numpy array over 0..support)."""

    name = "base"

    def __init__(self, pmf: np.ndarray):
        pmf = np.asarray(pmf, np.float64)
        pmf = np.clip(pmf, 0.0, None)
        s = pmf.sum()
        assert s > 0
        self._pmf = pmf / s
        self._cdf = np.cumsum(self._pmf)
        self._support = np.arange(len(pmf))

    # ------------------------------------------------------------------
    @property
    def pmf(self) -> np.ndarray:
        return self._pmf

    @property
    def cdf(self) -> np.ndarray:
        return self._cdf

    @property
    def support(self) -> np.ndarray:
        return self._support

    @property
    def max_tokens(self) -> int:
        return len(self._pmf) - 1

    def mean(self) -> float:
        return float((self._support * self._pmf).sum())

    def second_moment(self) -> float:
        return float((self._support.astype(np.float64) ** 2 * self._pmf).sum())

    def var(self) -> float:
        return self.second_moment() - self.mean() ** 2

    # ------------------------------------------------------------------
    # Paper Eqs (2)-(3): moments under max-token clipping
    def clipped_moments(self, n_max: int):
        """E[n_req], E[n_req^2] with outputs clipped at n_max."""
        n_max = int(n_max)
        if n_max >= self.max_tokens:
            return self.mean(), self.second_moment()
        n = self._support[:n_max]
        head_p = self._pmf[:n_max]
        tail = 1.0 - self._cdf[n_max - 1]
        m1 = float((n * head_p).sum() + n_max * tail)
        m2 = float((n.astype(np.float64) ** 2 * head_p).sum() + n_max ** 2 * tail)
        return m1, m2

    def clip(self, n_max: int) -> "TokenDistribution":
        """The distribution of min(N, n_max)."""
        n_max = int(n_max)
        if n_max >= self.max_tokens:
            return TokenDistribution(self._pmf.copy())
        pmf = self._pmf[: n_max + 1].copy()
        pmf[n_max] += 1.0 - self._cdf[n_max]
        return TokenDistribution(pmf)

    # ------------------------------------------------------------------
    # Paper Eq (23): E[L] = E[max of b iid draws]; discrete identity
    # E[L] = sum_{x>=0} (1 - F(x)^b).
    def max_order_stat_mean(self, b) -> np.ndarray:
        b = np.atleast_1d(np.asarray(b, np.float64))
        surv = 1.0 - self._cdf[None, :] ** b[:, None]
        out = surv.sum(axis=1)
        return out if out.size > 1 else float(out[0])

    def max_order_stat_limit(self, quantile: float = 1.0) -> float:
        """Upper bound used for linear envelopes: the (quantile-)max support."""
        if quantile >= 1.0:
            return float(self.max_tokens)
        return float(np.searchsorted(self._cdf, quantile))

    def sum_mean(self) -> float:
        return self.mean()

    # ------------------------------------------------------------------
    def sample(self, rng: np.random.Generator, size) -> np.ndarray:
        return rng.choice(len(self._pmf), size=size, p=self._pmf)

    def utility_after_clip(self, n_max: int) -> float:
        """Paper Eq (10): E[u | n_max], u = 1 if n<=n_max else 1-(n-n_max)/n."""
        n_max = int(n_max)
        if n_max >= self.max_tokens:
            return 1.0
        n = self._support[n_max + 1:]
        tail_p = self._pmf[n_max + 1:]
        head = self._cdf[n_max]
        u_tail = (1.0 - (n - n_max) / np.maximum(n, 1)) * tail_p
        return float(head + u_tail.sum())


# ----------------------------------------------------------------------------


class LogNormalTokens(TokenDistribution):
    """Heavy-tailed family used throughout the paper (log mean 7, log std 0.7)."""

    name = "lognormal"

    def __init__(self, log_mean: float = 7.0, log_std: float = 0.7,
                 support: int = 32768):
        self.log_mean, self.log_std = log_mean, log_std
        d = stats.lognorm(s=log_std, scale=np.exp(log_mean))
        grid = np.arange(support + 1, dtype=np.float64)
        cdf = d.cdf(grid + 0.5)
        pmf = np.diff(np.concatenate([[0.0], cdf]))
        pmf[-1] += 1.0 - cdf[-1]
        pmf[0] = 0.0   # zero-token replies don't occur
        super().__init__(pmf)


class UniformTokens(TokenDistribution):
    """Uniform 0..m (paper SIV-B1 / Fig 5)."""

    name = "uniform"

    def __init__(self, m: int = 1000, lo: int = 0):
        pmf = np.zeros(m + 1)
        pmf[lo:] = 1.0
        super().__init__(pmf)
        self.m = m


class TruncGaussianTokens(TokenDistribution):
    """Truncated Gaussian on [0, inf) (paper SIV-B2, Eqs 21-22)."""

    name = "trunc_gaussian"

    def __init__(self, mean: float = 800.0, std: float = 20.0,
                 support: int = None):
        support = int(support or (mean + 8 * std))
        a = (0.0 - mean) / std
        d = stats.truncnorm(a, np.inf, loc=mean, scale=std)
        grid = np.arange(support + 1, dtype=np.float64)
        cdf = d.cdf(grid + 0.5)
        pmf = np.diff(np.concatenate([[0.0], cdf]))
        pmf[-1] += 1.0 - cdf[-1]
        super().__init__(pmf)
        self.mu, self.sigma = mean, std


class DeterministicTokens(TokenDistribution):
    name = "deterministic"

    def __init__(self, n: int):
        pmf = np.zeros(n + 1)
        pmf[n] = 1.0
        super().__init__(pmf)


class GeometricTokens(TokenDistribution):
    """Memoryless discrete analogue of exponential service."""

    name = "geometric"

    def __init__(self, mean: float, support: int = None):
        p = 1.0 / mean
        support = int(support or mean * 12)
        n = np.arange(support + 1, dtype=np.float64)
        pmf = p * (1 - p) ** np.maximum(n - 1, 0)
        pmf[0] = 0.0
        super().__init__(pmf)


class EmpiricalTokens(TokenDistribution):
    """Built from observed output lengths (the control plane's estimator)."""

    name = "empirical"

    def __init__(self, samples, support: int = None):
        samples = np.asarray(samples, np.int64)
        support = int(support or samples.max())
        pmf = np.bincount(np.clip(samples, 0, support), minlength=support + 1)
        super().__init__(pmf.astype(np.float64))
