"""Optimal max-token limit and batch-size selection (paper §III-C, Eqs 10-13).

V1 (all users patient):     V1(n_max) = theta*E[u|n_max] - (1-theta)*E[W(n_max)]
V2 (impatient users):       V2(n_max) = theta*E[u|n_max] - (1-theta)*E[Wq(n_max)]
                                        - pi(n_max)*loss_cost

Note: the paper's Eq (11) prints "+(1-theta)E[W]"; a positive delay reward
contradicts Eq (10) and §V-B's discussion ("optimal limit decreases delay"),
so we implement the evident sign (-). Recorded in DESIGN.md §10.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import LatencyModel
from repro.core.mg1 import mg1_wait
from repro.core.impatience import dekok_tijms, exact_impatience


@dataclasses.dataclass(frozen=True)
class TokenLimitChoice:
    n_max: int
    objective: float
    utility: float
    wait: float
    loss_frac: float
    curve: dict


def optimize_token_limit_v1(dist: TokenDistribution, lat: LatencyModel,
                            lam: float, theta: float,
                            grid=None) -> TokenLimitChoice:
    """Paper Eqs (10)/(12)-(13) with patient users (M/G/1 wait)."""
    if grid is None:
        grid = np.unique(np.linspace(1, dist.max_tokens, 256).astype(int))
    utils, waits, vals = [], [], []
    for n in grid:
        u = dist.utility_after_clip(int(n))
        w = mg1_wait(dist, lat, lam, int(n)).wait
        utils.append(u)
        waits.append(w)
        vals.append(theta * u - (1.0 - theta) * (w if np.isfinite(w) else 1e12))
    i = int(np.argmax(vals))
    return TokenLimitChoice(
        n_max=int(grid[i]), objective=float(vals[i]), utility=float(utils[i]),
        wait=float(waits[i]), loss_frac=0.0,
        curve={"grid": np.asarray(grid), "objective": np.asarray(vals),
               "utility": np.asarray(utils), "wait": np.asarray(waits)})


def optimize_token_limit_v2(dist: TokenDistribution, lat: LatencyModel,
                            lam: float, theta: float, tau: float,
                            loss_cost: float, grid=None,
                            solver: str = "dekok") -> TokenLimitChoice:
    """Paper Eq (11): impatient users; pi and E[Wq] from the chosen solver
    ('dekok' = paper's interpolation, 'exact' = level-crossing)."""
    if grid is None:
        grid = np.unique(np.linspace(1, dist.max_tokens, 128).astype(int))
    fn = dekok_tijms if solver == "dekok" else exact_impatience
    utils, waits, losses, vals = [], [], [], []
    for n in grid:
        u = dist.utility_after_clip(int(n))
        r = fn(dist, lat, lam, tau, int(n))
        utils.append(u)
        waits.append(r.wq_all)
        losses.append(r.pi)
        vals.append(theta * u - (1.0 - theta) * r.wq_all - r.pi * loss_cost)
    i = int(np.argmax(vals))
    return TokenLimitChoice(
        n_max=int(grid[i]), objective=float(vals[i]), utility=float(utils[i]),
        wait=float(waits[i]), loss_frac=float(losses[i]),
        curve={"grid": np.asarray(grid), "objective": np.asarray(vals),
               "utility": np.asarray(utils), "wait": np.asarray(waits),
               "loss": np.asarray(losses)})
