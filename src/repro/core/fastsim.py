"""Compiled simulation kernels behind the batching-policy core (fast §V).

The NumPy event loops in :mod:`repro.core.simulate` stay the *reference
oracle*; this module re-derives them as compiled recursions so λ-grid
sweeps and policy search run 10-100x faster.  Dispatch is structural: every
:class:`repro.core.policies.BatchPolicy` names its kernel via
``policy.fast_kernel`` and the ``KERNELS`` table maps that name to an
implementation — policies without a compiled twin fall back to the oracle:

  * ``"mg1"``          — Lindley / workload recursion.  tau=None is the
    same closed-form cumulative-minimum as the reference; the impatience
    path becomes a ``lax.scan`` over the workload process (admit iff
    V < tau).
  * ``"batch_scan"``   — dynamic/elastic batch formation as a *per-request*
    scan with O(1) carry (start, count, token sum, token max); one scan
    step per request, ``vmap``-able across (λ, policy) lanes.
  * ``"fixed_cummax"`` — fully closed form: the free-time recursion
    F_k = max(F_{k-1}, A_k) + H_k telescopes to a running maximum.
  * ``"multibin"``     — per-bin FIFO queues + one shared server as a
    jitted ``lax.while_loop`` over batch events: per-bin head pointers,
    vmapped ``searchsorted`` for the waiting count, and a sparse-table
    (power-of-two window) range-max for the batch's padded token length.
    One iteration per BATCH, so high-load sweeps cost far fewer steps than
    requests.
  * ``"wait"``         — WAIT threshold admission (Dai et al. 2025) as a
    jitted ``lax.while_loop`` over batch events: the trigger is the k-th
    buffered arrival (or the head's timeout), membership via
    ``searchsorted``, padding via the shared sparse-table range max.
  * ``"srpt"``         — shortest-predicted-first batching as a
    ``lax.while_loop`` over a min-segment-tree keyed by (PREDICTED token,
    arrival) rank: 'leftmost rank with arrival <= start' is an O(log n)
    tree descent, so each batch pops its b_max shortest waiting requests
    in O(b_max log n).

Every kernel honors the predicted-vs-true column convention
(:mod:`repro.core.predictors`): membership/ordering inputs (SRPT's rank
order, multi-bin's bin assignment) come from ``Workload.predicted`` while
the service-law inputs (range-max tables, scan token carries) stay on the
true tokens.  ``sweep_noise(policy_factory, lam_grid, sigma_grid, ...)``
sweeps the (arrival rate, prediction noise) plane; SRPT cells are stacked
as lanes of ONE vmapped batch-event loop.

``sweep(policies, lam_grid, ...)`` is the uniform entry point: every
(λ, policy) combination whose policy rides the shared ``batch_scan``
kernel becomes a lane of ONE vmapped scan; the remaining policies dispatch
through ``KERNELS`` per cell.  ``simulate_policy_fast`` is the single-cell
twin.  Legacy entry points (``simulate_mg1_fast``, ...) wrap the same
kernels and keep their pre-refactor signatures.

The fleet layer (:mod:`repro.core.fleet`) rides the same kernels: every
kernel accepts a precomputed ``workload`` (a routed replica sub-stream,
padded to power-of-two shapes so nearby sizes share compiles), the
state-dependent routers' backlog recursion compiles to one ``lax.scan``
carrying the per-replica backlog vector (``backlog_route``), and
``simulate_fleet_fast`` is the fleet twin of the oracle's
``fleet.route_oracle``.

All absolute-time arithmetic runs under ``jax.experimental.enable_x64`` —
simulated clocks reach ~1e6 seconds where float32 ULP (~0.25 s) would swamp
the waits being measured.  Scans run with ``unroll=8``, which amortizes
XLA's per-iteration loop overhead on CPU while keeping compile time
sub-second.

Every kernel samples its workload through the policy's ``sample_workload``
— the *same* rng call order as the reference oracle — so equal seeds give
trajectory-level (not just moment-level) agreement; ``tests/test_fastsim.py``
and ``tests/test_policies.py`` pin this down.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    BatchPolicy, DynamicPolicy, ElasticPolicy, FCFSPolicy, FixedPolicy,
    policy_from_spec, single_from_batch)
from repro.core.simulate import (
    _warm, simulate_fixed_batching, simulate_policy)

_UNROLL = 8          # scan body replication (amortizes loop overhead on CPU)
_NEG = -1e30
_NO_CAP = 1e18       # "b_max=None" as a finite cap (inf would poison carries)

KERNELS: Dict[str, Callable] = {}


def kernel(name: str):
    """Register a compiled kernel; ``BatchPolicy.fast_kernel`` names it."""
    def deco(fn):
        KERNELS[name] = fn
        return fn
    return deco


def simulate_policy_fast(policy: BatchPolicy, lam: float,
                         dist: Optional[TokenDistribution], lat,
                         num_requests: int = 200_000, seed: int = 0,
                         workload=None, fault_trace=None,
                         traffic=None, sessions=None,
                         prefix_discount: float = 0.0, memory=None) -> dict:
    """Fast twin of :func:`repro.core.simulate.simulate_policy`: dispatch to
    the policy's compiled kernel, or fall back to the oracle when the
    policy has none (``fast_kernel=None``).

    ``workload`` overrides the policy's own sampling, exactly like the
    oracle twin's parameter — the fleet layer routes one stream and runs
    each replica's sub-workload through the unchanged kernels.  Kernels
    pad provided workloads to power-of-two lengths (sliced off the
    outputs) so replica sub-streams of nearby sizes share one compile.

    ``fault_trace`` injects failure epochs exactly like the oracle twin:
    the transform arithmetic is the SAME host-side code
    (``simulate._with_fault_trace``), only the inner fault-free run is
    the compiled kernel — so oracle and fastsim see bit-identical
    epochs and trajectory-equal faulty waits.

    ``traffic`` modulates the arrival rate exactly like the oracle
    twin's parameter: the HOST-side time-rescaling warp runs before the
    kernel sees the workload, so both layers simulate the identical
    modulated arrival instants; a null model never warps (the kernel
    keeps its internal sampling path, bit-equal to PR 5/6/7).

    ``sessions`` re-enters completed turns exactly like the oracle
    twin's parameter: the SAME feedback fixed point
    (:func:`repro.core.sessions.simulate_policy_sessions`) runs with the
    compiled kernels as the inner pass, so oracle ≡ fastsim under
    feedback is structural; a null model takes this exact code path.

    ``memory`` switches batch service to the prefill/decode tandem with
    KV-budget admission, exactly like the oracle twin's parameter: the
    dynamic (``batch_scan``, non-elastic) lane gets a compiled
    batch-event while_loop (``_tandem_loop``, bit-equal trajectories);
    elastic and the batch-event policies fall back to the tandem oracle
    the way ``fast_kernel=None`` policies always have.  A null budget
    takes this exact code path."""
    mem = None
    if memory is not None:
        from repro.core.memory import check_policy_supports_memory, \
            memory_from_spec
        mem = memory_from_spec(memory)
        if mem.is_null:
            mem = None
        else:
            check_policy_supports_memory(policy)
    if sessions is not None:
        from repro.core.sessions import (session_from_spec,
                                         simulate_policy_sessions)
        model = session_from_spec(sessions)
        if not model.is_null:
            if mem is not None:
                raise ValueError(
                    "sessions= x memory= is not supported: turn re-entry "
                    "holds KV across think times (a different occupancy "
                    "law); run the tandem on the expanded per-turn stream "
                    "instead")
            if workload is not None:
                raise ValueError("sessions= expands its own workload; "
                                 "pass lam/num_requests/seed instead of "
                                 "workload=")
            return simulate_policy_sessions(
                policy, lam, dist, lat, num_requests, seed, model,
                fault_trace=fault_trace, traffic=traffic,
                prefix_discount=prefix_discount, fast=True)
    if policy.uses_single_latency and isinstance(lat, BatchLatencyModel):
        lat = single_from_batch(lat)
    if traffic is not None:
        from repro.core.traffic import traffic_from_spec, warp_workload
        tm = traffic_from_spec(traffic)
        if not tm.is_null:
            wl = workload if workload is not None else \
                policy.sample_workload(lam, dist, num_requests, seed)
            workload = warp_workload(wl, tm, seed)
    if mem is not None:
        lane = policy.scan_lane()
        if lane is None or lane[0]:
            # elastic (per-request release times) and the batch-event
            # policies (non-contiguous membership) have no compiled
            # tandem twin yet: oracle fallback, traffic already applied
            return simulate_policy(policy, lam, dist, lat,
                                   num_requests=num_requests, seed=seed,
                                   workload=workload,
                                   fault_trace=fault_trace, memory=mem)
        if fault_trace is not None and not fault_trace.empty:
            from repro.core.simulate import _with_fault_trace
            wl = workload if workload is not None else \
                policy.sample_workload(lam, dist, num_requests, seed)
            return _with_fault_trace(
                lambda op_wl: _tandem_dynamic_kernel(
                    policy, lam, dist, lat, num_requests, seed, mem,
                    workload=op_wl),
                wl, fault_trace)
        return _tandem_dynamic_kernel(policy, lam, dist, lat, num_requests,
                                      seed, mem, workload=workload)
    if policy.fast_kernel is None:
        return simulate_policy(policy, lam, dist, lat,
                               num_requests=num_requests, seed=seed,
                               workload=workload, fault_trace=fault_trace)
    if fault_trace is not None and not fault_trace.empty:
        from repro.core.simulate import _with_fault_trace
        wl = workload if workload is not None else \
            policy.sample_workload(lam, dist, num_requests, seed)
        return _with_fault_trace(
            lambda op_wl: KERNELS[policy.fast_kernel](
                policy, lam, dist, lat, num_requests, seed, workload=op_wl),
            wl, fault_trace)
    return KERNELS[policy.fast_kernel](policy, lam, dist, lat,
                                       num_requests, seed, workload=workload)


# ----------------------------------------------------------------------------
# M/G/1 with deterministic impatience tau (workload recursion as a scan)
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _impatience_scan():
    def run(inter, service, tau):
        def step(v, xs):
            a, s = xs
            v = jnp.maximum(0.0, v - a)
            lost = v >= tau
            wait = jnp.where(lost, tau, v)
            v = jnp.where(lost, v, v + s)
            return v, (wait, lost)

        _, (waits, lost) = lax.scan(step, jnp.float64(0.0),
                                    (inter, service), unroll=_UNROLL)
        return waits, lost

    return jax.jit(run)


def _pad_pow2_1d(arr: np.ndarray, fill: float) -> np.ndarray:
    """Pad one row to the next power-of-two length (>= 2) so provided
    workloads of nearby sizes (fleet replica sub-streams) share one
    compiled shape; the padded tail is inert (arrivals at +inf never
    join/form batches) and is sliced off every output.  Thin single-row
    wrapper over the batch-event kernels' shared ``_pow2_rows`` layout
    helper."""
    return _pow2_rows([np.asarray(arr, np.float64)], fill)[0][0]


@kernel("mg1")
def _mg1_kernel(policy, lam, dist, lat, num_requests, seed,
                workload=None) -> dict:
    if policy.tau is None:
        # the reference tau=None path is already a closed-form vectorized
        # Lindley recursion — it IS the fast path.
        return simulate_policy(policy, lam, dist, lat,
                               num_requests=num_requests, seed=seed,
                               workload=workload)
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    n = len(wl.tokens)
    service = np.asarray(lat.service_time(wl.tokens), np.float64)
    # fleet sub-streams pad to power-of-two so replica sizes share one
    # compile; padded tail gaps are infinite, so wait=0, lost=False
    inter = _pad_pow2_1d(wl.inter, np.inf) if workload is not None \
        else np.asarray(wl.inter, np.float64)
    service = _pad_pow2_1d(service, 0.0) if workload is not None \
        else service
    with jax.experimental.enable_x64():
        waits, lost = _impatience_scan()(
            jnp.asarray(inter, jnp.float64),
            jnp.asarray(service, jnp.float64),
            jnp.float64(policy.tau))
        waits = np.asarray(waits)[:n]
        lost = np.asarray(lost)[:n]
    waits_w, lost_w = _warm(waits), _warm(lost)
    served = waits_w[~lost_w]
    return {
        "mean_wait": float(waits_w.mean()),
        "mean_wait_served": float(served.mean()) if served.size else 0.0,
        "loss_frac": float(lost_w.mean()),
        "p95_wait": float(np.percentile(waits_w, 95)),
        "waits": waits_w,
    }


def simulate_mg1_fast(lam: float, dist: TokenDistribution, lat: LatencyModel,
                      n_max: Optional[int] = None, tau: Optional[float] = None,
                      num_requests: int = 200_000, seed: int = 0) -> dict:
    """Drop-in fast twin of :func:`repro.core.simulate.simulate_mg1`."""
    return simulate_policy_fast(FCFSPolicy(n_max=n_max, tau=tau), lam, dist,
                                lat, num_requests=num_requests, seed=seed)


# ----------------------------------------------------------------------------
# Dynamic / elastic batching (per-request scan with O(1) forming-batch carry)
# ----------------------------------------------------------------------------

def _batching_core(arr, tok, k1, k2, k3, k4, elastic, b_max):
    """Per-request recursion. Carry = (start, count, sum, max) of the batch
    currently being formed; closing a batch advances the server-free time by
    its Eq-18 (padded) or Eq-26 (elastic) duration. Returns (per-request
    batch start times, per-request batch-close flags)."""

    def step(c, xs):
        a, t = xs
        t_cur, cnt, ssum, smax = c
        t_free = t_cur + jnp.where(
            elastic, k1 * cnt + k2 + k3 * ssum + k4 * smax,
            k1 * cnt + k2 + (k3 * cnt + k4) * smax)
        joins = (a <= t_cur) & (cnt < b_max)
        start_new = jnp.where(a >= t_free, a, t_free)
        t_cur = jnp.where(joins, t_cur, start_new)
        cnt = jnp.where(joins, cnt + 1.0, 1.0)
        ssum = jnp.where(joins, ssum + t, t)
        smax = jnp.where(joins, jnp.maximum(smax, t), t)
        return (t_cur, cnt, ssum, smax), (t_cur, ~joins)

    # cnt0 > b_max forces request 0 to "close" the empty batch; that bogus
    # close exactly offsets the last real batch, which never closes — so
    # sum(closed) equals the reference batch count.
    c0 = (jnp.float64(_NEG), b_max + 1.0, jnp.float64(0.0), jnp.float64(0.0))
    _, (starts, closed) = lax.scan(step, c0, (arr, tok), unroll=_UNROLL)
    return starts, closed


@functools.lru_cache(maxsize=None)
def _batching_scan(vmapped: bool):
    if vmapped:
        return jax.jit(jax.vmap(_batching_core,
                                in_axes=(0, 0, None, None, None, None, 0, 0)))
    return jax.jit(_batching_core)


def _batch_lane_stats(starts, closed, arrivals):
    starts = np.asarray(starts)
    nb = int(np.asarray(closed).sum())
    waits = starts - arrivals
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(len(starts) / max(nb, 1)),
        "waits": w,
    }


@kernel("batch_scan")
def _batch_scan_kernel(policy, lam, dist, lat, num_requests, seed,
                       workload=None) -> dict:
    elastic, b_max = policy.scan_lane()
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    n = len(wl.arrivals)
    # padded arrivals at +inf never join the forming batch; their bogus
    # singleton "batches" live past index n and are sliced off
    arr_p = _pad_pow2_1d(wl.arrivals, np.inf) if workload is not None \
        else wl.arrivals
    tok_p = _pad_pow2_1d(wl.tokens, 0.0) if workload is not None \
        else wl.tokens
    with jax.experimental.enable_x64():
        starts, closed = _batching_scan(False)(
            jnp.asarray(arr_p, jnp.float64),
            jnp.asarray(tok_p, jnp.float64),
            jnp.float64(lat.k1), jnp.float64(lat.k2),
            jnp.float64(lat.k3), jnp.float64(lat.k4),
            jnp.asarray(bool(elastic)),
            jnp.float64(b_max if b_max is not None else _NO_CAP))
        return _batch_lane_stats(np.asarray(starts)[:n],
                                 np.asarray(closed)[:n], wl.arrivals)


def simulate_dynamic_batching_fast(lam: float, dist: TokenDistribution,
                                   lat: BatchLatencyModel,
                                   b_max: Optional[int] = None,
                                   elastic: bool = False,
                                   n_max: Optional[int] = None,
                                   num_requests: int = 200_000,
                                   seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_dynamic_batching (same seeds =>
    trajectory-identical batch boundaries up to float rounding)."""
    cls = ElasticPolicy if elastic else DynamicPolicy
    return simulate_policy_fast(cls(n_max=n_max, b_max=b_max), lam, dist,
                                lat, num_requests=num_requests, seed=seed)


# ----------------------------------------------------------------------------
# Fixed batching (closed form — the recursion telescopes to a cummax)
# ----------------------------------------------------------------------------

@kernel("fixed_cummax")
def _fixed_kernel(policy, lam, dist, lat, num_requests, seed,
                  workload=None) -> dict:
    if "batch_time" in vars(policy):
        # an instance-level batch_time override cannot be vectorized:
        # delegate to the reference loop (same trajectory by construction)
        return simulate_policy(policy, lam, dist, lat,
                               num_requests=num_requests, seed=seed,
                               workload=workload)
    b = policy.b
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    n_served = (len(wl.arrivals) // b) * b    # provided workloads may be
    arrivals = wl.arrivals[:n_served]         # ragged (fleet sub-streams)
    tokens = wl.tokens[:n_served]
    arr_kb = arrivals.reshape(-1, b)
    h = np.asarray(lat.batch_time(b, tokens.reshape(-1, b).max(axis=1)),
                   np.float64)
    c = np.cumsum(h)
    # F_k = max(F_{k-1}, A_k) + H_k  =>  F_k - C_k = cummax_j(A_j - C_{j-1})
    free = np.maximum.accumulate(arr_kb[:, -1] - (c - h)) + c
    starts = free - h
    waits = (starts[:, None] - arr_kb).reshape(-1)
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "waits": w,
    }


def simulate_fixed_batching_fast(lam: float, b: int,
                                 dist: Optional[TokenDistribution],
                                 lat: Optional[BatchLatencyModel] = None,
                                 batch_time: Optional[Callable] = None,
                                 num_requests: int = 200_000,
                                 seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_fixed_batching. With an arbitrary
    ``batch_time`` callable the per-batch times cannot be vectorized, so that
    case delegates to the reference loop."""
    if batch_time is not None:
        return simulate_fixed_batching(lam, b, dist, lat,
                                       batch_time=batch_time,
                                       num_requests=num_requests, seed=seed)
    assert lat is not None
    return simulate_policy_fast(FixedPolicy(b=b), lam, dist, lat,
                                num_requests=num_requests, seed=seed)


# ----------------------------------------------------------------------------
# Batch-event loops (multi-bin / WAIT / SRPT): one while_loop step per BATCH
# ----------------------------------------------------------------------------

def _pow2_rows(values, pad):
    """Stack ragged rows into a (B, L) array with L the next power of two,
    padded with ``pad`` (the layout the batch-event kernels index)."""
    lens = np.array([len(v) for v in values], np.int32)
    L = max(1 << int(lens.max() - 1).bit_length(), 2)
    out = np.full((len(values), L), pad)
    for j, v in enumerate(values):
        out[j, :lens[j]] = v
    return out, lens, L


def _sparse_max_table(rows: np.ndarray) -> np.ndarray:
    """Sparse table for O(1) range max: table[k, j, i] = max rows[j, i:i+2^k].
    Rows must already be power-of-two length (``_pow2_rows``)."""
    B, L = rows.shape
    K = int(np.log2(L)) + 1
    table = np.empty((K, B, L))
    table[0] = rows
    for k in range(1, K):
        s = 1 << (k - 1)
        table[k, :, :L - s] = np.maximum(table[k - 1, :, :L - s],
                                         table[k - 1, :, s:])
        table[k, :, L - s:] = table[k - 1, :, L - s:]
    return table


# ----------------------------------------------------------------------------
# Multi-bin batching (jitted while_loop over batch events)
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _multibin_loop(B: int, L: int, K: int, M: int):
    """One iteration per BATCH: pick the non-empty bin with the earliest
    head arrival, count its waiting requests (vmapped searchsorted), pad
    the batch to its token range-max (sparse table), advance the server."""

    def run(arr_b, table, lens, k1, k2, k3, k4, b_max):
        def cond(c):
            return jnp.any(c[1] < lens)

        def row_search_right(j, v):
            # first index i in (sorted, inf-padded) row j with arr_b[j,i] > v
            def step(_, lohi):
                lo, hi = lohi
                mid = (lo + hi) // 2
                live = lo < hi
                right = live & (arr_b[j, mid] <= v)
                return (jnp.where(right, mid + 1, lo),
                        jnp.where(live & ~right, mid, hi))
            lo, _ = lax.fori_loop(0, L.bit_length() + 1, step,
                                  (jnp.int32(0), jnp.int32(L)))
            return lo

        def body(c):
            t_free, heads, nb, o_bin, o_lo, o_hi, o_start = c
            a_head = arr_b[jnp.arange(B), jnp.minimum(heads, L - 1)]
            a_head = jnp.where(heads < lens, a_head, jnp.inf)
            j = jnp.argmin(a_head).astype(jnp.int32)
            a = a_head[j]
            lo = heads[j]
            idle = a >= t_free
            hi_busy = jnp.minimum(row_search_right(j, t_free),
                                  jnp.minimum(lo + b_max, lens[j]))
            hi = jnp.where(idle, lo + 1, hi_busy)
            start = jnp.where(idle, a, t_free)
            m = hi - lo
            k = jnp.floor(jnp.log2(m.astype(jnp.float64))).astype(jnp.int32)
            p = jnp.left_shift(jnp.int32(1), k)
            rm = jnp.maximum(table[k, j, lo], table[k, j, hi - p])
            bf = m.astype(jnp.float64)
            h = k1 * bf + k2 + (k3 * bf + k4) * rm
            return (start + h, heads.at[j].set(hi), nb + 1,
                    o_bin.at[nb].set(j), o_lo.at[nb].set(lo),
                    o_hi.at[nb].set(hi), o_start.at[nb].set(start))

        init = (jnp.float64(0.0), jnp.zeros(B, jnp.int32), jnp.int32(0),
                jnp.zeros(M, jnp.int32), jnp.zeros(M, jnp.int32),
                jnp.zeros(M, jnp.int32), jnp.zeros(M, jnp.float64))
        t_free, heads, nb, o_bin, o_lo, o_hi, o_start = lax.while_loop(
            cond, body, init)
        return nb, o_bin, o_lo, o_hi, o_start

    return jax.jit(run)


@kernel("multibin")
def _multibin_kernel(policy, lam, dist, lat, num_requests, seed,
                     workload=None) -> dict:
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    arr, tok = wl.arrivals, wl.tokens
    n = len(arr)
    # bin ROUTING keys off the predicted column; the range-max table below
    # (the padded service law) stays on the true tokens
    bins = policy.bin_of(wl.predicted_or_true, dist)
    B = policy.num_bins
    members = [np.nonzero(bins == j)[0] for j in range(B)]
    arr_b, lens, L = _pow2_rows([arr[m] for m in members], np.inf)
    tok_b, _, _ = _pow2_rows([tok[m] for m in members], -np.inf)
    table = _sparse_max_table(tok_b)     # range max for the batch padding
    K = table.shape[0]
    b_max = np.int32(policy.b_max if policy.b_max is not None else L)
    # output buffers padded to a power of two: one compile serves every
    # nearby workload size (fleet replica sub-streams)
    M = max(1 << max(n - 1, 1).bit_length(), 2)
    with jax.experimental.enable_x64():
        nb, o_bin, o_lo, o_hi, o_start = _multibin_loop(B, L, K, M)(
            jnp.asarray(arr_b, jnp.float64), jnp.asarray(table, jnp.float64),
            jnp.asarray(lens, jnp.int32),
            jnp.float64(lat.k1), jnp.float64(lat.k2),
            jnp.float64(lat.k3), jnp.float64(lat.k4), b_max)
        nb = int(nb)
        o_bin = np.asarray(o_bin)[:nb]
        o_lo = np.asarray(o_lo)[:nb]
        o_hi = np.asarray(o_hi)[:nb]
        o_start = np.asarray(o_start)[:nb]
    starts_req = np.empty(n)
    for j, mem in enumerate(members):
        sel = o_bin == j
        starts_req[mem] = np.repeat(o_start[sel], (o_hi - o_lo)[sel])
    waits = starts_req - arr
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(n / max(nb, 1)),
        "waits": w,
    }


# ----------------------------------------------------------------------------
# WAIT threshold admission (jitted while_loop over batch events)
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _wait_loop(L: int, K: int, M: int):
    """One iteration per WAIT batch: the trigger is the k-th buffered
    arrival or the head's timeout expiry (whichever first); the batch is
    everything arrived by start (cap b_max), padded to its token range-max
    (sparse table)."""

    def run(arr, table, n, k, timeout, b_max, k1, k2, k3, k4):
        def cond(c):
            return c[1] < n

        def body(c):
            t_free, head, nb, o_lo, o_hi, o_start = c
            kth = arr[jnp.minimum(head + k - 1, n - 1)]
            trigger = jnp.minimum(kth, arr[head] + timeout)
            start = jnp.maximum(t_free, trigger)
            hi = jnp.searchsorted(arr, start, side="right").astype(jnp.int32)
            hi = jnp.minimum(jnp.minimum(hi, head + b_max), n)
            m = hi - head
            kk = jnp.floor(jnp.log2(m.astype(jnp.float64))).astype(jnp.int32)
            p = jnp.left_shift(jnp.int32(1), kk)
            rm = jnp.maximum(table[kk, 0, head], table[kk, 0, hi - p])
            bf = m.astype(jnp.float64)
            h = k1 * bf + k2 + (k3 * bf + k4) * rm
            return (start + h, hi, nb + 1, o_lo.at[nb].set(head),
                    o_hi.at[nb].set(hi), o_start.at[nb].set(start))

        init = (jnp.float64(0.0), jnp.int32(0), jnp.int32(0),
                jnp.zeros(M, jnp.int32), jnp.zeros(M, jnp.int32),
                jnp.zeros(M, jnp.float64))
        t_free, head, nb, o_lo, o_hi, o_start = lax.while_loop(
            cond, body, init)
        return nb, o_lo, o_hi, o_start

    return jax.jit(run)


@kernel("wait")
def _wait_kernel(policy, lam, dist, lat, num_requests, seed,
                 workload=None) -> dict:
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    arr, tok = wl.arrivals, wl.tokens
    n = len(arr)
    arr_p, _, L = _pow2_rows([arr], np.inf)
    tok_p, _, _ = _pow2_rows([tok], -np.inf)
    table = _sparse_max_table(tok_p)
    with jax.experimental.enable_x64():
        nb, o_lo, o_hi, o_start = _wait_loop(L, table.shape[0], L)(
            jnp.asarray(arr_p[0], jnp.float64),
            jnp.asarray(table, jnp.float64), jnp.int32(n),
            jnp.int32(policy.k),
            jnp.float64(policy.timeout if policy.timeout is not None
                        else np.inf),
            jnp.int32(policy.b_max if policy.b_max is not None else L),
            jnp.float64(lat.k1), jnp.float64(lat.k2),
            jnp.float64(lat.k3), jnp.float64(lat.k4))
        nb = int(nb)
        o_lo = np.asarray(o_lo)[:nb]
        o_hi = np.asarray(o_hi)[:nb]
        o_start = np.asarray(o_start)[:nb]
    waits = np.repeat(o_start, o_hi - o_lo) - arr     # batches are contiguous
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(n / max(nb, 1)),
        "waits": w,
    }


# ----------------------------------------------------------------------------
# SRPT shortest-predicted-first (jitted while_loop over a min-segment-tree)
# ----------------------------------------------------------------------------

def _srpt_core(L: int):
    """One iteration per SRPT batch.  Requests are laid out in rank order
    (PREDICTED token count, then arrival); a min-segment-tree over their
    arrival times (served leaves := +inf) answers 'leftmost rank with
    arrival <= start' in O(log L), which IS the shortest-predicted waiting
    request.  Each batch pops up to b_max such leaves (1 when the server
    was idle and the next arrival starts alone, exactly like dynamic
    batching).  ``tok_rank`` holds the TRUE token counts in rank order —
    the padded service law never sees predictions."""
    LOG = L.bit_length() - 1     # tree depth: root 1, leaves [L, 2L)

    def run(tree, tok_rank, n, b_max, k1, k2, k3, k4):
        def cond(c):
            return c[4] < n

        def body(c):
            t_free, tree, starts, nb, served = c
            root = tree[1]
            idle = root > t_free
            start = jnp.where(idle, root, t_free)
            cap = jnp.where(idle, jnp.int32(1), b_max)

            def pop_cond(s):
                tr, npop, _, _ = s
                return (npop < cap) & (tr[1] <= start)

            def pop_body(s):
                tr, npop, mx, st = s

                def down(_, i):
                    return jnp.where(tr[2 * i] <= start, 2 * i, 2 * i + 1)

                i = lax.fori_loop(0, LOG, down, jnp.int32(1))
                st = st.at[i - L].set(start)
                mx = jnp.maximum(mx, tok_rank[i - L])
                tr = tr.at[i].set(jnp.inf)

                def up(_, iv):
                    i2, tr2 = iv
                    i2 = i2 // 2
                    return i2, tr2.at[i2].set(
                        jnp.minimum(tr2[2 * i2], tr2[2 * i2 + 1]))

                _, tr = lax.fori_loop(0, LOG, up, (i, tr))
                return tr, npop + 1, mx, st

            tree, m, mx, starts = lax.while_loop(
                pop_cond, pop_body,
                (tree, jnp.int32(0), jnp.float64(-jnp.inf), starts))
            bf = m.astype(jnp.float64)
            h = k1 * bf + k2 + (k3 * bf + k4) * mx
            return (start + h, tree, starts, nb + 1, served + m)

        init = (jnp.float64(0.0), tree, jnp.zeros(L, jnp.float64),
                jnp.int32(0), jnp.int32(0))
        _, _, starts, nb, _ = lax.while_loop(cond, body, init)
        return starts, nb

    return run


@functools.lru_cache(maxsize=None)
def _srpt_loop(L: int):
    return jax.jit(_srpt_core(L))


@functools.lru_cache(maxsize=None)
def _srpt_loop_vmapped(L: int):
    """(lane, lane, shared...) vmap of the SRPT batch-event loop: every
    (λ, σ) cell of ``sweep_noise`` becomes one lane of a single jitted
    while_loop (lanes run until the slowest finishes, with masked bodies)."""
    return jax.jit(jax.vmap(
        _srpt_core(L), in_axes=(0, 0, None, None, None, None, None, None)))


def _srpt_rank_arrays(arr: np.ndarray, tok: np.ndarray, key: np.ndarray):
    """Host prep shared by the single-cell kernel and ``sweep_noise``:
    rank order by (predicted ``key``, arrival), power-of-two padded
    arrival/true-token rows, and the min-segment-tree over arrivals."""
    order = np.argsort(key, kind="stable")     # rank = (predicted, arrival)
    arr_rank, _, L = _pow2_rows([arr[order]], np.inf)
    tok_rank, _, _ = _pow2_rows([tok[order]], -np.inf)
    tree = np.full(2 * L, np.inf)
    tree[L:] = arr_rank[0]
    lvl, size = arr_rank[0], L
    while size > 1:
        lvl = np.minimum(lvl[0::2], lvl[1::2])
        size //= 2
        tree[size:2 * size] = lvl
    return order, tree, tok_rank[0], L


def _srpt_stats(starts_rank, nb, order, arr):
    n = len(arr)
    starts_req = np.empty(n)
    starts_req[order] = np.asarray(starts_rank)[:n]
    waits = starts_req - arr
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(n / max(int(nb), 1)),
        "waits": w,
    }


@kernel("srpt")
def _srpt_kernel(policy, lam, dist, lat, num_requests, seed,
                 workload=None) -> dict:
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    arr, tok = wl.arrivals, wl.tokens
    n = len(arr)
    order, tree, tok_rank, L = _srpt_rank_arrays(arr, tok,
                                                 wl.predicted_or_true)
    with jax.experimental.enable_x64():
        starts_rank, nb = _srpt_loop(L)(
            jnp.asarray(tree, jnp.float64),
            jnp.asarray(tok_rank, jnp.float64), jnp.int32(n),
            jnp.int32(policy.b_max if policy.b_max is not None else L),
            jnp.float64(lat.k1), jnp.float64(lat.k2),
            jnp.float64(lat.k3), jnp.float64(lat.k4))
        return _srpt_stats(starts_rank, nb, order, arr)


# ----------------------------------------------------------------------------
# Prefill/decode tandem with a KV-memory budget (repro.core.memory)
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _tandem_loop(L: int, K: int, M: int):
    """One iteration per BATCH of the memory-gated tandem, DYNAMIC
    formation only (contiguous membership + whole-batch release at decode
    end => one release-ledger event per batch, O(1) carry growth).  The
    admission arithmetic mirrors :func:`repro.core.memory.tandem_oracle`
    operation for operation — 'right'-sided release search, delayed start
    via a 'left' search over the release prefix sums, longest admissible
    prefix via a 'right' search over the footprint prefix sums — so the
    event ORDER (membership, deferrals, blocked counts) matches the
    oracle exactly and the clocks agree to float rounding (XLA may fuse
    multiply-adds the NumPy loop keeps separate)."""

    def run(arr, table, fp_cum, n, b_max, cap, k1, k2, k3, k4):
        def cond(c):
            return c[0] < n

        def body(c):
            (head, t_pf, t_dec, nb, blocked, blocked_t, deferred,
             rel_t, rel_cum, o_start, o_end, o_dend) = c
            a = arr[head]
            idle = a >= t_pf
            start0 = jnp.where(idle, a, t_pf)
            hi_busy = jnp.searchsorted(arr, t_pf,
                                       side="right").astype(jnp.int32)
            hi = jnp.where(idle, head + 1,
                           jnp.minimum(hi_busy, head + b_max))
            # -- releases banked by the candidate start ----------------
            r = jnp.searchsorted(rel_t, start0, side="right")
            target = cap + rel_cum[r]
            first = fp_cum[head + 1]
            fits = first <= target
            # delayed start: earliest release instant freeing `need`
            need = first - cap
            rs = jnp.searchsorted(rel_cum, need, side="left")
            start = jnp.where(fits, start0,
                              rel_t[jnp.maximum(rs - 1, 0)])
            r2 = jnp.searchsorted(rel_t, start, side="right")
            target = jnp.where(fits, target, cap + rel_cum[r2])
            blocked = blocked + jnp.where(fits, 0, 1)
            blocked_t = blocked_t + jnp.where(fits, 0.0, start - start0)
            # -- longest admissible prefix over the footprint cumsum ---
            e = jnp.searchsorted(fp_cum, target,
                                 side="right").astype(jnp.int32) - 1
            e = jnp.maximum(jnp.minimum(hi, e), head + 1)
            deferred = deferred + (hi - e)
            # -- tandem service ----------------------------------------
            m = e - head
            kk = jnp.floor(jnp.log2(m.astype(jnp.float64))).astype(jnp.int32)
            p = jnp.left_shift(jnp.int32(1), kk)
            rm = jnp.maximum(table[kk, 0, head], table[kk, 0, e - p])
            bf = m.astype(jnp.float64)
            pf = k1 * bf + k2
            h = k1 * bf + k2 + (k3 * bf + k4) * rm
            p_end = start + pf
            d_start = jnp.maximum(p_end, t_dec)
            d_end = d_start + (h - pf)    # same op order as stage_split
            return (e, p_end, d_end, nb + 1, blocked, blocked_t, deferred,
                    rel_t.at[nb].set(d_end),
                    rel_cum.at[nb + 1].set(fp_cum[e]),
                    o_start.at[nb].set(start), o_end.at[nb].set(e),
                    o_dend.at[nb].set(d_end))

        init = (jnp.int32(0), jnp.float64(0.0), jnp.float64(0.0),
                jnp.int32(0), jnp.int32(0), jnp.float64(0.0), jnp.int32(0),
                jnp.full(M, jnp.inf), jnp.full(M + 1, jnp.inf).at[0].set(0.0),
                jnp.zeros(M, jnp.float64), jnp.zeros(M, jnp.int32),
                jnp.zeros(M, jnp.float64))
        (head, t_pf, t_dec, nb, blocked, blocked_t, deferred,
         rel_t, rel_cum, o_start, o_end, o_dend) = lax.while_loop(
            cond, body, init)
        return nb, blocked, blocked_t, deferred, o_start, o_end, o_dend

    return jax.jit(run)


def _tandem_dynamic_kernel(policy, lam, dist, lat, num_requests, seed,
                           budget, workload=None) -> dict:
    """Compiled twin of the tandem oracle for the ``batch_scan`` lane
    (dynamic formation, padded decode).  Elastic and the batch-event
    policies (multibin/wait/srpt/fixed) release KV per REQUEST or form
    non-contiguous batches — their memory runs fall back to the oracle,
    like ``fast_kernel=None`` policies do."""
    from repro.core.memory import occupancy_stats
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    arr, tok = wl.arrivals, wl.tokens
    n = len(arr)
    fp = budget.footprint(tok)
    if n and float(fp.max()) > budget.capacity:
        raise ValueError(
            f"memory budget {budget.capacity} cannot hold the largest "
            f"single request (footprint {float(fp.max())}); no schedule "
            "exists")
    arr_p, _, L = _pow2_rows([arr], np.inf)
    tok_p, _, _ = _pow2_rows([tok], -np.inf)
    table = _sparse_max_table(tok_p)
    # prefix footprint sums on the HOST (np.cumsum accumulates in the same
    # sequential order as the oracle's running `A`), +inf beyond n so the
    # admission search never admits padded rows
    fp_cum = np.full(L + 1, np.inf)
    fp_cum[0] = 0.0
    fp_cum[1:n + 1] = np.cumsum(fp)
    M = max(1 << max(n - 1, 1).bit_length(), 2)
    with jax.experimental.enable_x64():
        nb, blocked, blocked_t, deferred, o_start, o_end, o_dend = \
            _tandem_loop(L, table.shape[0], M)(
                jnp.asarray(arr_p[0], jnp.float64),
                jnp.asarray(table, jnp.float64),
                jnp.asarray(fp_cum, jnp.float64), jnp.int32(n),
                jnp.int32(policy.b_max if policy.b_max is not None else L),
                jnp.float64(budget.capacity),
                jnp.float64(lat.k1), jnp.float64(lat.k2),
                jnp.float64(lat.k3), jnp.float64(lat.k4))
        nb = int(nb)
        o_start = np.asarray(o_start)[:nb]
        o_end = np.asarray(o_end)[:nb]
        o_dend = np.asarray(o_dend)[:nb]
    sizes = np.diff(o_end, prepend=0)
    starts_req = np.repeat(o_start, sizes)      # batches are contiguous
    comps_req = np.repeat(o_dend, sizes)
    waits = starts_req - arr
    w = _warm(waits)
    mem = occupancy_stats(starts_req, comps_req, fp, float(budget.capacity))
    mem["blocked_batches"] = int(blocked)
    mem["blocked_time"] = float(blocked_t)
    mem["deferred_requests"] = int(deferred)
    return {
        "mean_wait": float(w.mean()) if w.size else 0.0,
        "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
        "mean_batch": float(n / max(nb, 1)),
        "waits": w,
        "memory": mem,
    }


# ----------------------------------------------------------------------------
# Uniform sweep: one vmapped scan for every batch_scan lane, kernels for rest
# ----------------------------------------------------------------------------

def sweep(policies: dict, lam_grid, dist, lat,
          num_requests: int = 100_000, seed: int = 0,
          lane_scan: Optional[Callable] = None) -> dict:
    """Mean wait for each policy over an arrival-rate grid — the uniform
    fast entry point.  ``policies``: name -> BatchPolicy (or legacy spec
    dict).  Policies riding the shared per-request batching scan
    (``scan_lane() is not None``) are stacked as lanes of ONE vmapped scan;
    every other policy dispatches through ``KERNELS`` per (λ, policy) cell
    (falling back to the oracle when it has no compiled kernel).

    ``lane_scan`` overrides the vmapped lane executor (same signature and
    bit-identical per-lane semantics as ``_batching_scan(True)``) —
    :mod:`repro.core.shardsweep` passes its ``shard_map`` twin to spread
    the lanes over a device mesh."""
    lam_grid = list(lam_grid)
    insts = {name: (p if isinstance(p, BatchPolicy) else policy_from_spec(p))
             for name, p in policies.items()}
    lanes = []          # (name, lam_idx, elastic, b_max)
    out = {name: [None] * len(lam_grid) for name in insts}
    for name, pol in insts.items():
        lane = pol.scan_lane()
        if lane is not None and pol.n_max is None:
            for li in range(len(lam_grid)):
                lanes.append((name, li) + lane)
        else:
            for li, lam in enumerate(lam_grid):
                r = simulate_policy_fast(pol, lam, dist, lat,
                                         num_requests=num_requests, seed=seed)
                out[name][li] = r["mean_wait"]
    if lanes:
        arrs, toks = [], []
        for lam in lam_grid:
            wl = DynamicPolicy().sample_workload(lam, dist, num_requests,
                                                 seed)
            arrs.append(wl.arrivals)
            toks.append(wl.tokens)
        arr_l = np.stack([arrs[li] for _, li, _, _ in lanes])
        tok_l = np.stack([toks[li] for _, li, _, _ in lanes])
        elas = np.array([e for _, _, e, _ in lanes])
        bmax = np.array([float(bm) if bm is not None else _NO_CAP
                         for _, _, _, bm in lanes])
        scan = _batching_scan(True) if lane_scan is None else lane_scan
        with jax.experimental.enable_x64():
            starts, closed = scan(
                jnp.asarray(arr_l, jnp.float64),
                jnp.asarray(tok_l, jnp.float64),
                jnp.float64(lat.k1), jnp.float64(lat.k2),
                jnp.float64(lat.k3), jnp.float64(lat.k4),
                jnp.asarray(elas), jnp.asarray(bmax, jnp.float64))
            starts = np.asarray(starts)
            closed = np.asarray(closed)
        for row, (name, li, _, _) in enumerate(lanes):
            stats = _batch_lane_stats(starts[row], closed[row], arrs[li])
            out[name][li] = stats["mean_wait"]
    return {k: np.asarray(v) for k, v in out.items()}


def simulate_policy_sweep_fast(lam_grid, dist, lat, policies: dict,
                               num_requests: int = 100_000,
                               seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_policy_sweep (legacy argument order)."""
    return sweep(policies, lam_grid, dist, lat,
                 num_requests=num_requests, seed=seed)


# ----------------------------------------------------------------------------
# Noise-robustness sweep over the (arrival rate, prediction error) plane
# ----------------------------------------------------------------------------

def sweep_noise(policy_factory: Callable[[float], BatchPolicy], lam_grid,
                sigma_grid, dist, lat, num_requests: int = 50_000,
                seed: int = 0,
                srpt_loop: Optional[Callable] = None) -> dict:
    """Mean wait over the (λ, σ) grid: how a length-aware policy's win
    erodes as its predictor degrades.

    ``policy_factory(sigma)`` builds the policy at prediction-noise level
    ``sigma`` (typically with a
    :class:`repro.core.predictors.LogNormalNoisePredictor` of that sigma;
    sigma=0 must reproduce the oracle).  The workload stream per λ is
    identical across the σ row — the predictor rng is salted away from the
    workload rng — so the columns differ ONLY by prediction quality.

    When every produced policy rides the ``srpt`` kernel, all (λ, σ)
    cells become lanes of ONE vmapped jitted batch-event loop
    (``_srpt_loop_vmapped``); otherwise cells dispatch through
    ``simulate_policy_fast`` individually (multi-bin's per-bin row count
    varies with σ, so its kernel shapes cannot share a vmap).  Note the
    vmap trip count is the MAX over lanes (batch events, and pops within
    an event): lanes at loads where the server often idles (many
    singleton batches) drag every lane, so on CPU the single dispatch can
    cost more than per-cell calls — the lane layout pays off on
    accelerator backends where lanes are data-parallel, and keeps one
    compile for arbitrarily fine σ grids.

    ``srpt_loop`` overrides the vmapped lane executor factory (same
    ``L -> callable`` contract and bit-identical per-lane semantics as
    ``_srpt_loop_vmapped``) — :mod:`repro.core.shardsweep` passes its
    ``shard_map`` twin to spread the (λ, σ) lanes over a device mesh.

    Returns ``{"mean_wait": [len(lam_grid), len(sigma_grid)], "lams",
    "sigmas"}``.
    """
    lam_grid = [float(l) for l in lam_grid]
    sigma_grid = [float(s) for s in sigma_grid]
    pols = [policy_factory(s) for s in sigma_grid]
    out = np.empty((len(lam_grid), len(sigma_grid)))
    if all(p.fast_kernel == "srpt" for p in pols):
        b_maxes = {p.b_max for p in pols}
        assert len(b_maxes) == 1, "srpt lanes must share one b_max"
        b_max = b_maxes.pop()
        cells, trees, tok_ranks, orders, arrs = [], [], [], [], []
        L = None
        for li, lam in enumerate(lam_grid):
            for si, pol in enumerate(pols):
                wl = pol.sample_workload(lam, dist, num_requests, seed)
                order, tree, tok_rank, L = _srpt_rank_arrays(
                    wl.arrivals, wl.tokens, wl.predicted_or_true)
                cells.append((li, si))
                trees.append(tree)
                tok_ranks.append(tok_rank)
                orders.append(order)
                arrs.append(wl.arrivals)
        loop = _srpt_loop_vmapped if srpt_loop is None else srpt_loop
        with jax.experimental.enable_x64():
            starts, nbs = loop(L)(
                jnp.asarray(np.stack(trees), jnp.float64),
                jnp.asarray(np.stack(tok_ranks), jnp.float64),
                jnp.int32(num_requests),
                jnp.int32(b_max if b_max is not None else L),
                jnp.float64(lat.k1), jnp.float64(lat.k2),
                jnp.float64(lat.k3), jnp.float64(lat.k4))
            starts = np.asarray(starts)
            nbs = np.asarray(nbs)
        for c, (li, si) in enumerate(cells):
            out[li, si] = _srpt_stats(starts[c], nbs[c], orders[c],
                                      arrs[c])["mean_wait"]
    else:
        for li, lam in enumerate(lam_grid):
            for si, pol in enumerate(pols):
                r = simulate_policy_fast(pol, lam, dist, lat,
                                         num_requests=num_requests,
                                         seed=seed)
                out[li, si] = r["mean_wait"]
    return {"mean_wait": out, "lams": np.asarray(lam_grid),
            "sigmas": np.asarray(sigma_grid)}


# ----------------------------------------------------------------------------
# Fleet layer: jitted backlog routing + split-then-kernel per replica
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _backlog_scan(R: int):
    """The state-dependent routing recursion (jsq / least_work) as one
    ``lax.scan`` over arrivals with an O(R) carry: decay every replica's
    virtual backlog by the elapsed time, join the argmin (first index on
    ties, matching ``np.argmin``), add the request's work estimate.
    Elementary IEEE float64 ops only, so the assignments are bit-equal to
    the NumPy reference loop in ``repro.core.fleet``."""

    def run(arrivals, work):
        def step(carry, xs):
            v, t_prev = carry
            a, w = xs
            v = jnp.maximum(0.0, v - (a - t_prev))
            r = jnp.argmin(v).astype(jnp.int32)
            return (v.at[r].add(w), a), r

        _, rs = lax.scan(step, (jnp.zeros(R, jnp.float64), jnp.float64(0.0)),
                         (arrivals, work), unroll=_UNROLL)
        return rs

    return jax.jit(run)


def backlog_route(arrivals, work, R: int) -> np.ndarray:
    """Compiled twin of ``fleet._backlog_assign_np`` (replica id per
    request); arrays padded to a power of two so fleet sweeps share
    compiles across workload sizes."""
    n = len(arrivals)
    with jax.experimental.enable_x64():
        rs = _backlog_scan(int(R))(
            jnp.asarray(_pad_pow2_1d(arrivals, np.inf), jnp.float64),
            jnp.asarray(_pad_pow2_1d(work, 0.0), jnp.float64))
        return np.asarray(rs, np.int64)[:n]


@functools.lru_cache(maxsize=None)
def _masked_backlog_scan(R: int):
    """Availability-masked twin of :func:`_backlog_scan`: the replica
    up/down mask rides the scan inputs (one boolean row per arrival,
    failure epochs precomputed on host by :mod:`repro.core.faults`), and
    a down replica's virtual backlog is +inf in the argmin so it never
    receives work.  With every replica up, ``where(up, v, inf) == v``
    and the assignments are bit-equal to the unmasked scan."""

    def run(arrivals, work, up):
        def step(carry, xs):
            v, t_prev = carry
            a, w, u = xs
            v = jnp.maximum(0.0, v - (a - t_prev))
            r = jnp.argmin(jnp.where(u, v, jnp.inf)).astype(jnp.int32)
            return (v.at[r].add(w), a), r

        _, rs = lax.scan(step, (jnp.zeros(R, jnp.float64), jnp.float64(0.0)),
                         (arrivals, work, up), unroll=_UNROLL)
        return rs

    return jax.jit(run)


def masked_backlog_route(arrivals, work, up, R: int) -> np.ndarray:
    """Compiled twin of ``fleet._masked_backlog_assign_np``: replica id
    per request under an availability mask (padded rows are all-up, so
    padding is inert)."""
    n = len(arrivals)
    up = np.asarray(up, bool)
    m = len(_pad_pow2_1d(np.zeros(n), 0.0))
    up_pad = np.ones((m, up.shape[1]), bool)
    up_pad[:n] = up
    with jax.experimental.enable_x64():
        rs = _masked_backlog_scan(int(R))(
            jnp.asarray(_pad_pow2_1d(arrivals, np.inf), jnp.float64),
            jnp.asarray(_pad_pow2_1d(work, 0.0), jnp.float64),
            jnp.asarray(up_pad))
        return np.asarray(rs, np.int64)[:n]


def simulate_fleet_fast(router, policy: BatchPolicy, lam: float, R: int,
                        dist: Optional[TokenDistribution], lat,
                        num_requests: int = 100_000, seed: int = 0,
                        traffic=None, sessions=None,
                        prefix_discount: float = 0.0, memory=None) -> dict:
    """Fast twin of :func:`repro.core.fleet.route_oracle`: the router's
    split is identical (state-dependent assignment via the jitted backlog
    scan), and each replica's sub-workload runs through the policy's
    compiled single-server kernel (oracle fallback when it has none).
    ``traffic`` modulates the arrival stream before routing, exactly
    like the oracle twin's parameter.  ``sessions`` /
    ``prefix_discount`` re-enter completed turns through the fleet
    feedback fixed point
    (:func:`repro.core.sessions.simulate_fleet_sessions`) with the
    kernels as the inner pass — same control flow as the oracle twin.
    ``memory`` gives EACH replica its own KV budget (capacity is
    per-replica HBM, not a fleet pool) through the unchanged
    single-server tandem kernels."""
    from repro.core.fleet import router_from_spec, run_fleet
    router = router_from_spec(router)
    if sessions is not None:
        from repro.core.sessions import (session_from_spec,
                                         simulate_fleet_sessions)
        model = session_from_spec(sessions)
        if not model.is_null:
            return simulate_fleet_sessions(
                router, policy, lam, R, dist, lat, num_requests, seed,
                model, prefix_discount=prefix_discount, traffic=traffic,
                fast=True)
    fw = router.fleet_workload(policy, lam, dist, lat, num_requests, seed,
                               R, fast=True, traffic=traffic)
    return run_fleet(fw, policy, lat, dist,
                     lambda pol, wl: simulate_policy_fast(
                         pol, lam, dist, lat, workload=wl, memory=memory))


def run_controlled(policy, lam, dist, lat, **kw):
    """Closed-loop time-sliced control on the fast path: the compiled
    kernels run every window, the controller re-picks replicas / router /
    bin_edges / shed_prob between windows.  Thin wrapper over
    :func:`repro.core.control.simulate_controlled` with ``fast=True``
    (pass ``fast=False`` there for the reference-oracle twin)."""
    from repro.core.control import simulate_controlled
    kw.setdefault("fast", True)
    return simulate_controlled(policy, lam, dist, lat, **kw)
