"""Vectorized / ``lax.scan`` simulation core (fast path for §V validation).

The NumPy event loops in :mod:`repro.core.simulate` stay the *reference
oracle*; this module re-derives each of them as a compiled recursion so the
λ-grid sweeps behind Figs 4-6 and policy search run 10-100x faster:

  * ``simulate_mg1_fast``       — Lindley / workload recursion. tau=None is
    the same closed-form cumulative-minimum as the reference; the impatience
    path becomes a ``lax.scan`` over the workload process (admit iff V < tau).
  * ``simulate_dynamic_batching_fast`` — the batch-formation event loop is
    replaced by a *per-request* scan with O(1) carry: a forming batch is fully
    described by (start time, count, token sum, token max), and a request
    either joins the forming batch (arrival <= start) or closes it, which
    advances the server-free time by the padded Eq-18 / elastic Eq-26 batch
    time. One scan step per request, no searchsorted, no gathers — and the
    recursion is ``vmap``-able across (λ, policy) lanes.
  * ``simulate_fixed_batching_fast`` — fully closed form: with per-batch
    times H_k and last-arrivals A_k, the free-time recursion
    F_k = max(F_{k-1}, A_k) + H_k telescopes to a running maximum,
    F_k = cummax_j(A_j - C_{j-1}) + C_k with C = cumsum(H). Pure NumPy.
  * ``simulate_policy_sweep_fast`` — stacks every (λ, dynamic/elastic policy)
    combination into lanes of ONE vmapped scan (fixed-b policies use the
    closed form), so the whole grid costs a single compiled pass.

All absolute-time arithmetic runs under ``jax.experimental.enable_x64`` —
simulated clocks reach ~1e6 seconds where float32 ULP (~0.25 s) would swamp
the waits being measured. Scans run with ``unroll=8``, which amortizes XLA's
per-iteration loop overhead on CPU (~5x over unroll=1) while keeping compile
time sub-second.

Every function samples its workload with the *same* rng call order as its
reference twin, so equal seeds give trajectory-level (not just moment-level)
agreement; ``tests/test_fastsim.py`` pins this down.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.simulate import (
    _warm, simulate_fixed_batching, simulate_mg1)

_UNROLL = 8          # scan body replication (amortizes loop overhead on CPU)
_NEG = -1e30
_NO_CAP = 1e18       # "b_max=None" as a finite cap (inf would poison carries)


# ----------------------------------------------------------------------------
# M/G/1 with deterministic impatience tau (workload recursion as a scan)
# ----------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _impatience_scan():
    def run(inter, service, tau):
        def step(v, xs):
            a, s = xs
            v = jnp.maximum(0.0, v - a)
            lost = v >= tau
            wait = jnp.where(lost, tau, v)
            v = jnp.where(lost, v, v + s)
            return v, (wait, lost)

        _, (waits, lost) = lax.scan(step, jnp.float64(0.0),
                                    (inter, service), unroll=_UNROLL)
        return waits, lost

    return jax.jit(run)


def simulate_mg1_fast(lam: float, dist: TokenDistribution, lat: LatencyModel,
                      n_max: Optional[int] = None, tau: Optional[float] = None,
                      num_requests: int = 200_000, seed: int = 0) -> dict:
    """Drop-in fast twin of :func:`repro.core.simulate.simulate_mg1`."""
    if tau is None:
        # the reference tau=None path is already a closed-form vectorized
        # Lindley recursion — reuse it verbatim (it IS the fast path).
        return simulate_mg1(lam, dist, lat, n_max=n_max, tau=None,
                            num_requests=num_requests, seed=seed)
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / lam, num_requests)
    tokens = dist.sample(rng, num_requests)
    if n_max is not None:
        tokens = np.minimum(tokens, n_max)
    service = lat.service_time(tokens)
    with jax.experimental.enable_x64():
        waits, lost = _impatience_scan()(
            jnp.asarray(inter, jnp.float64),
            jnp.asarray(np.asarray(service, np.float64), jnp.float64),
            jnp.float64(tau))
        waits = np.asarray(waits)
        lost = np.asarray(lost)
    waits_w, lost_w = _warm(waits), _warm(lost)
    served = waits_w[~lost_w]
    return {
        "mean_wait": float(waits_w.mean()),
        "mean_wait_served": float(served.mean()) if served.size else 0.0,
        "loss_frac": float(lost_w.mean()),
        "p95_wait": float(np.percentile(waits_w, 95)),
        "waits": waits_w,
    }


# ----------------------------------------------------------------------------
# Dynamic / elastic batching (per-request scan with O(1) forming-batch carry)
# ----------------------------------------------------------------------------

def _batching_core(arr, tok, k1, k2, k3, k4, elastic, b_max):
    """Per-request recursion. Carry = (start, count, sum, max) of the batch
    currently being formed; closing a batch advances the server-free time by
    its Eq-18 (padded) or Eq-26 (elastic) duration. Returns (per-request
    batch start times, per-request batch-close flags)."""

    def step(c, xs):
        a, t = xs
        t_cur, cnt, ssum, smax = c
        t_free = t_cur + jnp.where(
            elastic, k1 * cnt + k2 + k3 * ssum + k4 * smax,
            k1 * cnt + k2 + (k3 * cnt + k4) * smax)
        joins = (a <= t_cur) & (cnt < b_max)
        start_new = jnp.where(a >= t_free, a, t_free)
        t_cur = jnp.where(joins, t_cur, start_new)
        cnt = jnp.where(joins, cnt + 1.0, 1.0)
        ssum = jnp.where(joins, ssum + t, t)
        smax = jnp.where(joins, jnp.maximum(smax, t), t)
        return (t_cur, cnt, ssum, smax), (t_cur, ~joins)

    # cnt0 > b_max forces request 0 to "close" the empty batch; that bogus
    # close exactly offsets the last real batch, which never closes — so
    # sum(closed) equals the reference batch count.
    c0 = (jnp.float64(_NEG), b_max + 1.0, jnp.float64(0.0), jnp.float64(0.0))
    _, (starts, closed) = lax.scan(step, c0, (arr, tok), unroll=_UNROLL)
    return starts, closed


@functools.lru_cache(maxsize=None)
def _batching_scan(vmapped: bool):
    if vmapped:
        return jax.jit(jax.vmap(_batching_core,
                                in_axes=(0, 0, None, None, None, None, 0, 0)))
    return jax.jit(_batching_core)


def _batch_lane_stats(starts, closed, arrivals):
    starts = np.asarray(starts)
    nb = int(np.asarray(closed).sum())
    waits = starts - arrivals
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(len(starts) / max(nb, 1)),
        "waits": w,
    }


def simulate_dynamic_batching_fast(lam: float, dist: TokenDistribution,
                                   lat: BatchLatencyModel,
                                   b_max: Optional[int] = None,
                                   elastic: bool = False,
                                   n_max: Optional[int] = None,
                                   num_requests: int = 200_000,
                                   seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_dynamic_batching (same seeds =>
    trajectory-identical batch boundaries up to float rounding)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, num_requests))
    tokens = dist.sample(rng, num_requests).astype(np.float64)
    if n_max is not None:
        tokens = np.minimum(tokens, n_max)
    with jax.experimental.enable_x64():
        starts, closed = _batching_scan(False)(
            jnp.asarray(arrivals, jnp.float64),
            jnp.asarray(tokens, jnp.float64),
            jnp.float64(lat.k1), jnp.float64(lat.k2),
            jnp.float64(lat.k3), jnp.float64(lat.k4),
            jnp.asarray(bool(elastic)),
            jnp.float64(b_max if b_max is not None else _NO_CAP))
        return _batch_lane_stats(starts, closed, arrivals)


# ----------------------------------------------------------------------------
# Fixed batching (closed form — the recursion telescopes to a cummax)
# ----------------------------------------------------------------------------

def simulate_fixed_batching_fast(lam: float, b: int,
                                 dist: Optional[TokenDistribution],
                                 lat: Optional[BatchLatencyModel] = None,
                                 batch_time: Optional[Callable] = None,
                                 num_requests: int = 200_000,
                                 seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_fixed_batching. With an arbitrary
    ``batch_time`` callable the per-batch times cannot be vectorized, so that
    case delegates to the reference loop."""
    if batch_time is not None:
        return simulate_fixed_batching(lam, b, dist, lat,
                                       batch_time=batch_time,
                                       num_requests=num_requests, seed=seed)
    assert lat is not None
    rng = np.random.default_rng(seed)
    num_requests = (num_requests // b) * b
    arrivals = np.cumsum(rng.exponential(1.0 / lam, num_requests))
    if dist is not None:
        tokens = dist.sample(rng, num_requests).astype(np.float64)
    else:
        tokens = np.zeros(num_requests)
    arr_kb = arrivals.reshape(-1, b)
    h = np.asarray(lat.batch_time(b, tokens.reshape(-1, b).max(axis=1)),
                   np.float64)
    c = np.cumsum(h)
    # F_k = max(F_{k-1}, A_k) + H_k  =>  F_k - C_k = cummax_j(A_j - C_{j-1})
    free = np.maximum.accumulate(arr_kb[:, -1] - (c - h)) + c
    starts = free - h
    waits = (starts[:, None] - arr_kb).reshape(-1)
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "waits": w,
    }


# ----------------------------------------------------------------------------
# Policy sweep: one vmapped scan over every (λ, dynamic/elastic) lane
# ----------------------------------------------------------------------------

def simulate_policy_sweep_fast(lam_grid, dist, lat, policies: dict,
                               num_requests: int = 100_000,
                               seed: int = 0) -> dict:
    """Drop-in fast twin of simulate_policy_sweep. All dynamic/elastic
    (λ, policy) combinations run as lanes of a single vmapped per-request
    scan; fixed-b policies use the closed-form recursion per λ."""
    lam_grid = list(lam_grid)
    lanes = []          # (name, lam_idx, elastic, b_max)
    out = {name: [None] * len(lam_grid) for name in policies}
    for name, spec in policies.items():
        kind = spec.get("kind")
        if kind not in ("dynamic", "elastic", "fixed"):
            raise ValueError(kind)
        if kind == "fixed":
            for li, lam in enumerate(lam_grid):
                r = simulate_fixed_batching_fast(
                    lam, spec["b"], dist, lat,
                    num_requests=num_requests, seed=seed)
                out[name][li] = r["mean_wait"]
        else:
            for li in range(len(lam_grid)):
                lanes.append((name, li, kind == "elastic", spec.get("b_max")))
    if lanes:
        arrs, toks = [], []
        for lam in lam_grid:
            rng = np.random.default_rng(seed)
            arrs.append(np.cumsum(rng.exponential(1.0 / lam, num_requests)))
            toks.append(dist.sample(rng, num_requests).astype(np.float64))
        arr_l = np.stack([arrs[li] for _, li, _, _ in lanes])
        tok_l = np.stack([toks[li] for _, li, _, _ in lanes])
        elas = np.array([e for _, _, e, _ in lanes])
        bmax = np.array([float(bm) if bm is not None else _NO_CAP
                         for _, _, _, bm in lanes])
        with jax.experimental.enable_x64():
            starts, closed = _batching_scan(True)(
                jnp.asarray(arr_l, jnp.float64),
                jnp.asarray(tok_l, jnp.float64),
                jnp.float64(lat.k1), jnp.float64(lat.k2),
                jnp.float64(lat.k3), jnp.float64(lat.k4),
                jnp.asarray(elas), jnp.asarray(bmax, jnp.float64))
            starts = np.asarray(starts)
            closed = np.asarray(closed)
        for row, (name, li, _, _) in enumerate(lanes):
            stats = _batch_lane_stats(starts[row], closed[row], arrs[li])
            out[name][li] = stats["mean_wait"]
    return {k: np.asarray(v) for k, v in out.items()}
