# The paper's primary contribution: queueing-theoretic analysis and control
# of LLM inference serving under variable output-token length.
#
#   distributions  output-token length distributions (+ clipped moments, order stats)
#   latency_model  S = a*n + c and H[b,l] = k1*b + k2 + (k3*b + k4)*l calibration
#   mg1            M/G/1 FCFS queueing delay with max-token clipping   (Eqs 1-5)
#   impatience     abandonment model: De Kok-Tijms + exact level crossing (6-9)
#   policy_opt     optimal n_max (V1/V2), optimal fixed batch b*       (10-13, 25)
#   bulk           dynamic / fixed / elastic batching bulk queues      (14-26)
#   simulate       event-driven simulators validating every formula    (paper SV)
#   fastsim        compiled (jitted) twins of the simulators + fleet kernels
#   predictors     length predictors (oracle / noise models / learned head /
#                  prompt features) driving SRPT ordering, multi-bin routing
#                  and least_work fleet dispatch
#   fleet          routing across parallel batched replicas (router registry,
#                  M/G/R transfer, QNA split approximation)
#   control        adaptive control plane wiring analytics into the engine
