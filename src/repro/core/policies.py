"""Unified batching-policy core: every serving discipline defined ONCE.

The paper analyses four serving disciplines (M/G/1 FCFS with max-token
clipping, dynamic, fixed and elastic batching); this repo additionally runs
iteration-level continuous batching and multi-bin batching.  Before this
module each discipline was re-implemented three to four times — analytic
formulas (``mg1``/``bulk``), the NumPy reference oracle (``simulate``), the
compiled fast simulators (``fastsim``) and the virtual-timeline schedulers
(``serving.scheduler``).  ``BatchPolicy`` collapses those rewrites into one
definition per discipline:

  * **workload law** — ``sample_workload`` fixes the rng call order
    (arrivals, token counts, clipping), so the oracle and the fast twin are
    trajectory-equal on equal seeds by construction;
  * **batch formation** — ``formation()`` returns an iterator-style state
    whose ``next_batch(t_free)`` encodes the trigger (when service starts)
    and the member-selection rule (who is in the batch); length-AWARE
    membership (SRPT's ordering, multi-bin's routing) keys off the
    workload's PREDICTED-length column (:mod:`repro.core.predictors`),
    while clipping and the service law keep the true lengths;
  * **service law** — ``batch_time`` (simulator layer, a
    ``BatchLatencyModel``/``LatencyModel``) and ``service_clock``
    (scheduler layer, a ``ServiceClock``) give the batch occupancy and the
    per-member completion offsets;
  * **analytic delay** — ``analytic_delay`` exposes the paper's closed
    forms/bounds (Pollaczek-Khinchine, Inoue Eq 16, M/D^b/1 Eq 25) behind
    one method; ``analytic_kind`` says whether it is exact, an upper bound
    or an approximation.

Consumers dispatch structurally, never by policy name:

  * :func:`repro.core.simulate.simulate_policy` picks the event loop from
    ``policy.oracle_kind`` ("mg1" | "batches" | "continuous");
  * :func:`repro.core.fastsim.sweep` picks the compiled kernel from
    ``policy.fast_kernel`` ("mg1" | "batch_scan" | "fixed_cummax" |
    "multibin" | None -> oracle fallback);
  * :class:`repro.serving.scheduler.PolicyScheduler` binds a policy to a
    ``ServiceClock`` (model-based or the real engine).

Adding a discipline is one subclass + ``@register``; it then automatically
appears in the oracle, the fast sweep, the schedulers, the cross-layer
agreement tests (``tests/test_policies.py``) and the registry-driven
benchmarks.  :class:`MultiBinPolicy` (Guldogan et al. 2024) was the first
policy added this way; :class:`WaitPolicy` (threshold admission, Dai et
al. 2025) and :class:`SRPTPolicy` (shortest-predicted-first) followed.
``docs/adding_a_policy.md`` walks through the recipe with WAIT and SRPT as
the worked examples, and ``docs/equations.md`` maps each policy's analytic
form back to the paper; CI gates that every registered policy is
documented there.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Type

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel


# ----------------------------------------------------------------------------
# Workload: the sampled request stream a policy operates on
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    """Arrivals + (clipped) output-token counts, sampled in a fixed rng
    order so every layer sees the same trajectory for equal seeds.

    ``predicted`` is the first-class predicted-length column (see
    :mod:`repro.core.predictors`): policies key membership/ordering off it
    while clipping and the service law keep the TRUE ``tokens``.  It is
    drawn from a salted rng stream SEPARATE from the workload rng, so
    arrivals/tokens are bit-identical with or without a predictor; None
    (no predictor configured) means "use the true lengths"."""

    arrivals: np.ndarray          # absolute arrival times (cumsum of expos)
    tokens: np.ndarray            # float64 output-token counts (clipped)
    inter: Optional[np.ndarray] = None   # inter-arrival times (FCFS oracle)
    predicted: Optional[np.ndarray] = None   # predictor output (float64)
    # Re-entrant sessions (repro.core.sessions): session id and 1-based
    # turn index per row; None on session-free streams (the PR 8 paths).
    session: Optional[np.ndarray] = None
    turn: Optional[np.ndarray] = None

    @property
    def predicted_or_true(self) -> np.ndarray:
        return self.tokens if self.predicted is None else self.predicted


def single_from_batch(lat: BatchLatencyModel) -> LatencyModel:
    """A single-request latency law derived from the batch law: S(n) =
    H(1, n) = (k1 + k2) + (k3 + k4) n.  Used when a single-service policy
    (FCFS) is swept with only a ``BatchLatencyModel`` in hand."""
    return LatencyModel(a=lat.k3 + lat.k4, c=lat.k1 + lat.k2)


# ----------------------------------------------------------------------------
# Formation states (trigger + member selection, shared by oracle & scheduler)
# ----------------------------------------------------------------------------

class _DynamicFormation:
    """Serve everything waiting when the server frees (cap ``b_max``); an
    idle server starts the next arrival alone at its arrival time."""

    def __init__(self, arrivals: np.ndarray, b_max: Optional[int]):
        self.arrivals = arrivals
        self.b_max = b_max
        self.head = 0

    def next_batch(self, t_free: float):
        arr, head = self.arrivals, self.head
        if head >= len(arr):
            return None
        if arr[head] >= t_free:
            start, hi = arr[head], head + 1
        else:
            start = t_free
            hi = int(np.searchsorted(arr, t_free, side="right"))
        if self.b_max:
            hi = min(hi, head + self.b_max)
        self.head = hi
        return float(start), np.arange(head, hi)

    def rewind(self, k: int):
        """Defer the last ``k`` members of the batch just formed (memory
        admission): they rejoin the head of the queue for the next
        trigger."""
        self.head -= k


class _FixedFormation:
    """Wait until exactly ``b`` requests are present (paper §IV-C)."""

    def __init__(self, arrivals: np.ndarray, b: int):
        self.arrivals = arrivals
        self.b = b
        self.head = 0
        self.n = (len(arrivals) // b) * b

    def next_batch(self, t_free: float):
        head, b = self.head, self.b
        if head >= self.n:
            return None
        # hi == head + b always, except after a memory-admission rewind
        # left a < b remnant near the truncated end — flush it rather than
        # strand requests that were already admitted once
        hi = min(head + b, self.n)
        start = max(t_free, float(self.arrivals[hi - 1]))
        self.head = hi
        return start, np.arange(head, hi)

    def rewind(self, k: int):
        # under a memory budget a "fixed-b" batch may serve a prefix and
        # re-offer the rest — exact-b is an admission target, not a
        # guarantee, once KV is the binding constraint
        self.head -= k


class _MultiBinFormation:
    """Per-bin FIFO queues, one shared server.  When the server frees it
    serves min(waiting, b_max) requests from the non-empty bin whose head
    arrived earliest (FCFS across bins); an idle server starts the next
    arrival alone, exactly like dynamic batching."""

    def __init__(self, arrivals: np.ndarray, bin_of: np.ndarray,
                 num_bins: int, b_max: Optional[int]):
        self.b_max = b_max
        # per-bin request-index lists (arrival order is preserved because
        # the global stream is already sorted by arrival)
        self.members = [np.nonzero(bin_of == j)[0] for j in range(num_bins)]
        self.arr = [arrivals[m] for m in self.members]
        self.heads = [0] * num_bins
        self._last_bin = -1

    def next_batch(self, t_free: float):
        a_min, j_min = np.inf, -1
        for j, h in enumerate(self.heads):
            if h < len(self.arr[j]) and self.arr[j][h] < a_min:
                a_min, j_min = float(self.arr[j][h]), j
        if j_min < 0:
            return None
        h = self.heads[j_min]
        if a_min >= t_free:
            start, hi = a_min, h + 1
        else:
            start = t_free
            hi = int(np.searchsorted(self.arr[j_min], t_free, side="right"))
            if self.b_max:
                hi = min(hi, h + self.b_max)
        self.heads[j_min] = hi
        self._last_bin = j_min
        return start, self.members[j_min][h:hi]

    def rewind(self, k: int):
        self.heads[self._last_bin] -= k


class _WaitFormation:
    """WAIT-style threshold admission (Dai et al. 2025): hold batch
    formation until at least ``k`` requests are buffered or the head
    request has waited ``timeout`` seconds; then serve everything that has
    arrived by the start instant (cap ``b_max``).  Fewer than ``k``
    requests remaining in the stream are flushed once the last of them has
    arrived (or the timer fires), so the tail of a finite workload is
    never stranded."""

    def __init__(self, arrivals: np.ndarray, k: int,
                 timeout: Optional[float], b_max: Optional[int]):
        self.arrivals = arrivals
        self.k = k
        self.timeout = timeout
        self.b_max = b_max
        self.head = 0

    def next_batch(self, t_free: float):
        arr, head = self.arrivals, self.head
        n = len(arr)
        if head >= n:
            return None
        trigger = float(arr[min(head + self.k - 1, n - 1)])
        if self.timeout is not None:
            trigger = min(trigger, float(arr[head]) + self.timeout)
        start = max(t_free, trigger)
        hi = int(np.searchsorted(arr, start, side="right"))
        if self.b_max:
            hi = min(hi, head + self.b_max)
        self.head = hi
        return start, np.arange(head, hi)

    def rewind(self, k: int):
        self.head -= k


class _SRPTFormation:
    """SRPT-like shortest-predicted-first selection: the waiting room is
    ordered by (predicted token count, arrival order) and batch formation
    takes the ``b_max`` shortest waiting requests — preempting FCFS order
    at formation time (admitted batches are never preempted).  An idle
    server starts the earliest next arrival, exactly like dynamic
    batching."""

    def __init__(self, arrivals: np.ndarray, predicted: np.ndarray,
                 b_max: Optional[int]):
        self.arrivals = arrivals
        self.predicted = predicted      # ordering key ONLY (never service)
        self.b_max = b_max
        self.head = 0
        self.heap: List = []
        self._last_pops: List = []

    def _admit(self, t: float):
        import heapq
        arr, tok, n = self.arrivals, self.predicted, len(self.arrivals)
        while self.head < n and arr[self.head] <= t:
            heapq.heappush(self.heap, (float(tok[self.head]), self.head))
            self.head += 1

    def next_batch(self, t_free: float):
        import heapq
        self._admit(t_free)
        if not self.heap:
            if self.head >= len(self.arrivals):
                return None
            start = float(self.arrivals[self.head])
            self._admit(start)
            cap = 1                       # idle server: next arrival alone
        else:
            start = t_free
            cap = self.b_max if self.b_max else len(self.heap)
        take = min(cap, len(self.heap))
        pops = [heapq.heappop(self.heap) for _ in range(take)]
        self._last_pops = pops
        return start, np.array([p[1] for p in pops])

    def rewind(self, k: int):
        import heapq
        # deferred members keep their (predicted, arrival) heap key, so
        # they compete on equal terms at the next trigger
        for p in self._last_pops[len(self._last_pops) - k:]:
            heapq.heappush(self.heap, p)


# ----------------------------------------------------------------------------
# BatchPolicy protocol + registry
# ----------------------------------------------------------------------------

REGISTRY: Dict[str, Type["BatchPolicy"]] = {}


def register(cls: Type["BatchPolicy"]) -> Type["BatchPolicy"]:
    REGISTRY[cls.name] = cls
    return cls


def get_policy(name: str, **kwargs) -> "BatchPolicy":
    return REGISTRY[name](**kwargs)


def policy_from_spec(spec: dict) -> "BatchPolicy":
    """Legacy ``{"kind": ..., **params}`` spec dicts -> policy instance."""
    spec = dict(spec)
    kind = spec.pop("kind")
    if kind not in REGISTRY:
        raise ValueError(kind)
    return REGISTRY[kind](**spec)


def default_policies(b: int = 4, b_max: Optional[int] = 8,
                     num_bins: int = 4, wait_k: int = 8,
                     srpt_b: int = 8) -> Dict[str, "BatchPolicy"]:
    """One representative instance per registered discipline — the set the
    cross-layer agreement tests and the registry-driven benchmarks iterate."""
    return {
        "fcfs": FCFSPolicy(),
        "dynamic": DynamicPolicy(),
        f"dynamic_b{b_max}": DynamicPolicy(b_max=b_max),
        "elastic": ElasticPolicy(),
        f"fixed_b{b}": FixedPolicy(b=b),
        f"multibin_{num_bins}": MultiBinPolicy(num_bins=num_bins),
        f"wait_k{wait_k}": WaitPolicy(k=wait_k),
        f"srpt_b{srpt_b}": SRPTPolicy(b_max=srpt_b),
        "continuous": ContinuousPolicy(slots=16),
    }


class BatchPolicy:
    """One serving discipline, defined once for every layer.

    Class attributes (the structural dispatch surface):
      name               registry key
      oracle_kind        event-loop family in ``repro.core.simulate``
      fast_kernel        compiled kernel in ``repro.core.fastsim`` (None ->
                         the fast layer falls back to the oracle)
      analytic_kind      'exact' | 'bound' | 'approx' | None
      uses_single_latency  True -> expects a ``LatencyModel`` (single
                         request); drivers convert a ``BatchLatencyModel``
                         via :func:`single_from_batch`

    ``predictor`` (a :class:`repro.core.predictors.LengthPredictor`, a
    registry name, or a legacy spec dict) fills the workload's
    ``predicted`` column; None keeps the oracle behavior (predicted ==
    true, zero extra rng calls — trajectories bit-equal to the
    pre-predictor code).  Length-aware policies (SRPT ordering, multi-bin
    routing) consume the predicted column for MEMBERSHIP only; clipping
    and the service law always use the true lengths.
    """

    name = "base"
    oracle_kind = "batches"
    fast_kernel: Optional[str] = None
    analytic_kind: Optional[str] = None
    uses_single_latency = False

    def __init__(self, n_max: Optional[int] = None, predictor=None):
        self.n_max = n_max
        if predictor is not None:
            from repro.core.predictors import predictor_from_spec
            predictor = predictor_from_spec(predictor)
        self.predictor = predictor

    # -------------------- prediction law --------------------
    def predict_lengths(self, key, tokens: np.ndarray,
                        prompts=None) -> Optional[np.ndarray]:
        """The policy's predicted-length column for ``tokens`` (true,
        already clipped); None when no predictor is configured (oracle
        semantics).  ``key`` seeds the predictor's salted rng stream —
        layers that pass the same key see the same predictions."""
        if self.predictor is None:
            return None
        return self.predictor.predict(key, tokens, prompts)

    # -------------------- workload law --------------------
    def sample_workload(self, lam: float, dist: Optional[TokenDistribution],
                        num_requests: int, seed: int) -> Workload:
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / lam, num_requests))
        if dist is not None:
            tokens = dist.sample(rng, num_requests).astype(np.float64)
        else:
            tokens = np.zeros(num_requests)
        if self.n_max is not None:
            tokens = np.minimum(tokens, self.n_max)
        return Workload(arrivals=arrivals, tokens=tokens,
                        predicted=self.predict_lengths(seed, tokens))

    def clip(self, tokens):
        return (np.minimum(tokens, self.n_max) if self.n_max is not None
                else tokens)

    # -------------------- formation (trigger + membership) ------------
    def formation(self, arrivals: np.ndarray, tokens: np.ndarray,
                  dist: Optional[TokenDistribution] = None,
                  predicted: Optional[np.ndarray] = None):
        raise NotImplementedError

    def schedule_length(self, n: int) -> int:
        """How many of ``n`` offered requests this policy serves (fixed
        batching truncates to a multiple of b)."""
        return n

    # -------------------- service law --------------------
    def batch_time(self, ns: np.ndarray, lat) -> float:
        """Batch occupancy on the simulator layer (``lat`` is the policy's
        latency model: batch or single per ``uses_single_latency``)."""
        raise NotImplementedError

    def service_clock(self, ns: np.ndarray, clock):
        """(occupancy, per-member completion offsets) on the scheduler
        layer.  Default: padded semantics — everyone completes with the
        batch."""
        h = clock.batch_time(ns)
        return h, np.full(len(ns), h)

    def stage_split(self, ns: np.ndarray, lat):
        """Tandem split of the batch law (:mod:`repro.core.memory`):
        (prefill seconds, per-request decode offsets from prefill end),
        with prefill + max(offsets) == ``batch_time`` exactly.  Default:
        padded semantics — everyone decodes to the batch max."""
        pf = float(lat.prefill_time(len(ns)))
        h = self.batch_time(ns, lat)
        return pf, np.full(len(ns), h - pf)

    # -------------------- analytics --------------------
    def analytic_delay(self, lam: float, dist: TokenDistribution,
                       lat) -> Optional[float]:
        """Mean queueing delay from the paper's closed forms, or None when
        the discipline has no analytic form yet (see ``analytic_kind``)."""
        return None

    # -------------------- convenience layer entry points --------------
    def simulate(self, lam, dist, lat, num_requests: int = 200_000,
                 seed: int = 0) -> dict:
        from repro.core.simulate import simulate_policy
        return simulate_policy(self, lam, dist, lat,
                               num_requests=num_requests, seed=seed)

    def simulate_fast(self, lam, dist, lat, num_requests: int = 200_000,
                      seed: int = 0) -> dict:
        from repro.core.fastsim import simulate_policy_fast
        return simulate_policy_fast(self, lam, dist, lat,
                                    num_requests=num_requests, seed=seed)

    def scheduler(self, clock, predictor=None):
        from repro.serving.scheduler import PolicyScheduler
        return PolicyScheduler(self, clock, predictor=predictor)

    # -------------------- fast-path hints --------------------
    def scan_lane(self):
        """(elastic_flag, b_max) when this policy can ride a lane of the
        shared vmapped per-request batching scan, else None."""
        return None

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items() if v is not None}
        return f"{type(self).__name__}({keys})"


# ----------------------------------------------------------------------------
# The paper's disciplines
# ----------------------------------------------------------------------------

@register
class FCFSPolicy(BatchPolicy):
    """M/G/1 FCFS with max-token clipping and optional deterministic
    impatience tau (paper §III, Eqs 1-9)."""

    name = "fcfs"
    oracle_kind = "mg1"
    fast_kernel = "mg1"
    analytic_kind = "exact"
    uses_single_latency = True

    def __init__(self, n_max: Optional[int] = None,
                 tau: Optional[float] = None, predictor=None):
        super().__init__(n_max, predictor)
        self.tau = tau

    def sample_workload(self, lam, dist, num_requests, seed) -> Workload:
        # The FCFS oracle consumes inter-arrival times directly (same rng
        # call order as arrivals=cumsum(inter), so trajectories still align).
        rng = np.random.default_rng(seed)
        inter = rng.exponential(1.0 / lam, num_requests)
        tokens = self.clip(dist.sample(rng, num_requests))
        return Workload(arrivals=np.cumsum(inter), tokens=tokens, inter=inter,
                        predicted=self.predict_lengths(seed, tokens))

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        return _DynamicFormation(arrivals, b_max=1)

    def batch_time(self, ns, lat) -> float:
        return float(lat.service_time(ns[0]))

    def service_clock(self, ns, clock):
        h = clock.single_time(ns[0])
        return h, np.array([h])

    def analytic_delay(self, lam, dist, lat) -> float:
        from repro.core.mg1 import mg1_wait
        if isinstance(lat, BatchLatencyModel):
            lat = single_from_batch(lat)
        if self.tau is not None:
            from repro.core.impatience import exact_impatience
            return exact_impatience(dist, lat, lam, self.tau, self.n_max).wq_all
        return mg1_wait(dist, lat, lam, self.n_max).wait

    def optimize_n_max(self, lam, dist, lat, theta: float,
                       loss_cost: float = 4.0) -> int:
        """The paper's optimal max-token limit (Eqs 10-13) for this
        discipline: V1 when users are patient, V2 under impatience tau."""
        from repro.core.policy_opt import (
            optimize_token_limit_v1, optimize_token_limit_v2)
        if isinstance(lat, BatchLatencyModel):
            lat = single_from_batch(lat)
        if self.tau is None:
            return optimize_token_limit_v1(dist, lat, lam, theta).n_max
        return optimize_token_limit_v2(dist, lat, lam, theta, self.tau,
                                       loss_cost).n_max


@register
class DynamicPolicy(BatchPolicy):
    """Dynamic batching: serve all waiting (cap ``b_max``) with padded
    decode H[b, max] (paper §IV-A/B, Eq 18)."""

    name = "dynamic"
    fast_kernel = "batch_scan"
    analytic_kind = "bound"

    def __init__(self, n_max: Optional[int] = None,
                 b_max: Optional[int] = None, predictor=None):
        super().__init__(n_max, predictor)
        self.b_max = b_max
        if b_max is not None:
            # the Inoue bound assumes serve-ALL-waiting; capping batch size
            # lowers throughput, so the unbounded bound is not an upper
            # bound for the capped system — no closed form available
            self.analytic_kind = None

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        return _DynamicFormation(arrivals, self.b_max)

    def batch_time(self, ns, lat) -> float:
        return float(lat.batch_time(len(ns), ns.max()))

    def scan_lane(self):
        return (False, self.b_max)

    def analytic_delay(self, lam, dist, lat) -> Optional[float]:
        from repro.core.bulk import dynamic_batching_bound
        if self.b_max is not None:
            return None
        return dynamic_batching_bound(dist if self.n_max is None
                                      else dist.clip(self.n_max),
                                      lat, lam)["wait_bound"]


@register
class ElasticPolicy(DynamicPolicy):
    """Elastic batching: dynamic formation, but short replies exit early
    (completion via Eq 26) and the batch ends at the slowest member."""

    name = "elastic"

    def batch_time(self, ns, lat) -> float:
        return lat.elastic_batch_time(ns)

    def service_clock(self, ns, clock):
        comp = clock.elastic_times(ns)            # sorted ascending order
        order = np.argsort(ns, kind="stable")
        offsets = np.empty(len(ns))
        offsets[order] = comp
        return float(comp.max()), offsets

    def stage_split(self, ns, lat):
        # Eq 26 early exit: per-request completions (sorted ascending in
        # length) measured from the shared prefill end
        comp = lat.elastic_completion_times(ns)
        order = np.argsort(ns, kind="stable")
        offsets = np.empty(len(ns))
        offsets[order] = comp
        pf = float(lat.prefill_time(len(ns)))
        return pf, offsets - pf

    def scan_lane(self):
        return (True, self.b_max)

    def analytic_delay(self, lam, dist, lat) -> Optional[float]:
        from repro.core.bulk import elastic_batching_bound
        if self.b_max is not None:
            return None
        return elastic_batching_bound(dist if self.n_max is None
                                      else dist.clip(self.n_max),
                                      lat, lam)["wait_bound"]


@register
class FixedPolicy(BatchPolicy):
    """Fixed batching M/D^b/1: wait until exactly ``b`` requests are
    present (paper §IV-C, Eqs 24-25)."""

    name = "fixed"
    fast_kernel = "fixed_cummax"
    analytic_kind = "approx"     # Eq 25 treats H^[b] as deterministic

    def __init__(self, b: int = 4, n_max: Optional[int] = None,
                 predictor=None):
        super().__init__(n_max, predictor)
        self.b = b

    def sample_workload(self, lam, dist, num_requests, seed) -> Workload:
        return super().sample_workload(
            lam, dist, (num_requests // self.b) * self.b, seed)

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        return _FixedFormation(arrivals, self.b)

    def schedule_length(self, n: int) -> int:
        return (n // self.b) * self.b

    def batch_time(self, ns, lat) -> float:
        return float(lat.batch_time(len(ns), ns.max()))

    def analytic_delay(self, lam, dist, lat) -> float:
        from repro.core.bulk import mdb1_wait_exact
        d = dist if self.n_max is None else dist.clip(self.n_max)
        h = float(lat.mean_batch_time(d, self.b))
        return mdb1_wait_exact(lam, h, self.b)


@register
class MultiBinPolicy(BatchPolicy):
    """Multi-bin batching (Guldogan et al. 2024): requests are routed to
    bins by (predicted) output length; within a bin, dynamic batching with
    padded decode; the server picks the non-empty bin whose head request
    arrived earliest.  Because bin members have similar lengths, the
    H[b, max] padding waste shrinks, buying throughput at high load.

    ``edges``: ascending upper token boundaries (last bin open-ended).
    ``edges=None``: equal-probability-mass boundaries are derived from the
    workload's token distribution at run time (the paper's suggestion)."""

    name = "multibin"
    fast_kernel = "multibin"
    analytic_kind = "bound"       # two-arm envelope, see bulk.multibin_bound

    def __init__(self, num_bins: int = 4,
                 edges: Optional[Sequence[float]] = None,
                 n_max: Optional[int] = None,
                 b_max: Optional[int] = None,
                 predictor=None,
                 bound_quantile: float = 1.0):
        super().__init__(n_max, predictor)
        self.num_bins = int(num_bins if edges is None else len(edges) + 1)
        self.edges = None if edges is None else tuple(float(e) for e in edges)
        self.b_max = b_max
        self.bound_quantile = float(bound_quantile)
        if b_max is not None:
            # both bound arms assume serve-all-waiting within the picked
            # bin; a batch cap lowers throughput, so neither arm dominates
            # the capped system
            self.analytic_kind = None
        elif bound_quantile < 1.0:
            # the quantile-envelope round arm ignores the top (1-q) tail of
            # the padding support: finite on heavy tails, but no longer a
            # strict bound
            self.analytic_kind = "approx"

    def bin_edges(self, dist: Optional[TokenDistribution],
                  tokens: Optional[np.ndarray] = None) -> np.ndarray:
        """Boundaries actually used: explicit ``edges``; else equal-mass
        quantiles of ``dist`` (after clipping); else — on the scheduler
        layer, where only observed lengths exist — empirical quantiles of
        ``tokens``."""
        qs = np.arange(1, self.num_bins) / self.num_bins
        if self.edges is not None:
            return np.asarray(self.edges, np.float64)
        if dist is not None:
            d = dist if self.n_max is None else dist.clip(self.n_max)
            return np.asarray([np.searchsorted(d.cdf, q) for q in qs],
                              np.float64)
        assert tokens is not None, "multibin needs edges, a dist, or tokens"
        return np.quantile(np.asarray(tokens, np.float64), qs)

    def bin_of(self, tokens: np.ndarray,
               dist: Optional[TokenDistribution] = None) -> np.ndarray:
        return np.searchsorted(self.bin_edges(dist, tokens), tokens,
                               side="left")

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        # routing keys off the PREDICTED length; the service law (padded
        # range max in batch_time) stays on the true tokens — mispredicted
        # long requests land in short bins and blow up that bin's padding,
        # which is exactly the erosion Guldogan et al. analyze
        key = tokens if predicted is None else predicted
        return _MultiBinFormation(arrivals, self.bin_of(key, dist),
                                  self.num_bins, self.b_max)

    def batch_time(self, ns, lat) -> float:
        return float(lat.batch_time(len(ns), ns.max()))

    def analytic_delay(self, lam, dist, lat) -> Optional[float]:
        from repro.core.bulk import multibin_bound
        if self.b_max is not None:
            return None
        d = dist if self.n_max is None else dist.clip(self.n_max)
        return multibin_bound(d, lat, lam, self.bin_edges(d),
                              quantile=self.bound_quantile)["wait_bound"]

    @classmethod
    def optimized(cls, lam: float, dist: TokenDistribution, lat,
                  num_bins: int = 4, **kwargs) -> "MultiBinPolicy":
        """Load-dependent boundaries (Guldogan et al. 2024) instead of the
        default equal-probability-mass quantiles; see
        :func:`repro.core.bulk.optimize_bin_edges`."""
        from repro.core.bulk import optimize_bin_edges
        edges = optimize_bin_edges(dist, lat, lam, num_bins=num_bins)
        return cls(edges=tuple(edges), **kwargs)


@register
class WaitPolicy(BatchPolicy):
    """WAIT-style threshold admission (Dai et al. 2025): hold batch
    formation until at least ``k`` requests are buffered or the head
    request has waited ``timeout`` seconds, then serve everything that has
    arrived (cap ``b_max``) with padded decode.  Holding trades queueing
    delay at low load for throughput at high load: formed batches amortize
    the per-batch overhead ``k1*b + k2`` and the padded decode over at
    least ``k`` requests, which is the mechanism behind the policy's
    heavy-traffic throughput optimality in Dai et al.  ``timeout=None`` is
    the pure threshold rule (the end of a finite stream still flushes the
    last ``< k`` stragglers).  No closed-form mean delay is known (Dai et
    al. prove throughput optimality, not a delay formula), but the
    M/D^k/1-like holding + clearing envelope :func:`repro.core.bulk.
    wait_bound` (positional trigger hold, timer-capped, plus Inoue's
    serve-all-waiting arm) upper-bounds it — ``analytic_kind='bound'``
    whenever the serve-all assumption holds (``b_max=None``)."""

    name = "wait"
    fast_kernel = "wait"
    analytic_kind = "bound"       # holding + clearing envelope (bulk.wait_bound)

    def __init__(self, k: int = 8, timeout: Optional[float] = None,
                 n_max: Optional[int] = None, b_max: Optional[int] = None,
                 predictor=None):
        super().__init__(n_max, predictor)
        assert k >= 1
        self.k = int(k)
        self.timeout = timeout
        self.b_max = b_max
        if b_max is not None:
            # the clearing arm assumes serve-ALL-arrived at the trigger; a
            # batch cap lowers throughput, so the envelope no longer
            # dominates the capped system
            self.analytic_kind = None

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        # membership is arrival-count/timer-driven: prediction-insensitive
        return _WaitFormation(arrivals, self.k, self.timeout, self.b_max)

    def batch_time(self, ns, lat) -> float:
        return float(lat.batch_time(len(ns), ns.max()))

    def analytic_delay(self, lam, dist, lat) -> Optional[float]:
        from repro.core.bulk import wait_bound
        if self.b_max is not None:
            return None
        return wait_bound(dist if self.n_max is None
                          else dist.clip(self.n_max),
                          lat, lam, self.k, self.timeout)["wait_bound"]


@register
class SRPTPolicy(BatchPolicy):
    """SRPT-like shortest-predicted-first batching: the waiting room is
    ordered by predicted output length and batch formation takes the
    ``b_max`` shortest waiting requests (padded decode), preempting FCFS
    order at formation time — running batches are never preempted, which
    is what a serving engine can actually implement.  Short replies stop
    queueing behind long ones AND the selected batch is length-homogeneous,
    so the ``H[b, max]`` padding waste shrinks like multi-bin batching's.

    The ordering key is the PREDICTED output length: the default (no
    ``predictor``) is the oracle — the true sampled token count, after
    ``n_max`` clipping — and any :mod:`repro.core.predictors` instance
    (noise models, bucket classifier, learned head) can replace it to
    measure how prediction error erodes the win.  The service law always
    uses the true lengths: a mispredicted-short request still decodes to
    its true length and pads the whole batch.  With ``b_max=None`` every
    waiting request is served, and membership degenerates to dynamic
    batching (order inside a padded batch is irrelevant) — so the
    discipline defaults to a finite cap.  No EXACT mean-delay formula is
    known for batched SRPT (classic SRPT analysis is per-request
    preemptive), but a size-interval envelope upper-bounds it:
    :func:`repro.core.bulk.srpt_bound` treats the shortest-first room as
    priority classes by length quantile and pads each class's clearing
    time to its own upper edge — ``analytic_kind='bound'`` under oracle
    ordering (a noisy ``predictor`` scrambles the class membership the
    envelope assumes, so it downgrades to None)."""

    name = "srpt"
    fast_kernel = "srpt"
    analytic_kind = "bound"       # size-interval envelope (bulk.srpt_bound)

    def __init__(self, b_max: Optional[int] = 8,
                 n_max: Optional[int] = None, predictor=None):
        super().__init__(n_max, predictor)
        self.b_max = b_max
        if predictor is not None:
            # the envelope's class decomposition assumes true-length
            # ordering; misprediction leaks long requests into short
            # classes and the bound no longer dominates
            self.analytic_kind = None

    def formation(self, arrivals, tokens, dist=None, predicted=None):
        key = tokens if predicted is None else predicted
        return _SRPTFormation(arrivals, key, self.b_max)

    def batch_time(self, ns, lat) -> float:
        return float(lat.batch_time(len(ns), ns.max()))

    def analytic_delay(self, lam, dist, lat) -> Optional[float]:
        from repro.core.bulk import srpt_bound
        if self.predictor is not None:
            return None
        d = dist if self.n_max is None else dist.clip(self.n_max)
        return srpt_bound(d, lat, lam, self.b_max)["wait_bound"]


@register
class ContinuousPolicy(BatchPolicy):
    """Iteration-level (Orca/vLLM-style) batching — beyond paper.  ``slots``
    decode streams; a freed slot refills immediately; admission and refill
    at ``chunk`` boundaries, mirroring the engine's fused decode loop."""

    name = "continuous"
    oracle_kind = "continuous"
    fast_kernel = None            # virtual-timeline loop IS the simulator

    def __init__(self, slots: int = 16, n_max: Optional[int] = None,
                 chunk: int = 1, predictor=None):
        super().__init__(n_max, predictor)
        assert chunk >= 1
        self.slots = slots
        self.chunk = chunk

    def scheduler(self, clock):
        from repro.serving.scheduler import ContinuousBatchScheduler
        return ContinuousBatchScheduler(clock, slots=self.slots,
                                        n_max=self.n_max, chunk=self.chunk)


__all__ = [
    "BatchPolicy", "ContinuousPolicy", "DynamicPolicy", "ElasticPolicy",
    "FCFSPolicy", "FixedPolicy", "MultiBinPolicy", "REGISTRY", "SRPTPolicy",
    "WaitPolicy", "Workload", "default_policies", "get_policy",
    "policy_from_spec", "register", "single_from_batch",
]
