"""KV-memory budget + prefill/decode tandem service (two-resource realism).

The paper's service laws (Eqs 18-26) gate a batch on its size ``b`` alone
and serve it as ONE stage ``H(b, l)``.  Real engines are a *tandem*: a
prefill bulk stage (``k1*b + k2``, the first-token term of Eq 18) feeds a
decode continuous stage (``(k3*b + k4)*l``), and the binding constraint is
HBM for KV cache, not batch size — the premise of WAIT scheduling (Dai et
al. 2025) and of memory-aware admission in AugServe (Wang et al. 2025).

This module supplies both halves:

* :class:`MemoryBudget` — per-replica KV-token capacity ``M``; a request
  holds ``prompt_tokens + n_i`` KV tokens from its prefill start until its
  decode completion, when the footprint is freed.
* :class:`TandemClock` — the multi-stage latency law.  It wraps the
  existing :class:`~repro.core.latency_model.BatchLatencyModel` and asks
  the *policy* for its stage split (``BatchPolicy.stage_split``), so every
  registered policy inherits the tandem structure with zero per-policy
  rewrites: the default split is (prefill, uniform decode offsets);
  elastic overrides it with the Eq 26 per-request completion offsets.
* :func:`tandem_oracle` — the reference event loop: batches form exactly
  as before (same formation objects), but the batch occupies the prefill
  stage for ``k1*b + k2`` and then the decode stage for the remainder, so
  batch j+1's prefill overlaps batch j's decode (pipelining).  Admission
  is memory-gated: a member joins only if the alive KV footprint stays
  <= M; members that do not fit are deferred via ``formation.rewind`` and
  re-offered later; if even the first member does not fit the start is
  delayed to the earliest release instant that frees enough.

Conformance discipline (same as faults/traffic/sessions): a *null* budget
(``capacity=None``/inf) short-circuits every caller to the exact pre-PR-10
code path — bit-equality by construction — because an infinite-budget
tandem PIPELINE is a genuinely different (faster) system than the serial
``H(b, l)`` gate, not a degenerate case of it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = [
    "MemoryBudget", "TandemClock", "memory_from_spec",
    "check_policy_supports_memory", "tandem_oracle", "occupancy_stats",
]


# ----------------------------------------------------------------------------
# Budget model
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Per-replica KV-token budget.

    ``capacity``       : KV tokens of HBM available to one replica; None or
                         inf means unconstrained (the null model).
    ``prompt_tokens``  : KV tokens a request's prompt occupies on top of
                         its generated tokens — footprint(n) = prompt + n.
    """

    capacity: Optional[float] = None
    prompt_tokens: float = 0.0

    @property
    def is_null(self) -> bool:
        return self.capacity is None or math.isinf(self.capacity)

    def footprint(self, tokens):
        """KV tokens request(s) hold from prefill start to completion."""
        return self.prompt_tokens + np.asarray(tokens, np.float64)

    def max_batch(self, dist, quantile: float = 1.0) -> int:
        """Largest batch that fits worst-case members: b(M) = floor(M /
        footprint(L_inf)) with the token support capped at ``quantile``
        (heavy tails would otherwise drive L_inf, and b(M), to 0/inf)."""
        if self.is_null:
            raise ValueError("max_batch is undefined for a null budget")
        linf = float(dist.max_order_stat_limit(quantile))
        per = float(self.footprint(linf))
        return max(1, int(self.capacity / max(per, 1e-12)))


def memory_from_spec(spec) -> MemoryBudget:
    """None -> null budget; a MemoryBudget passes through; a number is a
    bare capacity; a dict maps to the constructor."""
    if spec is None:
        return MemoryBudget()
    if isinstance(spec, MemoryBudget):
        return spec
    if isinstance(spec, (int, float)):
        return MemoryBudget(capacity=float(spec))
    if isinstance(spec, dict):
        return MemoryBudget(**spec)
    raise ValueError(f"cannot build a MemoryBudget from {spec!r}")


def check_policy_supports_memory(policy) -> None:
    """The tandem needs discrete batch formation events to gate: FCFS
    (oracle_kind 'mg1') has no batch admission point, and continuous
    (iteration-level) batching admits per token, not per batch."""
    if policy.oracle_kind != "batches":
        raise ValueError(
            f"policy {policy.name!r} (oracle_kind={policy.oracle_kind!r}) "
            "has no batch-formation admission point; memory= is only "
            "supported for batch-formation policies")


# ----------------------------------------------------------------------------
# Multi-stage latency law
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TandemClock:
    """Two-stage generalization of the single ``H(b, l)`` service clock.

    Stage 1 (prefill, bulk):      P(b)    = k1*b + k2
    Stage 2 (decode, continuous): D(b, l) = (k3*b + k4)*l

    so H(b, l) = P(b) + D(b, l) exactly recovers Eq 18 when the stages are
    run back to back.  The per-request decode offsets come from the
    policy's ``stage_split`` so elastic early exit (Eq 26) splits
    correctly too.
    """

    batch: "BatchLatencyModel"

    def prefill_time(self, b):
        return self.batch.prefill_time(b)

    def decode_time(self, b, l):
        return self.batch.decode_time(b, l)

    def serial_time(self, b, l):
        """Back-to-back total — the PR-9 single-stage H(b, l)."""
        return self.batch.batch_time(b, l)

    def stage_split(self, policy, ns):
        """(prefill seconds, per-request decode offsets) for a batch."""
        return policy.stage_split(ns, self.batch)


# ----------------------------------------------------------------------------
# Reference tandem oracle
# ----------------------------------------------------------------------------

def tandem_oracle(policy, wl, lat, dist, budget: MemoryBudget) -> dict:
    """Exact pipelined tandem event loop with memory-gated admission.

    State: ``t_pf`` (prefill stage free), ``t_dec`` (decode stage free),
    ``A`` (total KV ever admitted) and a per-request release ledger
    (``rel_t`` sorted times / ``rel_cum`` prefix sums — sorted by
    construction because batch j+1's decode starts after batch j's ends).
    Alive KV at time t is ``A_admitted_before_t - released_before_t``.

    Admission per batch (membership fixed at the formation trigger):

    1. releases up to the candidate start are banked:
       ``target = M + rel_cum[searchsorted(rel_t, start, 'right')]``;
    2. if even the first member overflows, the start is DELAYED to the
       earliest release instant freeing enough (never re-formed);
    3. the longest prefix of members (in formation order) with cumulative
       footprint <= target is admitted; the rest are deferred via
       ``formation.rewind`` and re-offered at the next trigger.

    The batch then holds the prefill stage for ``pf`` and the decode stage
    from ``max(start + pf, t_dec)``; waits are measured to prefill start
    (the PR-9 convention: waits end when service begins).
    """
    from repro.core.simulate import _warm

    arr, tok = wl.arrivals, wl.tokens
    n = len(arr)
    M = float(budget.capacity)
    fp = budget.footprint(tok)
    if n and float(fp.max()) > M:
        raise ValueError(
            f"memory budget {M} cannot hold the largest single request "
            f"(footprint {float(fp.max())}); no schedule exists")

    fs = policy.formation(arr, tok, dist, predicted=wl.predicted)
    waits = np.zeros(n)
    adm_start = np.zeros(n)          # prefill (allocation) instant
    adm_comp = np.zeros(n)           # completion (release) instant
    rel_t = np.empty(n)              # release ledger: times ...
    rel_cum = np.zeros(n + 1)        # ... and prefix footprint sums
    nr = 0
    t_pf = 0.0
    t_dec = 0.0
    A = 0.0
    batch_sizes = []
    blocked_batches = 0
    blocked_time = 0.0
    deferred = 0

    while (nb := fs.next_batch(t_pf)) is not None:
        start0, idx = nb
        start = float(start0)
        # -- releases banked by the candidate start --------------------
        r = int(np.searchsorted(rel_t[:nr], start, side="right"))
        target = M + rel_cum[r]
        if A + fp[idx[0]] > target:
            # delay to the earliest instant freeing enough; feasible
            # because rel_cum[nr] == A (every admitted token has a
            # scheduled release) and fp[idx[0]] <= M
            need = A + fp[idx[0]] - M
            r_star = int(np.searchsorted(rel_cum[1:nr + 1], need,
                                         side="left")) + 1
            start = float(rel_t[r_star - 1])
            blocked_batches += 1
            blocked_time += start - start0
            r = int(np.searchsorted(rel_t[:nr], start, side="right"))
            target = M + rel_cum[r]
        # -- longest admissible prefix, in formation order -------------
        admit = 0
        cum = A
        for i in idx:
            if cum + fp[i] <= target:
                cum += fp[i]
                admit += 1
            else:
                break
        if admit < len(idx):
            fs.rewind(len(idx) - admit)
            deferred += len(idx) - admit
            idx = idx[:admit]
        A = cum
        # -- tandem service --------------------------------------------
        pf, dec_off = policy.stage_split(tok[idx], lat)
        p_end = start + pf
        d_start = max(p_end, t_dec)
        comp = d_start + dec_off
        waits[idx] = start - arr[idx]
        adm_start[idx] = start
        adm_comp[idx] = comp
        batch_sizes.append(len(idx))
        # -- release ledger, in completion order -----------------------
        order = np.argsort(dec_off, kind="stable")
        for j in order:
            rel_t[nr] = comp[j]
            rel_cum[nr + 1] = rel_cum[nr] + fp[idx[j]]
            nr += 1
        t_pf = p_end
        t_dec = float(comp[order[-1]])

    w = _warm(waits)
    mem = occupancy_stats(adm_start, adm_comp, fp, M, served=nr)
    mem["blocked_batches"] = blocked_batches
    mem["blocked_time"] = float(blocked_time)
    mem["deferred_requests"] = deferred
    return {
        "mean_wait": float(w.mean()) if w.size else 0.0,
        "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
        "mean_batch": float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        "waits": w,
        "memory": mem,
        # untrimmed per-request views for the scheduler adapter
        # (PolicyScheduler drives this same loop through a ModelClock)
        "waits_all": waits,
        "completions": adm_comp,
        "batch_sizes": batch_sizes,
    }


def occupancy_stats(starts, comps, footprints, capacity: float,
                    served: Optional[int] = None) -> dict:
    """KV occupancy trajectory from per-request (allocate, free, size)
    triples: allocation events (+fp at start) and release events (-fp at
    completion), releases first on ties — consistent with the admission
    rule's 'right'-sided release search.  ``served`` limits to the first
    rows actually scheduled (fixed-b truncation leaves a tail)."""
    starts = np.asarray(starts, np.float64)
    comps = np.asarray(comps, np.float64)
    fp = np.asarray(footprints, np.float64)
    if served is not None and served < len(starts):
        # fixed-size batching truncates to a multiple of b: unserved tail
        # rows never allocate
        mask = comps > 0
        starts, comps, fp = starts[mask], comps[mask], fp[mask]
    n = len(starts)
    allocated = float(fp.sum())
    if n == 0:
        return {"capacity": float(capacity), "kv_peak": 0.0,
                "kv_mean": 0.0, "utilization": 0.0,
                "allocated": 0.0, "freed": 0.0}
    t = np.concatenate([starts, comps])
    d = np.concatenate([fp, -fp])
    # releases before allocations at ties (a freed slot is reusable at
    # the same instant)
    order = np.lexsort((np.sign(d), t))
    t, d = t[order], d[order]
    level = np.cumsum(d)
    peak = float(level.max())
    span = float(t[-1] - t[0])
    if span > 0:
        dt = np.diff(t)
        mean = float((level[:-1] * dt).sum() / span)
    else:
        mean = peak
    return {
        "capacity": float(capacity),
        "kv_peak": peak,
        "kv_mean": mean,
        "utilization": peak / capacity if capacity else 0.0,
        "allocated": allocated,
        "freed": float(-d[d < 0].sum()),
    }
