"""Inference latency models and their calibration (paper §II-B/C, Fig 2).

Single request (paper Fig 2a):        S(n)    = a*n + c
Batched inference (paper Eq 18):      H(b, l) = k1*b + k2 + (k3*b + k4)*l
Elastic batch completion (Eq 26):     H_el    = k1*b + k2 + k3*sum(n_i) + k4*max(n_i)

``fit_*`` functions calibrate the constants from engine measurements by least
squares, mirroring the paper's curve fitting on A100; TPU-v5e analytic
constants are derived in ``benchmarks/bench_latency_model.py`` from the
roofline terms.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """S = a*n + c  (seconds; n = output tokens)."""

    a: float
    c: float

    def service_time(self, n):
        return self.a * np.asarray(n, np.float64) + self.c

    def moments(self, dist, n_max: int = None):
        """E[S], E[S^2] under optional clipping (paper Eqs 4-5)."""
        if n_max is None:
            m1, m2 = dist.mean(), dist.second_moment()
        else:
            m1, m2 = dist.clipped_moments(n_max)
        es = self.a * m1 + self.c
        es2 = es ** 2 + self.a ** 2 * (m2 - m1 ** 2)
        return es, es2


# Back-derived A100 / LLaMA-2-7b-chat constants from the paper's Table I:
# (128,512)->12.63s and (128,1024)->23.47s give a=(23.47-12.63)/512=0.0212,
# c = 12.63 - 512a = 1.79. Used to reproduce the paper's Fig 4 numbers.
PAPER_A100_LLAMA2_7B = LatencyModel(a=0.021171875, c=1.79)


@dataclasses.dataclass(frozen=True)
class BatchLatencyModel:
    """H(b, l) = k1*b + k2 + (k3*b + k4)*l   (paper Eq 18).

    k1*b + k2     : first-token (prefill) time, linear in batch size
    (k3*b + k4)*l : per-output-token decode time, linear in batch size,
                    l = max output tokens in the batch (padding semantics)
    """

    k1: float
    k2: float
    k3: float
    k4: float

    def batch_time(self, b, l):
        b = np.asarray(b, np.float64)
        l = np.asarray(l, np.float64)
        return self.k1 * b + self.k2 + (self.k3 * b + self.k4) * l

    def prefill_time(self, b):
        """Stage 1 of the tandem split: the first-token term k1*b + k2."""
        return self.k1 * np.asarray(b, np.float64) + self.k2

    def decode_time(self, b, l):
        """Stage 2 of the tandem split: the per-token term (k3*b + k4)*l,
        so batch_time == prefill_time + decode_time exactly (Eq 18)."""
        b = np.asarray(b, np.float64)
        return (self.k3 * b + self.k4) * np.asarray(l, np.float64)

    def elastic_batch_time(self, ns):
        """Paper Eq (26): completion time of the slowest member when short
        replies exit early. ns: array of per-request output token counts."""
        ns = np.sort(np.asarray(ns, np.float64))
        b = len(ns)
        return self.k1 * b + self.k2 + self.k3 * ns.sum() + self.k4 * ns[-1]

    def elastic_completion_times(self, ns):
        """Per-request completion offsets within an elastic batch (sorted
        ascending): request j completes at
        k1*b + k2 + sum_{i<=j} (k3*(b-i) + k4) * (n_i - n_{i-1})."""
        ns = np.sort(np.asarray(ns, np.float64))
        b = len(ns)
        diffs = np.diff(np.concatenate([[0.0], ns]))
        rates = self.k3 * (b - np.arange(b)) + self.k4
        return self.k1 * b + self.k2 + np.cumsum(rates * diffs)

    def mean_batch_time(self, dist, b):
        """H^[b] = k1 b + k2 + (k3 b + k4) E[L_b]  (paper Eq 19/24)."""
        el = dist.max_order_stat_mean(b)
        return self.batch_time(b, el)

    def service_rate(self, dist, b):
        """mu^[b] = b / H^[b]  (paper Eq 24)."""
        b_arr = np.atleast_1d(np.asarray(b, np.float64))
        return b_arr / np.atleast_1d(self.mean_batch_time(dist, b_arr))

    def linear_envelope(self, dist, mode: str = "envelope",
                        b_range=None, quantile: float = 1.0):
        """(alpha, beta) with H^[b] <= alpha*b + beta, for Inoue's bound
        (paper Eq 20 for the uniform case; generalizes via L_inf)."""
        if mode == "envelope":
            linf = dist.max_order_stat_limit(quantile)
            return self.k1 + self.k3 * linf, self.k2 + self.k4 * linf
        bs = np.asarray(b_range if b_range is not None else np.arange(1, 129))
        h = self.mean_batch_time(dist, bs)
        # least-squares line, then shift up to dominate (exact envelope)
        A = np.stack([bs, np.ones_like(bs)], axis=1).astype(np.float64)
        coef, *_ = np.linalg.lstsq(A, h, rcond=None)
        alpha, beta = float(coef[0]), float(coef[1])
        beta += float(np.max(h - (alpha * bs + beta)))
        return alpha, beta


# ----------------------------------------------------------------------------
# Calibration
# ----------------------------------------------------------------------------

def fit_latency_model(tokens, seconds) -> LatencyModel:
    """Least-squares fit S = a*n + c (paper Fig 2a)."""
    n = np.asarray(tokens, np.float64)
    t = np.asarray(seconds, np.float64)
    A = np.stack([n, np.ones_like(n)], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    return LatencyModel(a=float(coef[0]), c=float(max(coef[1], 0.0)))


def fit_batch_latency_model(bs, ls, seconds) -> BatchLatencyModel:
    """Least-squares fit of Eq (18) from (batch, max_tokens, time) triples."""
    b = np.asarray(bs, np.float64)
    l = np.asarray(ls, np.float64)
    t = np.asarray(seconds, np.float64)
    A = np.stack([b, np.ones_like(b), b * l, l], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    k1, k2, k3, k4 = (float(max(c, 0.0)) for c in coef)
    return BatchLatencyModel(k1, k2, k3, k4)


def linear_fit_r2(x, y) -> float:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    return 1.0 - ss_res / max(ss_tot, 1e-12)
