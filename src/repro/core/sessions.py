"""Re-entrant agentic sessions: M/G/1 with feedback, at every layer.

Agentic workloads re-enter the queue: a request finishes a turn, leaves
for a tool call / user think time, and RETURNS as a new arrival of the
same session (Dai et al., "Throughput-Optimal Scheduling for LLM
Inference and AI Agents"; AugServe).  This module is the one definition
of that structure for all four layers:

  * **Session models** (registry): ``single`` (null, 1 turn),
    ``geometric`` (Bernoulli feedback with return probability p),
    ``chain`` (fixed k-turn agents), ``toolcall`` (capped geometric with
    exponential think time between turns).
  * **Expansion**: :func:`plan_sessions` / :func:`expand_workload` turn
    one sampled arrival stream of n sessions into per-turn rows
    (session id, turn index, parent row, think delay).  Turn counts,
    think times and the extra turns' token lengths are drawn from a
    salted ``_session_rng`` lane, so the base workload / predictor /
    fault / traffic streams stay bit-identical — a null model returns
    the original stream untouched (bit-equality by construction).
  * **Simulation** (oracle AND fast): one fixed-point runner per
    topology.  Turn t+1 of a session arrives at ``completion(turn t) +
    think``; completions depend on arrivals, so the re-arrival times are
    resolved by iterating the unchanged single-server engines (reference
    event loops when ``fast=False``, the compiled ``fastsim`` kernels
    when ``fast=True``) until the arrival vector is self-consistent.
    Both layers share this control flow — only the inner pass differs —
    so oracle ≡ fastsim under feedback is structural.
  * **Fleet**: the same fixed point with a routing pass per iteration;
    a ``session_affinity`` router (:mod:`repro.core.fleet`) makes turns
    sticky, and ``prefix_discount`` γ models KV/prefix reuse — a turn ≥ 2
    landing on its parent's replica serves ``tokens·(1−γ)`` (the engine
    keeps the session's ``kv_lens`` across turns, so the prefill work of
    the shared prefix is not repaid).  Routing work estimates stay
    UNdiscounted: routers see only arrivals + predictions (the design
    invariant), never downstream cache state.
  * **Analytics**: :func:`repro.core.mg1.mg1_feedback_wait` /
    :func:`repro.core.bulk.feedback_policy_delay` — the effective-load
    transfer λ_eff = λ·E[turns] with per-visit service moments.

Boundaries (by design, enforced with ``ValueError``):
``continuous`` has no discrete per-turn completion events, and
fixed-size batching deadlocks on the remnant tail under feedback — both
are rejected by :func:`check_policy_supports_sessions`.  The fleet
fault driver (``faults.simulate_fleet_faulty``) is not composed with
sessions; single-server session runs accept a ``fault_trace`` through
the same operational-time transform as PR 6 (think time stays
wall-clock).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Type

import numpy as np

from repro.core.policies import BatchPolicy, Workload, single_from_batch
from repro.core.latency_model import BatchLatencyModel

# Salted PRNG lane (same pattern as traffic.py's _TRAFFIC_SALT): session
# draws never consume the workload / predictor / fault / traffic streams.
_SESSION_SALT = 0x5E551011
_TURNS_LANE = 11        # per-session turn counts
_THINK_LANE = 13        # think-time delays for turns >= 2
_TOKENS_LANE = 17       # output-token lengths of turns >= 2
_PROMPT_LANE = 19       # serving-layer prompts of turns >= 2
_SESSION_PRED_LANE = 104729   # predicted lengths of turns >= 2

_MAX_PASSES = 200
_TOL = 1e-9


def _session_rng(seed, *lanes) -> np.random.Generator:
    parts = [int(k) for k in seed] if isinstance(seed, (tuple, list)) \
        else [int(seed)]
    return np.random.default_rng(np.random.SeedSequence(
        [_SESSION_SALT] + parts + [int(x) for x in lanes]))


# ----------------------------------------------------------------------------
# Session-model protocol + registry
# ----------------------------------------------------------------------------

SESSIONS: Dict[str, Type["SessionModel"]] = {}


def register_session(cls: Type["SessionModel"]) -> Type["SessionModel"]:
    SESSIONS[cls.name] = cls
    return cls


def get_session(name: str, **kwargs) -> "SessionModel":
    return SESSIONS[name](**kwargs)


def session_from_spec(spec) -> "SessionModel":
    """``SessionModel`` | name | ``{"name": ..., **params}`` -> instance;
    None means the null single-turn model."""
    if spec is None:
        return SingleSession()
    if isinstance(spec, SessionModel):
        return spec
    if isinstance(spec, str):
        return get_session(spec)
    spec = dict(spec)
    return get_session(spec.pop("name"), **spec)


def default_sessions() -> Dict[str, "SessionModel"]:
    """One representative (non-null where possible) instance per
    registered model — the set the conformance tests and the
    registry-coverage benchmark iterate."""
    return {
        "single": SingleSession(),
        "geometric": GeometricSession(p=0.5, think_mean=2.0),
        "chain": ChainSession(k=3, think=1.0),
        "toolcall": ToolcallSession(p=0.5, think_mean=1.0, max_turns=8),
    }


def null_sessions() -> Dict[str, "SessionModel"]:
    """A NULL (single-turn) instance per registered model, for the
    bit-equality conformance tests."""
    return {
        "single": SingleSession(),
        "geometric": GeometricSession(p=0.0),
        "chain": ChainSession(k=1),
        "toolcall": ToolcallSession(p=0.0),
    }


class SessionModel:
    """One re-entry law, defined once for every layer.

    ``is_null`` is the conformance switch: a null model (every session
    is exactly one turn) makes every entry point return the SAME objects
    / trajectories as the session-free code path, with zero extra rng
    draws — bit-equality by construction, like ``warp_workload``
    returning ``wl`` unchanged for null traffic."""

    name = "base"

    @property
    def is_null(self) -> bool:
        return False

    def mean_turns(self) -> float:
        """E[turns per session] — the feedback multiplier in
        λ_eff = λ·E[turns]."""
        raise NotImplementedError

    def draw_turns(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Turn counts (>= 1) for n sessions."""
        raise NotImplementedError

    def draw_think(self, rng: np.random.Generator, m: int) -> np.ndarray:
        """Think delays (>= 0) for m re-entries (turn >= 2 rows)."""
        raise NotImplementedError

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items() if v is not None}
        return f"{type(self).__name__}({keys})"


@register_session
class SingleSession(SessionModel):
    """The null model: every session is one turn.  All session entry
    points short-circuit to the historical code paths."""

    name = "single"

    @property
    def is_null(self) -> bool:
        return True

    def mean_turns(self) -> float:
        return 1.0

    def draw_turns(self, rng, n):
        return np.ones(n, np.int64)

    def draw_think(self, rng, m):
        return np.zeros(m)


@register_session
class GeometricSession(SessionModel):
    """Bernoulli feedback: after each turn the session returns with
    probability p, so turns ~ Geometric(1-p) with E[turns] = 1/(1-p) —
    the classic M/G/1-with-feedback model.  ``think_mean`` > 0 adds an
    exponential tool-call / user delay before each re-entry."""

    name = "geometric"

    def __init__(self, p: float = 0.5, think_mean: float = 0.0):
        assert 0.0 <= p < 1.0
        assert think_mean >= 0.0
        self.p = float(p)
        self.think_mean = float(think_mean)

    @property
    def is_null(self) -> bool:
        return self.p == 0.0

    def mean_turns(self) -> float:
        return 1.0 / (1.0 - self.p)

    def draw_turns(self, rng, n):
        if self.p == 0.0:
            return np.ones(n, np.int64)
        return rng.geometric(1.0 - self.p, n).astype(np.int64)

    def draw_think(self, rng, m):
        if self.think_mean == 0.0:
            return np.zeros(m)
        return rng.exponential(self.think_mean, m)


@register_session
class ChainSession(SessionModel):
    """Fixed k-turn agents (a deterministic plan: plan -> act -> ... ->
    summarize), with a deterministic think delay between turns."""

    name = "chain"

    def __init__(self, k: int = 3, think: float = 0.0):
        assert k >= 1 and think >= 0.0
        self.k = int(k)
        self.think = float(think)

    @property
    def is_null(self) -> bool:
        return self.k == 1

    def mean_turns(self) -> float:
        return float(self.k)

    def draw_turns(self, rng, n):
        return np.full(n, self.k, np.int64)

    def draw_think(self, rng, m):
        return np.full(m, self.think)


@register_session
class ToolcallSession(SessionModel):
    """Tool-calling agent: geometric feedback CAPPED at ``max_turns``
    (agents have an iteration budget), exponential think time (the tool
    round-trip).  E[turns] = (1 - p^max_turns) / (1 - p)."""

    name = "toolcall"

    def __init__(self, p: float = 0.5, think_mean: float = 1.0,
                 max_turns: int = 8):
        assert 0.0 <= p < 1.0 and think_mean >= 0.0 and max_turns >= 1
        self.p = float(p)
        self.think_mean = float(think_mean)
        self.max_turns = int(max_turns)

    @property
    def is_null(self) -> bool:
        return self.p == 0.0 or self.max_turns == 1

    def mean_turns(self) -> float:
        if self.p == 0.0:
            return 1.0
        return (1.0 - self.p ** self.max_turns) / (1.0 - self.p)

    def draw_turns(self, rng, n):
        if self.p == 0.0:
            return np.ones(n, np.int64)
        k = rng.geometric(1.0 - self.p, n).astype(np.int64)
        return np.minimum(k, self.max_turns)

    def draw_think(self, rng, m):
        if self.think_mean == 0.0:
            return np.zeros(m)
        return rng.exponential(self.think_mean, m)


# ----------------------------------------------------------------------------
# Expansion: one arrival stream of n sessions -> per-turn rows
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionPlan:
    """Session-major row layout: rows ``offsets[s] .. offsets[s] +
    turns[s] - 1`` are session s's turns 1..K_s in order; ``parent`` is
    the previous turn's row (-1 for turn 1); ``think`` is the delay
    between the parent's completion and this row's re-arrival (0 on
    first turns)."""

    session: np.ndarray     # int64 [total]
    turn: np.ndarray        # int64 [total], 1-based
    parent: np.ndarray      # int64 [total], -1 for first turns
    think: np.ndarray       # float64 [total], 0.0 for first turns
    turns: np.ndarray       # int64 [n_sessions]
    offsets: np.ndarray     # int64 [n_sessions], first row of each session

    @property
    def total(self) -> int:
        return len(self.session)

    @property
    def n_sessions(self) -> int:
        return len(self.turns)


def plan_sessions(model: SessionModel, n: int, seed) -> SessionPlan:
    """Draw the per-session structure from the salted session lanes."""
    turns = np.asarray(model.draw_turns(_session_rng(seed, _TURNS_LANE), n),
                       np.int64)
    total = int(turns.sum())
    session = np.repeat(np.arange(n, dtype=np.int64), turns)
    offsets = np.concatenate(([0], np.cumsum(turns)))[:-1].astype(np.int64)
    row = np.arange(total, dtype=np.int64)
    turn = row - np.repeat(offsets, turns) + 1
    parent = np.where(turn == 1, -1, row - 1).astype(np.int64)
    think = np.zeros(total)
    extra = np.nonzero(turn >= 2)[0]
    if len(extra):
        think[extra] = np.asarray(
            model.draw_think(_session_rng(seed, _THINK_LANE), len(extra)),
            np.float64)
    return SessionPlan(session=session, turn=turn, parent=parent,
                       think=think, turns=turns, offsets=offsets)


def plan_from_requests(reqs) -> tuple:
    """:class:`SessionPlan` view of an expanded serving request list
    (session-major reordering — request lists may arrive in any order).
    Returns ``(plan, order, lower_bound_arrivals)`` where ``order[p]``
    is the request index of plan row p."""
    sess = np.array([r.session for r in reqs], np.int64)
    turn = np.array([r.turn for r in reqs], np.int64)
    order_sm = np.lexsort((turn, sess))
    _, counts = np.unique(sess[order_sm], return_counts=True)
    counts = counts.astype(np.int64)
    offsets = np.concatenate(([0], np.cumsum(counts)))[:-1].astype(np.int64)
    t_in = turn[order_sm]
    row = np.arange(len(reqs), dtype=np.int64)
    plan = SessionPlan(
        session=np.repeat(np.arange(len(counts), dtype=np.int64), counts),
        turn=t_in, parent=np.where(t_in == 1, -1, row - 1),
        think=np.array([float(reqs[i].think) for i in order_sm]),
        turns=counts, offsets=offsets)
    lb = np.array([float(reqs[i].arrival) for i in order_sm])
    return plan, order_sm, lb


def expand_workload(wl: Workload, model: SessionModel,
                    dist, policy: BatchPolicy, seed):
    """Expand a base n-session workload into per-turn rows.  Turn-1 rows
    carry the base stream's tokens/predictions untouched; turns >= 2
    draw fresh lengths from the ``_TOKENS_LANE`` (clipped by the policy)
    and predictions from the ``_SESSION_PRED_LANE``.  The expanded
    arrivals are the LOWER BOUND ``base + cumulative think`` — the
    feedback fixed point raises each re-entry to its parent's completion
    + think.  Returns ``(Workload, SessionPlan)``."""
    n = len(wl.arrivals)
    plan = plan_sessions(model, n, seed)
    total = plan.total
    first = plan.offsets
    extra = np.nonzero(plan.turn >= 2)[0]
    tok = np.empty(total, np.float64)
    tok[first] = wl.tokens
    if len(extra):
        rng = _session_rng(seed, _TOKENS_LANE)
        et = dist.sample(rng, len(extra)).astype(np.float64) \
            if dist is not None else np.zeros(len(extra))
        tok[extra] = np.asarray(policy.clip(et), np.float64)
    pred = None
    if wl.predicted is not None:
        pred = np.empty(total, np.float64)
        pred[first] = wl.predicted
        if len(extra):
            ep = policy.predict_lengths((seed, _SESSION_PRED_LANE),
                                        tok[extra])
            pred[extra] = tok[extra] if ep is None else ep
    cs = np.cumsum(plan.think)
    cum = cs - np.repeat(cs[plan.offsets], plan.turns)
    arr = np.repeat(wl.arrivals, plan.turns) + cum
    ewl = Workload(arrivals=arr, tokens=tok, predicted=pred,
                   session=plan.session, turn=plan.turn)
    return ewl, plan


# ----------------------------------------------------------------------------
# Policy support gate
# ----------------------------------------------------------------------------

def check_policy_supports_sessions(policy: BatchPolicy) -> None:
    """Sessions need a discrete completion event per turn and must serve
    every offered row: continuous (iteration-level) batching has
    neither, and fixed-size batching deadlocks on the < b remnant tail
    once re-arrivals stop coming."""
    if policy.oracle_kind == "continuous":
        raise ValueError(
            "continuous batching has no per-turn completion events; "
            "sessions= is not supported (use the serving-layer engine "
            "path for iteration-level realism)")
    if any(policy.schedule_length(k) != k for k in (3, 7, 1001)):
        raise ValueError(
            "fixed-size batching deadlocks on the remnant tail under "
            "feedback (the last < b turns never form a batch); "
            "sessions= is not supported for this policy")


# ----------------------------------------------------------------------------
# Shared fixed-point machinery (oracle and fast differ only in the pass)
# ----------------------------------------------------------------------------

def _single_pass(policy, lam, dist, lat, seed, swl: Workload,
                 fast: bool) -> dict:
    """One single-server run on a fully materialized sorted workload,
    returning FULL per-row waits (no warmup trim) aligned to ``swl``'s
    row order."""
    from repro.core.simulate import ORACLES, no_warmup
    with no_warmup():
        if fast and policy.fast_kernel is not None:
            from repro.core.fastsim import KERNELS
            return KERNELS[policy.fast_kernel](
                policy, lam, dist, lat, len(swl.arrivals), seed,
                workload=swl)
        return ORACLES[policy.oracle_kind](policy, swl, lat, dist)


def _pass_completions(policy, lat, starts: np.ndarray, tokens: np.ndarray,
                      lost: np.ndarray) -> np.ndarray:
    """Per-row completion times recovered from service starts.  FCFS
    (oracle_kind 'mg1') serves one request per start; batch policies
    share one start per batch — on a single server consecutive batch
    starts are separated by at least one batch occupancy (>> float
    round-trip noise), so grouping equal starts recovers the batches and
    ``policy.batch_time`` the shared completion.  Lost rows (impatience)
    never occupy the server: completion = +inf."""
    comp = np.full(len(starts), np.inf)
    srv = np.nonzero(~lost)[0]
    if len(srv) == 0:
        return comp
    if policy.oracle_kind == "mg1":
        comp[srv] = starts[srv] + np.asarray(
            lat.service_time(tokens[srv]), np.float64)
        return comp
    order = srv[np.argsort(starts[srv], kind="stable")]
    ss = starts[order]
    brk = np.empty(len(ss), bool)
    brk[0] = True
    if len(ss) > 1:
        brk[1:] = np.diff(ss) > _TOL * np.maximum(1.0, np.abs(ss[1:]))
    bounds = np.nonzero(brk)[0]
    ends = np.append(bounds[1:], len(ss))
    for b0, b1 in zip(bounds, ends):
        members = order[b0:b1]
        comp[members] = ss[b0] + policy.batch_time(tokens[members], lat)
    return comp


def _nudge_ties(a: np.ndarray) -> np.ndarray:
    """Strictify a sorted arrival vector: exact re-arrival ties (children
    of one batch share a completion epoch, and chain/toolcall think times
    can be deterministic) are kept in row order but pushed one ulp apart.
    A re-arrival landing EXACTLY on a batch-formation epoch is a knife
    edge the reference event loops and the vectorized kernels resolve
    differently (>= vs >) — Poisson streams never produce exact ties, so
    only the feedback fixed point needs this.  Ulp-sized nudges shift
    waits by ~1e-14 and never move a row across a genuine gap."""
    if len(a) < 2:
        return a
    d = np.diff(a)
    if np.all(d > 0):
        return a
    new_run = np.concatenate(([True], d > 0))
    first = np.maximum.accumulate(
        np.where(new_run, np.arange(len(a)), 0))
    rank = np.arange(len(a)) - first
    out = a + rank * np.spacing(a)
    while True:                 # rare rounding collisions: fix up
        bad = np.nonzero(np.diff(out) <= 0)[0]
        if not len(bad):
            return out
        i = int(bad[0]) + 1
        out[i] = np.nextafter(out[i - 1], np.inf)


def _cascade_cancel(plan: SessionPlan, lost_row: np.ndarray) -> np.ndarray:
    """Rows whose ANY ancestor turn (within the session chain) was lost:
    those turns never re-enter the queue."""
    x = lost_row.astype(np.int64)
    cs = np.cumsum(x)
    before = cs - x                       # lost count among rows < i
    base = np.repeat(before[plan.offsets], plan.turns)
    return (before - base) > 0


def _session_summary(plan: SessionPlan, arr: np.ndarray, waits: np.ndarray,
                     comp: np.ndarray, cancelled: np.ndarray,
                     lost: np.ndarray) -> dict:
    """Per-session accounting shared by both simulator layers (and the
    scheduler wrappers): turn conservation (arrived = served + lost) and
    end-to-end latency of fully-served sessions (last-turn completion −
    first-turn arrival)."""
    arrived = ~cancelled
    served = arrived & ~lost
    n = plan.n_sessions
    srv_count = np.bincount(plan.session[served], minlength=n)
    complete = srv_count == plan.turns
    last_rows = plan.offsets + plan.turns - 1
    e2e = comp[last_rows[complete]] - arr[plan.offsets[complete]]
    out = {
        "n_sessions": int(n),
        "mean_turns": float(plan.turns.mean()),
        "turns_total": int(plan.total),
        "turns_arrived": int(arrived.sum()),
        "turns_served": int(served.sum()),
        "turns_lost": int(lost.sum()),
        "turns_cancelled": int(cancelled.sum()),
        "sessions_completed": int(complete.sum()),
        "mean_session_e2e": float(e2e.mean()) if e2e.size else 0.0,
        "p95_session_e2e": float(np.percentile(e2e, 95)) if e2e.size
        else 0.0,
        # per-row trajectories for conformance / consistency checks
        "rows": {
            "session": plan.session, "turn": plan.turn,
            "parent": plan.parent, "think": plan.think,
            "arrival": arr, "wait": waits, "completion": comp,
            "cancelled": cancelled, "lost": lost,
        },
    }
    return out


def _effective_tokens(tok: np.ndarray, plan: SessionPlan,
                      prefix_discount: float,
                      sticky: Optional[np.ndarray] = None) -> np.ndarray:
    """KV/prefix-reuse service law: a turn >= 2 whose KV cache survived
    (single server: always; fleet: landed on its parent's replica)
    serves ``tokens·(1−γ)``.  Membership predictions stay undiscounted."""
    if prefix_discount <= 0.0:
        return tok
    eff = tok.copy()
    reuse = plan.turn >= 2
    if sticky is not None:
        reuse = reuse & sticky
    eff[reuse] *= (1.0 - prefix_discount)
    return eff


def _tau_event_loop(plan: SessionPlan, tok: np.ndarray, lat, tau: float,
                    lb: np.ndarray, trace=None) -> tuple:
    """Causal engine for FCFS-with-impatience under feedback.  Shedding
    makes the generic fixed point non-contractive (losing a turn cancels
    its descendants, which empties the queue, which un-loses the turn —
    a two-cycle with no fixed point), so tau runs chronologically
    instead: pop the next arrival, apply the workload recursion with the
    PR 1 semantics (a lost row spends exactly tau in queue and adds no
    service, Eq 9), and enqueue the child at completion + think only
    when the turn was served.  The queue runs in operational time when a
    fault ``trace`` is given; think delays stay wall-clock.  On a null
    plan this IS the PR 1 recursion bit-for-bit (arrivals pop in the
    base order, identical float ops)."""
    import heapq
    total = plan.total
    service = np.asarray(lat.service_time(tok), np.float64)
    arr = lb.copy()
    w_row = np.full(total, np.nan)
    comp = np.full(total, np.inf)
    lost = np.zeros(total, bool)
    seen = np.zeros(total, bool)
    heap = [(float(lb[r]), int(r)) for r in plan.offsets]
    heapq.heapify(heap)
    order = []
    v = 0.0        # residual workload at the previous arrival (op time)
    t_prev = 0.0   # previous arrival epoch (op time)
    while heap:
        a_wall, r = heapq.heappop(heap)
        seen[r] = True
        arr[r] = a_wall
        order.append(r)
        a_q = float(trace.op_time(np.array([a_wall]))[0]) \
            if trace is not None else a_wall
        v = max(0.0, v - (a_q - t_prev))
        t_prev = a_q
        served = v < tau
        if served:
            w_row[r] = v
            c_q = a_q + v + service[r]
            v += service[r]
            comp[r] = float(trace.wall_time(np.array([c_q]))[0]) \
                if trace is not None else c_q
        else:
            w_row[r] = tau
            lost[r] = True
        nxt = r + 1
        if served and nxt < total and plan.parent[nxt] == r:
            heapq.heappush(heap, (comp[r] + float(plan.think[nxt]), nxt))
    ids = np.asarray(order, np.int64)
    return ids, arr, w_row, comp, lost, ~seen


# ----------------------------------------------------------------------------
# Single-server session runner (oracle when fast=False, kernels when True)
# ----------------------------------------------------------------------------

def simulate_policy_sessions(policy: BatchPolicy, lam: float, dist, lat,
                             num_requests: int, seed, model: SessionModel,
                             fault_trace=None, traffic=None,
                             prefix_discount: float = 0.0,
                             fast: bool = False) -> dict:
    """Single-server M/G/1-with-feedback: expand ``num_requests``
    sessions into per-turn rows and iterate the policy's unchanged
    engine until every re-arrival equals its parent's completion +
    think (the feedback fixed point).  FCFS impatience (tau) sheds
    turns: a lost turn terminates its session (descendants are
    cancelled and never arrive).  ``fault_trace`` composes through the
    PR 6 operational-time transform per pass — the queue runs in
    operational time, think delays stay wall-clock."""
    from repro.core.simulate import _warm
    check_policy_supports_sessions(policy)
    if policy.uses_single_latency and isinstance(lat, BatchLatencyModel):
        lat = single_from_batch(lat)
    wl = policy.sample_workload(lam, dist, num_requests, seed)
    if traffic is not None:
        from repro.core.traffic import warp_workload
        wl = warp_workload(wl, traffic, seed)
    ewl, plan = expand_workload(wl, model, dist, policy, seed)
    trace = fault_trace if (fault_trace is not None
                            and not fault_trace.empty) else None
    total = plan.total
    tok = _effective_tokens(ewl.tokens, plan, prefix_discount)
    pred = ewl.predicted
    tau = getattr(policy, "tau", None)
    lb = ewl.arrivals.copy()
    if tau is not None:
        # impatience shedding: no contractive fixed point exists (see
        # _tau_event_loop) — resolve causally; fast and oracle coincide.
        ids, arr, w_row, comp, lost, cancelled = _tau_event_loop(
            plan, tok, lat, float(tau), lb, trace)
        w = _warm(w_row[ids])
        lw = _warm(lost[ids])
        srv = w[~lw] if len(lw) == len(w) else w
        return {
            "mean_wait": float(w.mean()) if w.size else 0.0,
            "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
            "waits": w,
            "converged": True,
            "passes": 1,
            "loss_frac": float(lw.mean()) if lw.size else 0.0,
            "mean_wait_served": float(srv.mean()) if srv.size else 0.0,
            "sessions": _session_summary(plan, arr, w_row, comp,
                                         cancelled, lost),
        }
    arr = lb.copy()
    child_rows = np.nonzero(plan.parent >= 0)[0]
    cancelled = np.zeros(total, bool)
    lost = np.zeros(total, bool)
    converged = False
    w_row = np.full(total, np.nan)
    comp = np.full(total, np.inf)
    ids = np.arange(total)
    last_res: dict = {}
    passes = 0
    for passes in range(1, _MAX_PASSES + 1):
        canc_pass = cancelled       # the set that defines this pass's ids
        active = np.nonzero(~cancelled)[0]
        order = np.lexsort((active, arr[active]))
        ids = active[order]
        a_wall = arr[ids]
        a_q = trace.op_time(a_wall) if trace is not None else a_wall
        a_q = _nudge_ties(a_q)   # after op_time: down episodes flatten
        swl = Workload(arrivals=a_q, tokens=tok[ids],
                       inter=np.diff(a_q, prepend=0.0),
                       predicted=None if pred is None else pred[ids],
                       session=plan.session[ids], turn=plan.turn[ids])
        last_res = _single_pass(policy, lam, dist, lat, seed, swl, fast)
        waits_q = np.asarray(last_res["waits"], np.float64)
        lost_s = (waits_q >= tau - 1e-12) if tau is not None \
            else np.zeros(len(ids), bool)
        start_q = a_q + waits_q
        comp_q = _pass_completions(policy, lat, start_q, tok[ids], lost_s)
        if trace is not None:
            start_wall = trace.wall_time(start_q)
            fin = np.isfinite(comp_q)
            comp_wall = np.full(len(ids), np.inf)
            comp_wall[fin] = trace.wall_time(comp_q[fin])
        else:
            start_wall, comp_wall = start_q, comp_q
        comp = np.full(total, np.inf)
        comp[ids] = comp_wall
        w_row = np.full(total, np.nan)
        w_row[ids] = start_wall - a_wall
        lost_row = np.zeros(total, bool)
        lost_row[ids] = lost_s
        new_cancelled = _cascade_cancel(plan, lost_row)
        new_arr = arr.copy()
        new_arr[child_rows] = comp[plan.parent[child_rows]] \
            + plan.think[child_rows]
        # a parent not scheduled this pass (it was cancelled and the
        # cancel set just shrank) has comp=inf: park its live children
        # at the lower bound; the next passes re-resolve them
        unresolved = child_rows[~np.isfinite(new_arr[child_rows])]
        new_arr[unresolved] = lb[unresolved]
        new_arr[new_cancelled] = lb[new_cancelled]   # inert, keep finite
        live = child_rows[~new_cancelled[child_rows]]
        delta = float(np.max(np.abs(new_arr[live] - arr[live]))) \
            if len(live) else 0.0
        stable_sets = (np.array_equal(new_cancelled, cancelled)
                       and np.array_equal(lost_row, lost))
        arr, cancelled, lost = new_arr, new_cancelled, lost_row
        if stable_sets and delta <= _TOL:
            converged = True
            break
    # report the state of the LAST SIMULATED PASS: on the converged break
    # canc_pass == cancelled already; on pass exhaustion this keeps the
    # (ids, waits, completions, lost) tuple self-consistent instead of
    # pairing a post-update cancel set with the pre-update simulation
    cancelled = canc_pass
    waits_final = w_row[ids]
    w = _warm(waits_final)
    out = {
        "mean_wait": float(w.mean()) if w.size else 0.0,
        "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
        "waits": w,
        "converged": converged,
        "passes": passes,
        "sessions": _session_summary(plan, arr, w_row, comp, cancelled,
                                     lost),
    }
    if "mean_batch" in last_res:
        out["mean_batch"] = last_res["mean_batch"]
    if tau is not None:
        lost_final = lost[ids]
        lw = _warm(lost_final)
        srv = w[~lw] if len(lw) == len(w) else w
        out["loss_frac"] = float(lw.mean()) if lw.size else 0.0
        out["mean_wait_served"] = float(srv.mean()) if srv.size else 0.0
    return out


# ----------------------------------------------------------------------------
# Fleet session runner (routing pass per iteration; prefix-reuse discount)
# ----------------------------------------------------------------------------

def simulate_fleet_sessions(router, policy: BatchPolicy, lam: float, R: int,
                            dist, lat, num_requests: int, seed,
                            model: SessionModel,
                            prefix_discount: float = 0.0,
                            traffic=None, fast: bool = False) -> dict:
    """Fleet M/G/1-with-feedback: each fixed-point pass re-routes the
    materialized turn rows (routers see arrivals + UNdiscounted
    predictions, with the session column available for sticky hashing),
    runs every replica's sub-stream through the unchanged single-server
    engine, and re-enqueues turn t+1 at completion(t) + think.  With
    ``prefix_discount`` γ > 0 a turn >= 2 landing on its parent's
    replica serves ``tokens·(1−γ)`` — KV/prefix reuse, the quantity the
    affinity-vs-least_work trade-off is about.  Oracle (``fast=False``)
    and fastsim (``fast=True``) share this control flow."""
    from repro.core.fleet import router_from_spec
    from repro.core.simulate import _warm
    router = router_from_spec(router)
    check_policy_supports_sessions(policy)
    lat_run = single_from_batch(lat) if (policy.uses_single_latency and
                                         isinstance(lat, BatchLatencyModel)) \
        else lat
    wl = policy.sample_workload(lam, dist, num_requests, seed)
    if traffic is not None:
        from repro.core.traffic import warp_workload
        wl = warp_workload(wl, traffic, seed)
    ewl, plan = expand_workload(wl, model, dist, policy, seed)
    total = plan.total
    tok, pred = ewl.tokens, ewl.predicted
    tau = getattr(policy, "tau", None)
    lb = ewl.arrivals.copy()
    arr = lb.copy()
    child_rows = np.nonzero(plan.parent >= 0)[0]
    cancelled = np.zeros(total, bool)
    lost = np.zeros(total, bool)
    rep_row = np.full(total, -1, np.int64)
    converged = False
    w_row = np.full(total, np.nan)
    comp = np.full(total, np.inf)
    ids = np.arange(total)
    batch_stats = []
    passes = 0
    seen_states = set()
    for passes in range(1, _MAX_PASSES + 1):
        canc_pass = cancelled       # the set that defines this pass's ids
        active = np.nonzero(~cancelled)[0]
        order = np.lexsort((active, arr[active]))
        ids = active[order]
        swl = Workload(arrivals=arr[ids], tokens=tok[ids],
                       inter=np.diff(arr[ids], prepend=0.0),
                       predicted=None if pred is None else pred[ids],
                       session=plan.session[ids], turn=plan.turn[ids])
        work = router.routing_work(swl, lat, seed)
        rep_s = np.asarray(router.assign(swl.arrivals, work, R, seed,
                                         fast=fast, sessions=swl.session),
                           np.int64)
        new_rep = np.full(total, -1, np.int64)
        new_rep[ids] = rep_s
        sticky = np.zeros(total, bool)
        sticky[child_rows] = (new_rep[child_rows] >= 0) & \
            (new_rep[child_rows] == new_rep[plan.parent[child_rows]])
        eff = _effective_tokens(tok, plan, prefix_discount, sticky)
        comp = np.full(total, np.inf)
        w_row = np.full(total, np.nan)
        lost_row = np.zeros(total, bool)
        batch_stats = []
        for r in range(R):
            sub = ids[rep_s == r]
            if len(sub) == 0:
                continue
            a_r = _nudge_ties(arr[sub])
            rwl = Workload(arrivals=a_r, tokens=eff[sub],
                           inter=np.diff(a_r, prepend=0.0),
                           predicted=None if pred is None else pred[sub],
                           session=plan.session[sub], turn=plan.turn[sub])
            res = _single_pass(policy, lam, dist, lat_run, seed, rwl, fast)
            waits_r = np.asarray(res["waits"], np.float64)
            lost_r = (waits_r >= tau - 1e-12) if tau is not None \
                else np.zeros(len(sub), bool)
            start_r = a_r + waits_r
            comp[sub] = _pass_completions(policy, lat_run, start_r,
                                          eff[sub], lost_r)
            w_row[sub] = waits_r
            lost_row[sub] = lost_r
            if "mean_batch" in res:
                batch_stats.append((len(sub), res["mean_batch"]))
        new_cancelled = _cascade_cancel(plan, lost_row)
        new_arr = arr.copy()
        new_arr[child_rows] = comp[plan.parent[child_rows]] \
            + plan.think[child_rows]
        unresolved = child_rows[~np.isfinite(new_arr[child_rows])]
        new_arr[unresolved] = lb[unresolved]
        new_arr[new_cancelled] = lb[new_cancelled]
        live = child_rows[~new_cancelled[child_rows]]
        delta = float(np.max(np.abs(new_arr[live] - arr[live]))) \
            if len(live) else 0.0
        stable_sets = (np.array_equal(new_cancelled, cancelled)
                       and np.array_equal(lost_row, lost)
                       and np.array_equal(new_rep, rep_row))
        arr, cancelled, lost, rep_row = (new_arr, new_cancelled, lost_row,
                                         new_rep)
        if stable_sets and delta <= _TOL:
            converged = True
            break
        if not stable_sets:
            # shedding can cycle the lost/cancel sets (no fixed point —
            # see _tau_event_loop); a repeated set state will never
            # converge, so stop early and report it honestly
            state = (new_cancelled.tobytes(), lost_row.tobytes(),
                     new_rep.tobytes())
            if state in seen_states:
                break
            seen_states.add(state)
    # see simulate_policy_sessions: keep the reported state aligned with
    # the last simulated pass when the loop exhausts without converging
    cancelled = canc_pass
    waits_final = w_row[ids]
    w = _warm(waits_final)
    out = {
        "mean_wait": float(w.mean()) if w.size else 0.0,
        "p50_wait": float(np.percentile(w, 50)) if w.size else 0.0,
        "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
        "p99_wait": float(np.percentile(w, 99)) if w.size else 0.0,
        "waits": w,
        "replica_of": rep_row[ids],
        "replica_counts": np.bincount(rep_row[ids], minlength=R),
        "converged": converged,
        "passes": passes,
        "prefix_discount": float(prefix_discount),
        "sessions": _session_summary(plan, arr, w_row, comp, cancelled,
                                     lost),
    }
    if batch_stats:
        nb = sum(m / max(mb, 1e-12) for m, mb in batch_stats)
        out["mean_batch"] = float(sum(m for m, _ in batch_stats)
                                  / max(nb, 1e-12))
    if tau is not None:
        lost_final = lost[ids]
        lw = _warm(lost_final)
        srv = w[~lw] if len(lw) == len(w) else w
        out["loss_frac"] = float(lw.mean()) if lw.size else 0.0
        out["mean_wait_served"] = float(srv.mean()) if srv.size else 0.0
    return out


__all__ = [
    "SESSIONS",
    "ChainSession",
    "GeometricSession",
    "SessionModel",
    "SessionPlan",
    "SingleSession",
    "ToolcallSession",
    "check_policy_supports_sessions",
    "default_sessions",
    "expand_workload",
    "get_session",
    "null_sessions",
    "plan_from_requests",
    "plan_sessions",
    "register_session",
    "session_from_spec",
    "simulate_fleet_sessions",
    "simulate_policy_sessions",
]
