"""Fault models: deterministic failure injection for every layer.

The paper's queueing models (and the PR 5 fleet built on them) assume
servers never fail; the ROADMAP's production north-star does not get that
luxury.  This module makes faults a first-class *registered* component,
mirroring the policy / predictor / router registries: a
:class:`FaultModel` describes how replicas break, and the SAME model is
injected into all four layers —

  * the reference oracle and the compiled kernels through an
    **operational-time transform** (below) plus a shared host-side
    retry driver (:func:`simulate_fleet_faulty`),
  * the analytic layer through :func:`repro.core.bulk.breakdown_wait`
    (M/G/1-with-breakdowns completion-time decomposition) and the
    availability-discounted :func:`effective_lambda` transfer,
  * the serving layer through :mod:`repro.serving.resilience`
    (drain / re-dispatch / hedging / dedup on real schedulers+engines).

Registered models (``FAULTS``; docs/faults.md is CI-gated to mention
every one):

  * ``none``     — the null model; every layer is bit-equal to its
    fault-free PR 5 behaviour (pinned by ``tests/test_faults.py``).
  * ``crash``    — replica crash/repair as an **alternating renewal
    process**: up-times ~ Exp(mtbf), down-times ~ Exp(mttr).  While
    down a replica serves nothing and accepts no arrivals; at a crash
    epoch the replica's in-flight batch AND local queue are lost and the
    affected requests are re-dispatched (exponential backoff) to the
    back of a surviving replica's queue.  ``lose_work=False`` switches
    to preemptive-resume semantics (service freezes, nothing is lost) —
    the exactly-analyzable M/G/1-with-breakdowns mode the closed form in
    :func:`repro.core.bulk.breakdown_wait` is validated against.
  * ``slowdown`` — straggler episodes (alternating renewal like crash)
    during which the replica runs at ``1/factor`` speed: the latency law
    is scaled, nothing is lost, arrivals are still accepted.
  * ``drop``     — per-request admission drop with probability ``p``
    (shed at the dispatcher; never enters any queue).

Determinism: every random draw comes from ``np.random.default_rng`` on a
``SeedSequence`` salted with ``_FAULT_SALT`` — a stream independent of
the workload, predictor (``_PRED_SALT``) and router (``_ROUTE_SALT``)
streams, so turning a fault model on NEVER perturbs the sampled workload
(bit-identical arrivals/tokens), and the same (seed, replica) always
yields the same failure epochs on every layer.

The operational-time transform
------------------------------

A replica with episodes ``[s_k, e_k)`` running at speed ``phi`` during
an episode (0 for crash, 1/factor for slowdown) accumulates service
capacity ``A(t) = \\int_0^t speed(u) du``.  A work-conserving queue on a
breaking server is EXACTLY the fault-free queue run in operational time:
map arrivals ``t -> A(t)``, run the unchanged single-server event loop /
kernel, and map service starts back through the inverse ``A^{-1}``.
Batch-formation timers (WAIT timeouts, dynamic triggers) run on the
replica's operational clock — the clock freezes while the replica is
down — which is what makes the transform exact rather than approximate.
Crash-mode work LOSS is layered on top by the retry driver: at each
crash epoch, entries still in system are removed and re-dispatched, and
the replica trajectory is recomputed — identical across oracle and
fastsim because the driver is shared and only the per-replica simulator
(reference loop vs compiled kernel) differs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core.latency_model import BatchLatencyModel
from repro.core.policies import BatchPolicy, Workload

# Salt for every fault-model rng stream: independent of the workload
# stream, the predictor stream (_PRED_SALT) and the router stream
# (_ROUTE_SALT), so fault injection never perturbs the sampled workload.
_FAULT_SALT = 0xFA111E57
# Key lanes inside the fault stream (episode draws use the replica id
# as the lane), kept disjoint from replica ids by a large offset.
_DROP_LANE = 1_000_003
_REROUTE_LANE = 1_000_033
_RETRY_LANE = 1_000_081


def _fault_rng(seed, *lanes) -> np.random.Generator:
    parts = [int(k) for k in seed] if isinstance(seed, (tuple, list)) \
        else [int(seed)]
    return np.random.default_rng(np.random.SeedSequence(
        [_FAULT_SALT] + parts + [int(x) for x in lanes]))


# ----------------------------------------------------------------------------
# Replica fault trace + the operational-time transform
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaTrace:
    """One replica's failure epochs: disjoint sorted episodes
    ``[starts_k, ends_k)`` served at ``speed`` (0 = down, (0,1) =
    straggling).  All transform math lives here so the oracle and the
    fast layer share bit-identical host-side arithmetic."""

    starts: np.ndarray
    ends: np.ndarray
    speed: float = 0.0

    @property
    def empty(self) -> bool:
        return len(self.starts) == 0

    # capacity lost inside episodes before each episode start (cum[k] =
    # capacity lost in episodes 0..k-1); one extra entry for "after all"
    def _cumloss(self) -> np.ndarray:
        lost = (1.0 - self.speed) * (self.ends - self.starts)
        return np.concatenate([[0.0], np.cumsum(lost)])

    def op_time(self, t) -> np.ndarray:
        """A(t): cumulative service capacity by wall time t."""
        t = np.asarray(t, np.float64)
        if self.empty:
            return t.copy()
        cum = self._cumloss()
        j = np.searchsorted(self.starts, t, side="right")
        inside = (j > 0) & (t < self.ends[np.maximum(j - 1, 0)])
        k = np.maximum(j - 1, 0)
        # written so that speed=0 yields EXACTLY starts[k] - cum[k] (the
        # same float ops wall_time uses for its flat levels), keeping the
        # flat-skip branch bit-stable under rounding
        a_in = (self.starts[k] - cum[k]) + self.speed * (t - self.starts[k])
        a_out = t - cum[j]
        return np.where(inside, a_in, a_out)

    def wall_time(self, u) -> np.ndarray:
        """Inverse transform: earliest wall time at which the replica has
        accumulated capacity u, skipping zero-speed flats (a service
        event landing exactly on a down episode's capacity level resumes
        at the episode END — the server is down until then)."""
        u = np.asarray(u, np.float64)
        if self.empty:
            return u.copy()
        cum = self._cumloss()
        a_starts = self.starts - cum[:-1]          # A at episode starts
        a_ends = self.ends - cum[1:]               # A at episode ends
        j = np.searchsorted(a_starts, u, side="right")
        k = np.maximum(j - 1, 0)
        inside = (j > 0) & (u <= a_ends[k])
        if self.speed > 0.0:
            t_in = self.starts[k] + (u - a_starts[k]) / self.speed
        else:
            t_in = self.ends[k]                    # skip the flat
        t_out = u + cum[j]
        return np.where(inside, t_in, t_out)

    def up_at(self, t) -> np.ndarray:
        """Accepting arrivals at wall time t?  Down only inside a
        speed-0 (crash) episode; straggling replicas still accept."""
        t = np.asarray(t, np.float64)
        if self.empty or self.speed > 0.0:
            return np.ones(t.shape, bool)
        j = np.searchsorted(self.starts, t, side="right")
        return ~((j > 0) & (t < self.ends[np.maximum(j - 1, 0)]))

    def next_up(self, t) -> np.ndarray:
        """Earliest wall time >= t at which the replica accepts again."""
        t = np.asarray(t, np.float64)
        if self.empty or self.speed > 0.0:
            return t.copy()
        j = np.searchsorted(self.starts, t, side="right")
        k = np.maximum(j - 1, 0)
        inside = (j > 0) & (t < self.ends[k])
        return np.where(inside, self.ends[k], t)

    def crash_starts(self) -> np.ndarray:
        return self.starts if self.speed == 0.0 else np.zeros(0)

    def availability(self, T: float) -> float:
        """Fraction of [0, T] the replica is up (speed-0 episodes only)."""
        if self.empty or self.speed > 0.0 or T <= 0:
            return 1.0
        down = np.clip(np.minimum(self.ends, T)
                       - np.minimum(self.starts, T), 0.0, None).sum()
        return float(1.0 - down / T)


_EMPTY_TRACE = ReplicaTrace(np.zeros(0), np.zeros(0), 0.0)


def _renewal_episodes(rng: np.random.Generator, mean_up: float,
                      mean_down: float, horizon: float):
    """Alternating renewal episodes on [0, horizon]: up ~ Exp(mean_up),
    down ~ Exp(mean_down), starting up at t=0.  Infinite means yield no
    episodes / episodes clamped at the horizon."""
    if not np.isfinite(mean_up) or mean_up <= 0 or horizon <= 0:
        return np.zeros(0), np.zeros(0)
    md = mean_down if np.isfinite(mean_down) else 0.0
    cycle = mean_up + md
    starts_parts: List[np.ndarray] = []
    ends_parts: List[np.ndarray] = []
    t = 0.0
    while t < horizon:
        # Draw a block of whole up/down cycles at once; expected count plus
        # a safety margin so almost every horizon needs a single block.
        est = (horizon - t) / cycle
        m = int(est + 6.0 * math.sqrt(est + 1.0)) + 16
        ups = rng.exponential(mean_up, m)
        downs = rng.exponential(mean_down, m) if np.isfinite(mean_down) \
            else np.full(m, math.inf)
        s = t + np.cumsum(ups) + np.concatenate(
            ([0.0], np.cumsum(downs)[:-1]))
        e = np.minimum(s + downs, horizon)
        keep = s < horizon
        starts_parts.append(s[keep])
        ends_parts.append(e[keep])
        if not keep.all():          # horizon reached inside this block
            t = horizon
            break
        t = float(e[-1])
        if not np.isfinite(mean_down):
            break
    starts = np.concatenate(starts_parts) if starts_parts else np.zeros(0)
    ends = np.concatenate(ends_parts) if ends_parts else np.zeros(0)
    # A down period pinned at the horizon absorbs everything after it.
    cut = np.searchsorted(ends, horizon, "left") + 1
    return starts[:cut], ends[:cut]


# ----------------------------------------------------------------------------
# Fault-model registry
# ----------------------------------------------------------------------------

FAULTS: Dict[str, Type["FaultModel"]] = {}


def register_fault(cls: Type["FaultModel"]) -> Type["FaultModel"]:
    FAULTS[cls.name] = cls
    return cls


def get_fault(name: str, **kwargs) -> "FaultModel":
    return FAULTS[name](**kwargs)


def fault_from_spec(spec) -> "FaultModel":
    """``FaultModel`` | registry name | ``{"kind": name, **params}`` |
    None (the null model) -> instance."""
    if spec is None:
        return NoFaults()
    if isinstance(spec, FaultModel):
        return spec
    if isinstance(spec, str):
        return get_fault(spec)
    spec = dict(spec)
    return get_fault(spec.pop("kind"), **spec)


def default_faults() -> Dict[str, "FaultModel"]:
    """One representative instance per registered model — the set the
    fault tests and the registry-driven benchmarks iterate."""
    return {
        "none": NoFaults(),
        "crash": CrashRepair(mtbf=200.0, mttr=10.0),
        "slowdown": Slowdown(mtbf=150.0, duration=15.0, factor=3.0),
        "drop": RequestDrop(p=0.05),
    }


class FaultModel:
    """One failure discipline, defined once for every layer.

    ``trace(seed, replica, horizon)`` draws that replica's episodes from
    the salted fault stream; ``drop_mask(seed, n)`` the per-request
    admission drops; ``capacity()`` the long-run service-capacity factor
    the analytic layer discounts λ by (:func:`effective_lambda`)."""

    name = "base"
    lose_work = False            # crash-mode work loss (retry driver)
    max_retries = 3
    retry_backoff = 0.0

    def trace(self, seed, replica: int, horizon: float) -> ReplicaTrace:
        return _EMPTY_TRACE

    def drop_mask(self, seed, n: int) -> np.ndarray:
        return np.zeros(n, bool)

    def capacity(self) -> float:
        return 1.0

    @property
    def is_null(self) -> bool:
        return True

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items() if v is not None}
        return f"{type(self).__name__}({keys})"


@register_fault
class NoFaults(FaultModel):
    """The null model: no episodes, no drops.  Every layer run under it
    is bit-equal to the fault-free path (pinned in tests)."""

    name = "none"


@register_fault
class CrashRepair(FaultModel):
    """Replica crash/repair as an alternating renewal process: up-times
    ~ Exp(``mtbf``), down-times ~ Exp(``mttr``).  Down replicas accept
    no arrivals and serve nothing.  ``lose_work=True`` (default): at a
    crash epoch the in-flight batch and the local queue are lost and
    re-dispatched with backoff ``retry_backoff * 2**attempt`` (at most
    ``max_retries`` attempts, then the request is failed).
    ``lose_work=False``: preemptive-resume — the replica freezes and
    continues after repair; nothing is re-dispatched (the exactly-
    analyzable M/G/1-with-breakdowns mode)."""

    name = "crash"

    def __init__(self, mtbf: float = 200.0, mttr: float = 10.0,
                 lose_work: bool = True, retry_backoff: float = 0.1,
                 max_retries: int = 3):
        assert mtbf > 0 and mttr > 0
        self.mtbf = float(mtbf)
        self.mttr = float(mttr)
        self.lose_work = bool(lose_work)
        self.retry_backoff = float(retry_backoff)
        self.max_retries = int(max_retries)

    def trace(self, seed, replica: int, horizon: float) -> ReplicaTrace:
        rng = _fault_rng(seed, replica)
        s, e = _renewal_episodes(rng, self.mtbf, self.mttr, horizon)
        return ReplicaTrace(s, e, 0.0)

    def capacity(self) -> float:
        if not np.isfinite(self.mtbf):
            return 1.0
        return self.mtbf / (self.mtbf + self.mttr)

    @property
    def is_null(self) -> bool:
        return not np.isfinite(self.mtbf)


@register_fault
class Slowdown(FaultModel):
    """Straggler episodes: alternating renewal with normal periods
    ~ Exp(``mtbf``) and episodes ~ Exp(``duration``) during which the
    replica serves at 1/``factor`` speed (the latency law is scaled).
    Nothing is lost and arrivals are still accepted — delay comes purely
    through the operational-time stretch."""

    name = "slowdown"

    def __init__(self, mtbf: float = 150.0, duration: float = 15.0,
                 factor: float = 3.0):
        assert factor >= 1.0 and mtbf > 0 and duration > 0
        self.mtbf = float(mtbf)
        self.duration = float(duration)
        self.factor = float(factor)

    def trace(self, seed, replica: int, horizon: float) -> ReplicaTrace:
        rng = _fault_rng(seed, replica)
        s, e = _renewal_episodes(rng, self.mtbf, self.duration, horizon)
        return ReplicaTrace(s, e, 1.0 / self.factor)

    def capacity(self) -> float:
        if not np.isfinite(self.mtbf):
            return 1.0
        frac = self.duration / (self.mtbf + self.duration)
        return 1.0 - (1.0 - 1.0 / self.factor) * frac

    @property
    def is_null(self) -> bool:
        return not np.isfinite(self.mtbf) or self.factor == 1.0


@register_fault
class RequestDrop(FaultModel):
    """Per-request admission drop with probability ``p``: the dispatcher
    sheds the request before it enters any queue (counted, never
    served).  Replicas themselves never fail."""

    name = "drop"

    def __init__(self, p: float = 0.05):
        assert 0.0 <= p <= 1.0
        self.p = float(p)

    def drop_mask(self, seed, n: int) -> np.ndarray:
        if self.p <= 0.0:
            return np.zeros(n, bool)
        return _fault_rng(seed, _DROP_LANE).random(n) < self.p

    @property
    def is_null(self) -> bool:
        return self.p <= 0.0


def effective_lambda(lam: float, fault) -> float:
    """Availability-discounted arrival rate: a server delivering capacity
    factor a serves the same offered load as a fault-free server at
    λ/a — the transfer that carries every single-server closed form to
    the faulty regime (exact for preemptive-resume crash in operational
    time; first-order for slowdown)."""
    return float(lam) / fault_from_spec(fault).capacity()


# ----------------------------------------------------------------------------
# Availability-masked routing
# ----------------------------------------------------------------------------

def up_matrix(traces: List[ReplicaTrace], times: np.ndarray) -> np.ndarray:
    """[n, R] availability mask at each arrival instant.  A row with
    every replica down is patched to admit the replica that recovers
    first (the dispatcher holds the request until then), so masked
    assignment always has a candidate."""
    times = np.asarray(times, np.float64)
    up = np.stack([tr.up_at(times) for tr in traces], axis=1)
    dead = ~up.any(axis=1)
    if dead.any():
        rec = np.stack([tr.next_up(times) for tr in traces], axis=1)
        first = np.argmin(rec, axis=1)
        up[dead, first[dead]] = True
    return up


def masked_assign(router, arrivals, work, R: int, seed, up: np.ndarray,
                  fast: bool = False, sessions=None) -> np.ndarray:
    """Availability-aware replica assignment.  Backlog routers get the
    mask INSIDE the recursion (down replicas' virtual work is +inf in
    the argmin — the jitted ``lax.scan`` twin in fastsim carries the
    same mask row per arrival); routers that define their own
    ``masked_assign`` (session affinity's sticky probing) keep their
    law; other stateless routers assign as usual and any request landing
    on a down replica is re-drawn uniformly among the up ones from the
    fault-salted rng.  With every replica up all paths reduce exactly to
    the PR 5 assignment."""
    from repro.core.fleet import router_from_spec
    router = router_from_spec(router)
    arrivals = np.asarray(arrivals, np.float64)
    work = np.asarray(work, np.float64)
    up = np.asarray(up, bool)
    if hasattr(router, "masked_assign"):
        return np.asarray(
            router.masked_assign(arrivals, work, R, seed, up, fast=fast,
                                 sessions=sessions), np.int64)
    if router.state_dependent:
        w = router._work_units(work)
        if fast:
            from repro.core.fastsim import masked_backlog_route
            return masked_backlog_route(arrivals, w, up, R)
        from repro.core.fleet import _masked_backlog_assign_np
        return _masked_backlog_assign_np(arrivals, w, R, up)
    rep = np.asarray(router.assign(arrivals, work, R, seed, fast=fast,
                                   sessions=sessions),
                     np.int64)
    bad = np.nonzero(~up[np.arange(len(rep)), rep])[0]
    if len(bad):
        u = _fault_rng(seed, _REROUTE_LANE).random(len(rep))
        for i in bad:
            cand = np.nonzero(up[i])[0]
            rep[i] = int(cand[int(u[i] * len(cand)) % len(cand)])
    return rep


def replay_backlog(arrivals, work, rep, R: int,
                   t: Optional[float] = None) -> np.ndarray:
    """Virtual per-replica work backlog after replaying FROZEN
    assignments (Lindley decay + add assigned work), evaluated at time
    ``t`` (default: just after the last arrival).  Used to route retry
    re-dispatches against the live backlog state and to estimate
    per-request waits for SLO hedging (:mod:`repro.serving.resilience`)."""
    v = np.zeros(R)
    t_prev = 0.0
    for a, w, r in zip(arrivals, work, rep):
        v = np.maximum(0.0, v - (a - t_prev))
        t_prev = a
        v[int(r)] += w
    if t is not None:
        v = np.maximum(0.0, v - (max(float(t), t_prev) - t_prev))
    return v


# ----------------------------------------------------------------------------
# The fault-injected fleet driver (shared by oracle and fastsim)
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class _Entry:
    """One dispatch attempt of one request."""
    req: int
    arrival: float
    replica: int
    attempt: int


def _entry_workload(entries: List[_Entry], wl: Workload,
                    trace: ReplicaTrace):
    """A replica's current entries as an operational-time Workload (plus
    the sorted entry list and op arrivals).  Sorting is deterministic:
    (arrival, request id, attempt)."""
    entries = sorted(entries, key=lambda e: (e.arrival, e.req, e.attempt))
    arr = np.array([e.arrival for e in entries], np.float64)
    op_arr = trace.op_time(arr)
    idx = np.array([e.req for e in entries], np.int64)
    sub = Workload(
        arrivals=op_arr, tokens=wl.tokens[idx],
        inter=np.diff(op_arr, prepend=0.0),
        predicted=None if wl.predicted is None else wl.predicted[idx])
    return entries, arr, op_arr, sub


def _replica_waits(policy: BatchPolicy, sub: Workload, lam, dist, lat,
                   fast: bool) -> np.ndarray:
    """Full (untrimmed) operational-time waits for a replica's entry
    workload — reference loop or compiled kernel, unchanged."""
    from repro.core.simulate import no_warmup, simulate_policy
    with no_warmup():
        if fast:
            from repro.core.fastsim import simulate_policy_fast
            res = simulate_policy_fast(policy, lam, dist, lat, workload=sub)
        else:
            res = simulate_policy(policy, lam, dist, lat, workload=sub)
    return np.asarray(res["waits"], np.float64)


def simulate_fleet_faulty(router, policy: BatchPolicy, lam: float, R: int,
                          dist, lat, fault, num_requests: int = 20_000,
                          seed: int = 0, fast: bool = False,
                          traffic=None) -> dict:
    """Fault-injected fleet simulation — ONE driver for both layers
    (``fast=False``: reference event loops; ``fast=True``: compiled
    kernels), so oracle and fastsim see identical failure epochs,
    identical masked routing and identical retry re-dispatches.

    Null fault models delegate verbatim to the PR 5 fleet paths
    (:func:`repro.core.fleet.route_oracle` /
    :func:`repro.core.fastsim.simulate_fleet_fast`) — fault rate 0 is
    bit-equal to the fault-free fleet by construction.

    With faults on: the global stream is sampled unchanged (fault draws
    live on their own salted stream), admission drops are shed, primary
    dispatch uses availability-masked routing, and each crash epoch —
    processed in global time order — kills the victims still in system
    on that replica (in-flight batch + local queue), re-dispatching them
    to a surviving replica at ``epoch + backoff * 2**attempt``.  Waits
    are reported against each request's ORIGINAL arrival.  Returns the
    fleet aggregate plus fault accounting (conservation:
    ``served + shed + failed + unserved == arrived``).

    ``traffic`` (a :mod:`repro.core.traffic` model, name or spec)
    modulates the arrival rate via the time-rescaling warp; the fault
    stream is salted independently, so modulation never perturbs the
    failure epochs (and vice versa)."""
    from repro.core.fleet import router_from_spec
    from repro.core.simulate import _warm
    fault = fault_from_spec(fault)
    router = router_from_spec(router)

    wl = policy.sample_workload(lam, dist, num_requests, seed)
    if traffic is not None:
        from repro.core.traffic import warp_workload
        wl = warp_workload(wl, traffic, seed)
    n = len(wl.arrivals)
    horizon = float(wl.arrivals[-1]) * 2.0 + 1.0
    traces = [fault.trace(seed, r, horizon) for r in range(R)]
    drop = fault.drop_mask(seed, n)

    if all(tr.empty for tr in traces) and not drop.any():
        if fast:
            from repro.core.fastsim import simulate_fleet_fast
            res = simulate_fleet_fast(router, policy, lam, R, dist, lat,
                                      num_requests=num_requests, seed=seed,
                                      traffic=traffic)
        else:
            from repro.core.fleet import route_oracle
            res = route_oracle(router, policy, lam, R, dist, lat,
                               num_requests=num_requests, seed=seed,
                               traffic=traffic)
        res.update(shed=0, retries=0, failed=0, unserved=0,
                   availability=[1.0] * R, n_arrived=n, n_served=n)
        return res

    # ---- admitted stream + per-request routing work -------------------
    adm = np.nonzero(~drop)[0]
    gwl = Workload(arrivals=wl.arrivals[adm], tokens=wl.tokens[adm],
                   inter=np.diff(wl.arrivals[adm], prepend=0.0),
                   predicted=None if wl.predicted is None
                   else wl.predicted[adm])
    work_adm = router.routing_work(gwl, lat, seed)
    work_of = np.zeros(n)
    work_of[adm] = work_adm                   # per-request work estimate
    proxy = np.zeros(n)                       # service proxy (op seconds)
    if lat is None or policy.uses_single_latency \
            or not isinstance(lat, BatchLatencyModel):
        proxy[adm] = router.work_from_lengths(gwl.tokens, lat)
    else:
        # Amortized per-request cost under large-batch serving — the same
        # alpha = k1 + k3*len the control layer uses for capacity; the
        # single-request law would overstate in-system time by the batch
        # width and mass-kill on every epoch.
        proxy[adm] = lat.k1 + lat.k3 * np.asarray(gwl.tokens, np.float64)

    # ---- primary dispatch: availability-masked routing ----------------
    up = up_matrix(traces, gwl.arrivals)
    rep = masked_assign(router, gwl.arrivals, work_adm, R, seed, up,
                        fast=fast)
    by_rep: List[List[_Entry]] = [[] for _ in range(R)]
    for i, g in enumerate(adm):
        by_rep[int(rep[i])].append(_Entry(int(g), float(gwl.arrivals[i]),
                                          int(rep[i]), 0))
    failed: List[int] = []
    retries = 0

    # ---- crash epochs in global time order (kill + re-dispatch) -------
    if fault.lose_work:
        epochs = sorted((float(f), r) for r in range(R)
                        for f in traces[r].crash_starts())
        for f, r in epochs:
            if not by_rep[r]:
                continue
            entries, arr, op_arr, sub = _entry_workload(by_rep[r], wl,
                                                        traces[r])
            m = policy.schedule_length(len(entries))
            # Victims are picked by a work-conserving FCFS progress proxy
            # (Lindley on the routing work units, in operational time).
            # The proxy is host-side and layer-independent, so oracle and
            # fastsim kill identical victim sets regardless of float-level
            # differences in their per-replica trajectories; the policy
            # sim runs once per replica at the end for reported waits.
            svc = proxy[[e.req for e in entries]]
            c = np.concatenate(([0.0], np.cumsum(svc[:-1])))
            start = np.maximum.accumulate(op_arr - c) + c
            comp = start + svc
            if m < len(entries):
                comp[m:] = np.inf        # never scheduled => still queued
            a_f = float(traces[r].op_time([f])[0])
            kill = np.nonzero((arr < f) & (comp > a_f))[0]
            if not len(kill):
                continue
            keep = set(range(len(entries))) - set(int(k) for k in kill)
            by_rep[r] = [entries[i] for i in sorted(keep)]
            u = _fault_rng(seed, _RETRY_LANE, int(round(f * 1e6)) % (1 << 31)
                           ).random(len(kill))
            for j, k in enumerate(kill):
                e = entries[int(k)]
                if e.attempt + 1 > fault.max_retries:
                    failed.append(e.req)
                    continue
                # (j+1)*1e-9 spaces victims re-entering at the same epoch:
                # exactly-tied arrivals sit on a batch-formation boundary
                # where oracle and kernel may disagree ('<' vs '<=').
                t_new = f + fault.retry_backoff * (2.0 ** e.attempt) \
                    + (j + 1) * 1e-9
                row = up_matrix(traces, np.array([t_new]))[0]
                if router.state_dependent:
                    flat = [x for lst in by_rep for x in lst]
                    flat.sort(key=lambda x: (x.arrival, x.req, x.attempt))
                    v = replay_backlog(
                        [x.arrival for x in flat],
                        router._work_units(work_of[[x.req for x in flat]]),
                        [x.replica for x in flat], R, t=t_new)
                    r_new = int(np.argmin(np.where(row, v, np.inf)))
                else:
                    cand = np.nonzero(row)[0]
                    r_new = int(cand[int(u[j] * len(cand)) % len(cand)])
                by_rep[r_new].append(_Entry(e.req, float(t_new), r_new,
                                            e.attempt + 1))
                retries += 1

    # ---- final trajectories -------------------------------------------
    waits_of = np.full(n, np.nan)
    final_rep = np.full(n, -1, np.int64)
    unserved: List[int] = []
    for r in range(R):
        if not by_rep[r]:
            continue
        entries, arr, op_arr, sub = _entry_workload(by_rep[r], wl,
                                                    traces[r])
        m = policy.schedule_length(len(entries))
        for e in entries[m:]:
            unserved.append(e.req)
        if m == 0:
            continue
        waits = _replica_waits(policy, Workload(
            arrivals=sub.arrivals[:m], tokens=sub.tokens[:m],
            inter=None if sub.inter is None else sub.inter[:m],
            predicted=None if sub.predicted is None
            else sub.predicted[:m]), lam, dist, lat, fast)
        start_wall = traces[r].wall_time(op_arr[:m] + waits)
        for i, e in enumerate(entries[:m]):
            waits_of[e.req] = float(start_wall[i]) - float(wl.arrivals[e.req])
            final_rep[e.req] = r

    served = np.isfinite(waits_of)
    served[failed] = False
    w_all = waits_of[served]
    w = _warm(w_all)                    # warm-trim in request order
    T = float(wl.arrivals[-1])
    out = {
        "mean_wait": float(w.mean()) if w.size else 0.0,
        "p50_wait": float(np.percentile(w, 50)) if w.size else 0.0,
        "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
        "p99_wait": float(np.percentile(w, 99)) if w.size else 0.0,
        "waits": w,
        "waits_by_request": waits_of,
        "served_mask": served,
        "replica_of": final_rep,
        "shed": int(drop.sum()),
        "retries": int(retries),
        "failed": int(len(set(failed))),
        "unserved": int(len(set(unserved) - set(failed))),
        "availability": [tr.availability(T) for tr in traces],
        "n_arrived": int(n),
        "n_served": int(served.sum()),
    }
    return out


__all__ = [
    "FAULTS", "CrashRepair", "FaultModel", "NoFaults", "ReplicaTrace",
    "RequestDrop", "Slowdown", "default_faults", "effective_lambda",
    "fault_from_spec", "get_fault", "masked_assign", "register_fault",
    "replay_backlog", "simulate_fleet_faulty", "up_matrix",
]
