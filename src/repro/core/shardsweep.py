"""Multi-device grid sweeps: ``shard_map`` twins of the fastsim lane scans.

:mod:`repro.core.fastsim` stacks every (λ, policy) / (λ, σ) / (R, λ,
replica) grid cell as a *lane* of one vmapped compiled loop.  This module
spreads those lanes over a 1-D ``"cells"`` device mesh
(:func:`repro.distributed.sharding.cells_mesh`) with ``shard_map``: each
device runs the UNCHANGED vmapped kernel on its shard of the lanes, no
collectives, so per-lane results are bit-equal to the single-device path —
lanes are elementwise-independent, and sharding only changes which device
computes which lane.

Two invariants make the equality exact rather than approximate:

  * **Lane padding duplicates real lanes** (``np.arange(Lp) % n``): the
    lane count pads to a power of two that divides the mesh (so every
    cell-count shares one compile per mesh and shards evenly), and a
    duplicated lane computes the identical trajectory of the lane it
    copies — sliced off the output, it can't perturb anything.
  * **Row padding appends inert tail entries** (arrivals at +inf, tokens
    0): a ``lax.scan`` carry at position i only sees inputs [0, i], so
    appending entries after a lane's true length never changes its first
    n outputs — fleet replica sub-streams of ragged lengths pad to ONE
    global power-of-two row length instead of per-replica lengths, and
    the sliced prefixes still match ``_batch_scan_kernel`` bit for bit.

Entry points mirror their single-device twins and accept ``mesh=None``
(-> all local devices):

  * :func:`sweep`        — ``fastsim.sweep`` with sharded batching lanes.
  * :func:`sweep_noise`  — ``fastsim.sweep_noise`` with sharded SRPT lanes.
  * :func:`fleet_sweep`  — the big win: ``fleet.sweep`` runs R separate
    kernel dispatches per (R, λ) cell; here EVERY replica sub-stream of
    EVERY cell becomes one lane of a single sharded scan (one dispatch
    for the whole grid), then aggregates per cell exactly like
    ``fleet.run_fleet``.  Policies without a ``batch_scan`` lane fall
    back to the per-cell path unchanged.

On a single-device host the mesh has size 1 and the shard_map path still
runs (CI forces ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
for a real 4-way CPU mesh); ``tests/test_shardsweep.py`` pins exact
equality against the single-device entry points in both regimes.
"""

from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fastsim, fleet
from repro.core.fastsim import (
    _NO_CAP, _batch_lane_stats, _batching_core, _srpt_core)
from repro.core.fleet import (
    FleetWorkload, RoutingPolicy, _aggregate, _sub_workload,
    router_from_spec, served_slice)
from repro.core.policies import BatchPolicy
from repro.distributed.sharding import SWEEP_RULES, cells_mesh, logical_to_spec


def pad_lane_count(n: int, ndev: int) -> int:
    """Padded lane count: next power of two >= max(n, 2), rounded up to a
    multiple of ``ndev`` so shard_map splits evenly (for the usual
    power-of-two device counts the pow2 is already a multiple)."""
    L = max(1 << max(n - 1, 1).bit_length(), 2)
    if L % ndev:
        L = -(-L // ndev) * ndev
    return L


def _lane_spec(mesh: Mesh) -> P:
    """PartitionSpec for the lane axis via the shared rule machinery."""
    return logical_to_spec(("lanes",), SWEEP_RULES, mesh, None)


@functools.lru_cache(maxsize=None)
def _sharded_batching_scan(mesh: Mesh):
    """shard_map twin of ``fastsim._batching_scan(True)``: lanes shard
    over the "cells" axis, latency constants replicate, each device runs
    the unchanged vmapped per-request scan on its lane shard."""
    lane = _lane_spec(mesh)
    vmapped = jax.vmap(_batching_core,
                       in_axes=(0, 0, None, None, None, None, 0, 0))
    return jax.jit(shard_map(
        vmapped, mesh=mesh,
        in_specs=(lane, lane, P(), P(), P(), P(), lane, lane),
        out_specs=(lane, lane), check_rep=False))


@functools.lru_cache(maxsize=None)
def lane_executor(mesh: Optional[Mesh] = None):
    """Drop-in replacement for ``fastsim._batching_scan(True)`` (the
    ``lane_scan`` hook of :func:`repro.core.fastsim.sweep`): pad the lane
    axis by duplicating real lanes, run the sharded scan, slice back."""
    mesh = cells_mesh() if mesh is None else mesh

    def scan(arr, tok, k1, k2, k3, k4, elas, bmax):
        n = arr.shape[0]
        Lp = pad_lane_count(n, mesh.size)
        if Lp != n:
            idx = np.arange(Lp) % n      # duplicate real lanes (inert)
            arr, tok = arr[idx], tok[idx]
            elas, bmax = elas[idx], bmax[idx]
        starts, closed = _sharded_batching_scan(mesh)(
            arr, tok, k1, k2, k3, k4, elas, bmax)
        return starts[:n], closed[:n]

    return scan


@functools.lru_cache(maxsize=None)
def _sharded_srpt_loop(mesh: Mesh, L: int):
    """shard_map twin of ``fastsim._srpt_loop_vmapped(L)``: each device
    runs the vmapped SRPT batch-event while_loop on its lane shard (the
    loops are data-local, so lanes on different devices run their own
    trip counts with no cross-device sync)."""
    lane = _lane_spec(mesh)
    vmapped = jax.vmap(_srpt_core(L),
                       in_axes=(0, 0, None, None, None, None, None, None))
    return jax.jit(shard_map(
        vmapped, mesh=mesh,
        in_specs=(lane, lane, P(), P(), P(), P(), P(), P()),
        out_specs=(lane, lane), check_rep=False))


def srpt_executor(mesh: Optional[Mesh] = None):
    """``L -> callable`` factory matching ``fastsim._srpt_loop_vmapped``
    (the ``srpt_loop`` hook of :func:`repro.core.fastsim.sweep_noise`),
    with lane padding by duplication."""
    mesh = cells_mesh() if mesh is None else mesh

    def make(L: int):
        def loop(trees, tok_ranks, n, b_max, k1, k2, k3, k4):
            c = trees.shape[0]
            Lp = pad_lane_count(c, mesh.size)
            if Lp != c:
                idx = np.arange(Lp) % c
                trees, tok_ranks = trees[idx], tok_ranks[idx]
            starts, nbs = _sharded_srpt_loop(mesh, L)(
                trees, tok_ranks, n, b_max, k1, k2, k3, k4)
            return starts[:c], nbs[:c]
        return loop

    return make


def _backlog_core_padded(arrivals, work, v0):
    """One lane of the stacked state-dependent routing recursion
    (``fastsim._backlog_scan`` with the replica axis padded to a shared
    R_max): ``v0`` seeds real replicas at 0 and padding replicas at +inf —
    +inf survives the decay (``max(0, inf - dt) = inf``) and never wins
    the argmin, so assignments are bit-equal to the unpadded scan."""
    def step(carry, xs):
        v, t_prev = carry
        a, w = xs
        v = jnp.maximum(0.0, v - (a - t_prev))
        r = jnp.argmin(v).astype(jnp.int32)
        v = v.at[r].add(w)
        return (v, a), r

    _, rs = jax.lax.scan(step, (v0, jnp.float64(0.0)), (arrivals, work),
                         unroll=fastsim._UNROLL)
    return rs


@functools.lru_cache(maxsize=None)
def _sharded_backlog_scan(mesh: Mesh):
    """shard_map of the vmapped padded backlog recursion: every (R, λ)
    grid cell's routing becomes one lane (arrivals/work/v0 shard over
    "cells"), replacing fleet.sweep's per-cell ``backlog_route`` calls
    with ONE dispatch."""
    lane = _lane_spec(mesh)
    vmapped = jax.vmap(_backlog_core_padded, in_axes=(0, 0, 0))
    return jax.jit(shard_map(
        vmapped, mesh=mesh, in_specs=(lane, lane, lane),
        out_specs=lane, check_rep=False))


def _stacked_assign(router, jobs, mesh: Mesh):
    """Run every state-dependent routing job ``(key, arrivals, work, R)``
    as one lane of the sharded backlog scan.  Arrivals pad with +inf /
    work with 0 (the exact fills of ``fastsim.backlog_route``) and the
    replica axis pads to the grid's R_max with +inf initial backlog.
    Returns {key: replica ids}, each bit-equal to ``router.assign(...,
    fast=True)``."""
    if not jobs:
        return {}
    r_max = max(R for _, _, _, R in jobs)
    rows = max(fastsim._pad_pow2_1d(a, np.inf).shape[0]
               for _, a, _, _ in jobs)
    nl = pad_lane_count(len(jobs), mesh.size)
    arr = np.full((nl, rows), np.inf)
    wrk = np.zeros((nl, rows))
    v0 = np.full((nl, r_max), np.inf)
    for j, (_, a, w, R) in enumerate(jobs):
        arr[j, :len(a)] = a
        wrk[j, :len(w)] = router._work_units(np.asarray(w, np.float64))
        v0[j, :R] = 0.0
    for j in range(len(jobs), nl):       # duplicate lane 0 (inert)
        arr[j], wrk[j], v0[j] = arr[0], wrk[0], v0[0]
    with jax.experimental.enable_x64():
        rs = _sharded_backlog_scan(mesh)(
            jnp.asarray(arr, jnp.float64), jnp.asarray(wrk, jnp.float64),
            jnp.asarray(v0, jnp.float64))
        rs = np.asarray(rs, np.int64)
    return {key: rs[j, :len(a)]
            for j, (key, a, _, _) in enumerate(jobs)}


# ----------------------------------------------------------------------------
# Public entry points (signatures mirror the single-device twins + mesh)
# ----------------------------------------------------------------------------

def sweep(policies: dict, lam_grid, dist, lat, num_requests: int = 100_000,
          seed: int = 0, mesh: Optional[Mesh] = None) -> dict:
    """:func:`repro.core.fastsim.sweep` with the (λ, policy) batching
    lanes sharded over the device mesh — same return, bit-equal values."""
    return fastsim.sweep(policies, lam_grid, dist, lat,
                         num_requests=num_requests, seed=seed,
                         lane_scan=lane_executor(mesh))


def sweep_noise(policy_factory, lam_grid, sigma_grid, dist, lat,
                num_requests: int = 50_000, seed: int = 0,
                mesh: Optional[Mesh] = None) -> dict:
    """:func:`repro.core.fastsim.sweep_noise` with the (λ, σ) SRPT lanes
    sharded over the device mesh — same return, bit-equal values."""
    return fastsim.sweep_noise(policy_factory, lam_grid, sigma_grid, dist,
                               lat, num_requests=num_requests, seed=seed,
                               srpt_loop=srpt_executor(mesh))


def fleet_sweep(R_grid, lam_grid, router, policy: BatchPolicy, dist, lat,
                num_requests: int = 50_000, seed: int = 0,
                mesh: Optional[Mesh] = None) -> dict:
    """Sharded twin of :func:`repro.core.fleet.sweep`: route every (R, λ)
    cell on host (identical split machinery), then run EVERY replica
    sub-stream of EVERY cell as one lane of a single sharded scan and
    aggregate per cell exactly like ``fleet.run_fleet`` — one device
    dispatch for the whole grid instead of sum(R_grid)·len(lam_grid)
    kernel calls.  Values are bit-equal to ``fleet.sweep`` (same routing,
    same per-lane recursion, inert padding).  Policies without a
    ``batch_scan`` lane (or with an n_max admission cap) fall back to the
    per-cell path."""
    mesh = cells_mesh() if mesh is None else mesh
    router = router_from_spec(router)
    R_grid = [int(r) for r in R_grid]
    lam_grid = [float(l) for l in lam_grid]
    lane = policy.scan_lane() if policy.fast_kernel == "batch_scan" else None
    if lane is None or policy.n_max is not None:
        return fleet.sweep(R_grid, lam_grid, router, policy, dist, lat,
                           num_requests=num_requests, seed=seed)
    elastic, b_max = lane

    # ---- routing: one workload sample per λ, one stacked assign call ----
    # The base fleet_workload samples the SAME (λ, seed) stream for every
    # R and assigns per cell; here the sample is shared across the R
    # column and all state-dependent cells route as lanes of one sharded
    # backlog scan.  Routers that override fleet_workload (random's exact
    # per-replica superposition) keep their own per-cell construction.
    base_route = type(router).fleet_workload is RoutingPolicy.fleet_workload
    fws = {}
    if base_route:
        wl_of = {lam: policy.sample_workload(lam, dist, num_requests, seed)
                 for lam in lam_grid}
        work_of = {lam: router.routing_work(wl_of[lam], lat, seed)
                   for lam in lam_grid}
        if router.state_dependent:
            jobs = [((R, lam), wl_of[lam].arrivals, work_of[lam], R)
                    for R in R_grid for lam in lam_grid if R > 1]
            assigns = _stacked_assign(router, jobs, mesh)
        else:
            assigns = {(R, lam): np.asarray(
                router.assign(wl_of[lam].arrivals, work_of[lam], R, seed,
                              fast=True), np.int64)
                for R in R_grid for lam in lam_grid if R > 1}
        for R in R_grid:
            for lam in lam_grid:
                wl = wl_of[lam]
                if R == 1:
                    fws[(R, lam)] = FleetWorkload(
                        [wl], np.zeros(len(wl.arrivals), np.int64),
                        wl.arrivals, 1)
                    continue
                rep = assigns[(R, lam)]
                subs = [_sub_workload(wl, np.nonzero(rep == r)[0])
                        for r in range(R)]
                fws[(R, lam)] = FleetWorkload(subs, rep, wl.arrivals, R)
    else:
        for R in R_grid:
            for lam in lam_grid:
                fws[(R, lam)] = router.fleet_workload(
                    policy, lam, dist, lat, num_requests, seed, R, fast=True)

    # ---- collect one lane per non-empty replica sub-stream ----
    cells = []                      # (ri, li, fw, [None | (row, workload)])
    lane_wls = []
    for ri, R in enumerate(R_grid):
        for li, lam in enumerate(lam_grid):
            fw = fws[(R, lam)]
            slots = []
            for wl in fw.replicas:
                wl = served_slice(policy, wl)
                if len(wl.arrivals) == 0:
                    slots.append(None)      # run_fleet's empty-replica None
                    continue
                slots.append((len(lane_wls), wl))
                lane_wls.append(wl)
            cells.append((ri, li, fw, slots))

    # ---- one sharded scan per power-of-two row-length bucket ----
    # +inf arrivals / 0 tokens are inert past each lane's true length
    # (scan-prefix property), so the sliced prefixes match the
    # per-replica-padded kernel runs bit for bit.  Bucketing by the same
    # pow2 row length the single-lane kernel pads to avoids stretching
    # every short replica stream to the grid's longest lane.
    starts = [None] * len(lane_wls)
    closed = [None] * len(lane_wls)
    buckets = {}
    for j, wl in enumerate(lane_wls):
        rows = max(1 << max(len(wl.arrivals) - 1, 1).bit_length(), 2)
        buckets.setdefault(rows, []).append(j)
    scan = lane_executor(mesh)
    for rows, idxs in sorted(buckets.items()):
        nl = len(idxs)
        arr_l = np.full((nl, rows), np.inf)
        tok_l = np.zeros((nl, rows))
        for r, j in enumerate(idxs):
            wl = lane_wls[j]
            arr_l[r, :len(wl.arrivals)] = wl.arrivals
            tok_l[r, :len(wl.tokens)] = wl.tokens
        elas = np.full(nl, bool(elastic))
        bmax = np.full(nl, float(b_max) if b_max is not None else _NO_CAP)
        with jax.experimental.enable_x64():
            s, c = scan(jnp.asarray(arr_l, jnp.float64),
                        jnp.asarray(tok_l, jnp.float64),
                        jnp.float64(lat.k1), jnp.float64(lat.k2),
                        jnp.float64(lat.k3), jnp.float64(lat.k4),
                        jnp.asarray(elas), jnp.asarray(bmax, jnp.float64))
            s, c = np.asarray(s), np.asarray(c)
        for r, j in enumerate(idxs):
            starts[j], closed[j] = s[r], c[r]

    out = np.empty((len(R_grid), len(lam_grid)))
    for ri, li, fw, slots in cells:
        per = []
        for slot in slots:
            if slot is None:
                per.append(None)
                continue
            row, wl = slot
            n = len(wl.arrivals)
            per.append(_batch_lane_stats(starts[row][:n], closed[row][:n],
                                         wl.arrivals))
        out[ri, li] = _aggregate(per, fw)["mean_wait"]
    return {"mean_wait": out, "R_grid": np.asarray(R_grid),
            "lams": np.asarray(lam_grid)}


__all__ = [
    "cells_mesh", "fleet_sweep", "lane_executor", "pad_lane_count",
    "srpt_executor", "sweep", "sweep_noise",
]
