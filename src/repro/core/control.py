"""Adaptive control plane: the paper's analytics as a first-class feature.

``AdaptiveController`` watches the live request stream (arrival times,
completed output-token counts), maintains an empirical output-token
distribution and arrival-rate estimate, and derives the serving
configuration from the paper's models:

  * ``n_max``  — optimal max-token limit (V1 or V2, Eqs 10-13)
  * ``b_max``  — optimal dynamic-batching cap: b* from the M/D^b/1 analysis
                 when the tail is heavy (paper §IV-C finding), unbounded for
                 light tails
  * ``policy`` — 'elastic' when the engine supports early-exit batching
                 (minimal delay for every distribution, paper §IV-D);
                 otherwise 'multibin' for heavy tails (binning by length
                 recovers most of elastic's win under padded decode,
                 Guldogan et al. 2024) and 'dynamic' for light tails
  * ``bin_edges`` — load-dependent multi-bin boundaries
                 (:func:`repro.core.bulk.optimize_bin_edges`) whenever the
                 recommended policy is 'multibin'
  * ``predictor`` — which length predictor
                 (:mod:`repro.core.predictors` registry name) should feed
                 the recommended policy's length-based routing; set
                 whenever the policy or router consumes predicted lengths
                 ('multibin', 'least_work'), None otherwise — a
                 recommendation is only actionable together with the
                 estimator that powers it
  * ``replicas`` / ``router`` — the fleet axis (:mod:`repro.core.fleet`):
                 the smallest replica count keeping per-replica batched
                 utilization under ``replica_target_util``
                 (``fleet.recommend_replicas``), and the router to put in
                 front of it — 'least_work' (predicted-work balancing)
                 for heavy tails, 'jsq' (burst balancing) otherwise;
                 enabled by ``max_replicas > 1``

The serving engine polls ``recommendation()`` between batches; hysteresis
avoids thrashing.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.distributions import EmpiricalTokens, TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policy_opt import optimize_token_limit_v1, optimize_token_limit_v2
from repro.core.bulk import (
    optimal_fixed_batch, dynamic_batching_bound, optimize_bin_edges)


@dataclasses.dataclass
class Recommendation:
    n_max: Optional[int]
    b_max: Optional[int]
    policy: str
    heavy_tailed: bool
    lam_hat: float
    details: dict
    bin_edges: Optional[tuple] = None   # set when policy == 'multibin'
    predictor: Optional[str] = None     # registry name, when the policy
    #                                     routes on predicted length
    replicas: int = 1                   # fleet size (repro.core.fleet)
    router: Optional[str] = None        # fleet router registry name, when
    #                                     replicas > 1
    availability: float = 1.0           # learned replica availability
    shed_prob: float = 0.0              # admission drop prob. keeping the
    #                                     surviving fleet under target util
    memory_budget: Optional[float] = None   # per-replica KV-token capacity
    #                                     the recommendation was sized for;
    #                                     b_max is then capped at the
    #                                     effective b(M) (memory.MemoryBudget
    #                                     .max_batch) so recommended batches
    #                                     always fit the budget


def tail_index(dist: TokenDistribution) -> float:
    """Heavy-tail heuristic: squared coefficient of variation of N."""
    m, v = dist.mean(), dist.var()
    return v / max(m * m, 1e-12)


class AdaptiveController:
    def __init__(self, single_lat: LatencyModel, batch_lat: BatchLatencyModel,
                 *, theta: float = 0.95, tau: Optional[float] = None,
                 loss_cost: float = 4.0, elastic_available: bool = True,
                 window: int = 4096, min_samples: int = 64,
                 heavy_tail_scv: float = 0.5, b_search: int = 64,
                 num_bins: int = 4, length_predictor: str = "oracle",
                 max_replicas: int = 1,
                 replica_target_util: float = 0.7,
                 memory=None, memory_quantile: float = 1.0,
                 prefix_discount: float = 0.0):
        self.single_lat = single_lat
        self.batch_lat = batch_lat
        self.theta = theta
        self.tau = tau
        self.loss_cost = loss_cost
        self.elastic_available = elastic_available
        self.min_samples = min_samples
        self.heavy_tail_scv = heavy_tail_scv
        self.b_search = b_search
        self.num_bins = num_bins
        # which length predictor backs length-based routing; validated
        # against the predictor registry so recommendations stay actionable
        from repro.core.predictors import PREDICTORS
        assert length_predictor in PREDICTORS, length_predictor
        self.length_predictor = length_predictor
        assert max_replicas >= 1
        assert 0.0 < replica_target_util < 1.0
        self.max_replicas = int(max_replicas)
        self.replica_target_util = float(replica_target_util)
        # KV-memory axis (repro.core.memory): recommendations trade batch
        # size against KV headroom by capping b_max at the effective b(M).
        # ``prefix_discount`` gamma composes with PR 9 sessions' KV reuse:
        # a reused prefix holds only (1-gamma) of its prompt tokens, so the
        # per-request footprint shrinks and b(M) grows accordingly.
        from repro.core.memory import memory_from_spec
        budget = memory_from_spec(memory)
        self.memory = None if budget.is_null else budget
        assert 0.0 < memory_quantile <= 1.0
        assert 0.0 <= prefix_discount < 1.0
        self.memory_quantile = float(memory_quantile)
        self.prefix_discount = float(prefix_discount)
        self._tokens = deque(maxlen=window)
        self._arrivals = deque(maxlen=window)
        self._episodes = deque(maxlen=window)   # (up_seconds, down_seconds)
        self._last: Optional[Recommendation] = None

    # ---------------- stream ingestion ----------------
    def observe_arrival(self, t: float):
        self._arrivals.append(t)

    def observe_completion(self, output_tokens: int):
        self._tokens.append(int(output_tokens))

    def observe_episode(self, up_seconds: float, down_seconds: float):
        """One replica failure/repair renewal cycle: ``up_seconds`` of
        service followed by ``down_seconds`` of repair (the serving layer
        reports each :class:`~repro.serving.resilience.ResilienceReport`
        kill event this way; a scale-down drain is a planned episode)."""
        self._episodes.append((float(up_seconds), float(down_seconds)))

    def availability_hat(self) -> float:
        """Empirical availability MTBF/(MTBF+MTTR); 1.0 before any
        observed failure (the fault-free prior)."""
        if not self._episodes:
            return 1.0
        up = sum(u for u, _ in self._episodes)
        down = sum(d for _, d in self._episodes)
        return up / max(up + down, 1e-12)

    def shed_probability(self, lam: float, dist) -> float:
        """Admission drop probability keeping the AVAILABLE fleet under
        ``replica_target_util``: per-request marginal work is the elastic
        envelope slope alpha = k1 + k3*E[N] (the same capacity law as
        ``fleet.recommend_replicas``), each of the ``max_replicas``
        replicas contributes ``availability_hat()`` of a server, so shed
        p = max(0, 1 - a*R*target/(lam*alpha))."""
        if lam <= 0 or dist is None:
            return 0.0
        alpha = self.batch_lat.k1 + self.batch_lat.k3 * dist.mean()
        cap = (self.availability_hat() * self.max_replicas
               * self.replica_target_util)
        return float(max(0.0, 1.0 - cap / max(lam * alpha, 1e-12)))

    def lam_hat(self) -> float:
        if len(self._arrivals) < 2:
            return 0.0
        span = self._arrivals[-1] - self._arrivals[0]
        return (len(self._arrivals) - 1) / max(span, 1e-9)

    def empirical_dist(self) -> Optional[TokenDistribution]:
        if len(self._tokens) < self.min_samples:
            return None
        return EmpiricalTokens(list(self._tokens))

    # ---------------- recommendation ----------------
    def recommendation(self, force: bool = False) -> Recommendation:
        dist = self.empirical_dist()
        lam = self.lam_hat()
        if dist is None or lam <= 0:
            return Recommendation(n_max=None, b_max=None,
                                  policy="dynamic", heavy_tailed=False,
                                  lam_hat=lam, details={"reason": "warmup"})

        scv = tail_index(dist)
        heavy = scv > self.heavy_tail_scv

        # optimal token limit (paper Eqs 10-13)
        if self.tau is None:
            ch = optimize_token_limit_v1(dist, self.single_lat, lam, self.theta)
        else:
            ch = optimize_token_limit_v2(dist, self.single_lat, lam,
                                         self.theta, self.tau, self.loss_cost)
        n_max = ch.n_max

        # batching policy (paper §IV conclusions + Guldogan et al. 2024)
        clipped = dist.clip(n_max)
        b_max = None
        policy = "elastic" if self.elastic_available else "dynamic"
        if heavy:
            fb = optimal_fixed_batch(clipped, self.batch_lat, lam,
                                     b_max=self.b_search)
            b_max = fb["b_star"]
            if not self.elastic_available:
                # padded decode pays the full max-token padding on a heavy
                # tail: route by predicted length instead (bin_edges below)
                policy = "multibin"

        # KV-memory axis (repro.core.memory): trade batch size against KV
        # headroom.  The effective b(M) = floor(M / footprint(L_q)) caps
        # b_max so a recommended batch always FITS the budget.  When the
        # gate BINDS (the tandem bound's memory arm dominates its slack
        # arm), serve-all formation is the wrong discipline: the prefill
        # stage races ahead of decode, fills the budget, and admissions
        # fragment into small poorly-amortized batches (docs/memory.md).
        # The controller then throttles formation with a count trigger
        # sized so TWO batches in flight (one decoding, one prefilled)
        # fit worst-case: b_pipe = max(1, b_mem // 2), refined by the
        # fixed-batch optimizer below that cap.  Sessions' prefix reuse
        # (gamma) shrinks the footprint, so a cache-heavy workload earns
        # a larger b(M).
        b_mem = None
        mem_binding = False
        if self.memory is not None:
            from repro.core.bulk import tandem_bound
            budget = self.memory
            if self.prefix_discount > 0.0:
                budget = dataclasses.replace(
                    budget, prompt_tokens=budget.prompt_tokens
                    * (1.0 - self.prefix_discount))
            tb = tandem_bound(clipped, self.batch_lat, lam, memory=budget,
                              quantile=self.memory_quantile)
            b_mem = tb["b_mem"]
            b_max = b_mem if b_max is None else min(b_max, b_mem)
            # the memory arm approaches the slack arm from above as the
            # budget loosens (it carries an extra beta/b_mem amortization
            # term), so "binding" needs a margin, not a plain comparison
            mem_binding = (not tb["stable"]
                           or tb["memory_arm"] >= 1.5 * tb["slack_arm"])
            if mem_binding:
                b_pipe = max(1, b_mem // 2)
                fb = optimal_fixed_batch(clipped, self.batch_lat, lam,
                                         b_max=b_pipe)
                policy = "fixed"
                b_max = fb["b_star"]

        # fleet axis (repro.core.fleet): smallest replica count keeping
        # per-replica batched utilization under target; a heavy tail wants
        # length-aware dispatch (predicted-work balancing), a light tail
        # only needs burst balancing
        replicas, router = 1, None
        avail = self.availability_hat()
        if self.max_replicas > 1:
            from repro.core.fleet import ROUTERS, recommend_replicas
            # availability-discounted effective-lambda transfer
            # (repro.core.faults.effective_lambda): a replica that is up a
            # fraction `avail` of the time sizes like load lam/avail
            replicas = recommend_replicas(
                lam / max(avail, 1e-12), clipped, self.batch_lat,
                target_util=self.replica_target_util,
                max_replicas=self.max_replicas)
            if replicas > 1:
                router = "least_work" if heavy else "jsq"
                assert router in ROUTERS, router

        rec = Recommendation(
            n_max=n_max, b_max=b_max, policy=policy, heavy_tailed=heavy,
            lam_hat=lam, replicas=replicas, router=router,
            availability=avail,
            shed_prob=self.shed_probability(lam, clipped),
            memory_budget=(float(self.memory.capacity)
                           if self.memory is not None else None),
            details={"scv": scv, "objective": ch.objective,
                     "expected_wait": ch.wait, "loss_frac": ch.loss_frac,
                     "b_mem": b_mem, "memory_binding": mem_binding},
            # multibin and least_work route on predicted length: name the
            # predictor that should feed them (repro.core.predictors)
            predictor=(self.length_predictor
                       if policy == "multibin" or router == "least_work"
                       else None))
        # hysteresis: ignore <10% n_max moves (bin_edges revert alongside,
        # so the recommendation stays internally consistent)
        if (not force and self._last is not None
                and self._last.n_max and n_max
                and abs(n_max - self._last.n_max) < 0.1 * self._last.n_max):
            rec = dataclasses.replace(
                rec, n_max=self._last.n_max, b_max=self._last.b_max,
                bin_edges=(self._last.bin_edges
                           if rec.policy == "multibin" else None))
        if rec.policy == "multibin" and rec.bin_edges is None:
            # the coordinate descent is the expensive step: reuse the last
            # edges unless the operating point (n_max, lam) actually moved
            last = self._last
            if (last is not None and last.bin_edges is not None
                    and last.n_max == rec.n_max
                    and abs(lam - last.lam_hat)
                    < 0.1 * max(last.lam_hat, 1e-9)):
                edges = last.bin_edges
            else:
                edges = tuple(optimize_bin_edges(
                    dist.clip(rec.n_max), self.batch_lat, lam,
                    num_bins=self.num_bins))
            rec = dataclasses.replace(rec, bin_edges=edges)
        self._last = rec
        return rec


# ----------------------------------------------------------------------------
# Closed-loop time-sliced control (PR 8): the controller ACTS
# ----------------------------------------------------------------------------
#
# ``simulate_controlled`` closes the loop the module docstring only
# recommends: the run is sliced into fixed-length windows; after each
# window the controller ingests the window's realized arrivals and
# completions and re-picks the next window's serving configuration —
# ``replicas`` (clamped to powers of two, so the compiled kernels reuse
# cached shapes), ``router``, ``bin_edges`` (multibin) and ``shed_prob``
# — from the same analytic laws ``recommendation()`` has always used.
#
# Replica carry across windows rides a SYNTHETIC head request: a replica
# still busy at the window boundary W (busy-until f > W) is modeled by
# prepending a request at W with token count l0 = (f - W - c)/a (single
# law S(n) = a n + c, so its solo service time is exactly f - W).  For
# every carry-safe policy an idle server starts its earliest arrival
# ALONE (``_DynamicFormation`` semantics; SRPT's idle start caps at one;
# multibin picks the synthetic's bin — it is the sole head), so the
# synthetic occupies the server precisely over the carried interval and
# the real requests queue behind it.  When f - W <= c the residual is
# below one prefill and is dropped (the server is treated as free) — a
# bounded, documented approximation applied identically to the oracle
# and fast runners, which therefore stay trajectory-equal.  A replica
# scaled DOWN simply stops receiving work and drains its carry.

_CARRY_SAFE = ("fcfs", "dynamic", "elastic", "multibin", "srpt")


def pow2_replicas(r: int, max_replicas: int) -> int:
    """Smallest power of two >= r, clamped to the largest power of two
    <= max_replicas — compile-cache-friendly fleet sizes."""
    assert max_replicas >= 1
    cap = 1
    while cap * 2 <= max_replicas:
        cap *= 2
    p = 1
    while p < max(r, 1):
        p *= 2
    return min(p, cap)


@dataclasses.dataclass(frozen=True)
class WindowAction:
    """The controller's decision for one window (determinism contract:
    equal seeds and observations yield equal action sequences)."""
    window: int
    t0: float
    t1: float
    replicas: int
    router: str
    shed_prob: float = 0.0
    bin_edges: Optional[tuple] = None


@dataclasses.dataclass
class ControlledResult:
    """One closed-loop run.  ``objective`` is the cost-aware score the
    regret benchmark compares: mean served wait + replica_cost * the
    time-average replica count (+ shed_cost * shed fraction) — more
    replicas always weakly cut delay, so without a replica price the
    static R=max fleet would trivially win."""
    waits: np.ndarray            # per request; NaN where shed
    lost: np.ndarray             # shed mask
    actions: List[WindowAction]
    windows: List[dict]
    mean_wait: float
    served: int
    shed: int
    avg_replicas: float
    replica_cost: float
    shed_cost: float
    objective: float


def _carry_backlog_assign(arrivals, work, R: int, v0, t0: float):
    """``fleet._backlog_assign_np`` with carried initial backlog: the
    state-dependent routers' Lindley recursion seeded with each
    replica's residual busy time at the window start."""
    v = np.asarray(v0, np.float64).copy()
    t_prev = float(t0)
    out = np.empty(len(arrivals), np.int64)
    for i, (a, w) in enumerate(zip(arrivals, work)):
        v = np.maximum(0.0, v - (a - t_prev))
        t_prev = float(a)
        r = int(np.argmin(v))
        v[r] += w
        out[i] = r
    return out


def _with_bin_edges(policy, bin_edges):
    """Rebuild a multibin policy around the controller's re-picked
    edges; every other policy ignores the knob."""
    if bin_edges is None or policy.name != "multibin":
        return policy
    from repro.core.policies import MultiBinPolicy
    return MultiBinPolicy(edges=bin_edges, n_max=policy.n_max,
                          b_max=policy.b_max, predictor=policy.predictor,
                          bound_quantile=policy.bound_quantile)


def _default_controller(lam: float, window: float, single, batch_lat,
                        policy, max_replicas: int, kw: dict
                        ) -> "AdaptiveController":
    """Controller sized for windowed control: the arrival deque spans
    roughly two windows so ``lam_hat`` tracks the modulation instead of
    the long-run average."""
    kw = dict(kw or {})
    kw.setdefault("window", int(max(128, 2.0 * lam * window)))
    kw.setdefault("min_samples", 32)
    kw.setdefault("max_replicas", max_replicas)
    kw.setdefault("elastic_available", policy.name == "elastic")
    return AdaptiveController(single, batch_lat, **kw)


def simulate_controlled(policy, lam: float, dist, lat, *, traffic=None,
                        num_requests: int = 20_000, seed: int = 0,
                        window: float = 200.0, max_replicas: int = 8,
                        replica_cost: float = 0.0, shed_cost: float = 0.0,
                        router_default: str = "round_robin",
                        controller: Optional["AdaptiveController"] = None,
                        controller_kwargs: Optional[dict] = None,
                        fixed: Optional[Tuple[int, str]] = None,
                        clairvoyant: bool = False,
                        candidate_routers: Sequence[str] = (
                            "round_robin", "least_work"),
                        fast: bool = True) -> ControlledResult:
    """Time-sliced closed-loop fleet control over a (possibly modulated)
    arrival stream — ONE driver, two runners (``fast``: compiled kernels
    vs. reference event loops), so both layers see identical actions and
    trajectory-equal waits.

    Modes (mutually exclusive):
      * adaptive (default)    — ``AdaptiveController`` observes each
        window and re-picks replicas/router/bin_edges/shed_prob for the
        next one; actions are rng-free given the observations, so equal
        seeds give equal action sequences.
      * ``fixed=(R, router)`` — a static configuration run through the
        SAME windowed machinery (the apples-to-apples baseline for the
        regret benchmark).
      * ``clairvoyant=True``  — per-window greedy oracle: every
        (power-of-two R, candidate router) pair is simulated on the
        window's actual arrivals from the current carry state and the
        cheapest (window mean wait + replica_cost * R) is committed.

    Windows run under ``no_warmup`` with replica busy-carry via the
    synthetic-head construction documented above."""
    from repro.core.policies import Workload, single_from_batch
    from repro.core.simulate import no_warmup, simulate_policy
    from repro.core.fastsim import simulate_policy_fast
    from repro.core.fleet import router_from_spec, recommend_replicas
    from repro.core.traffic import _SHED_LANE, _traffic_rng, warp_workload

    assert policy.name in _CARRY_SAFE, \
        f"windowed carry needs idle-start-alone semantics, " \
        f"got {policy.name!r} (supported: {_CARRY_SAFE})"
    assert getattr(policy, "tau", None) is None, \
        "impatience is not supported in the windowed driver"
    assert not (fixed is not None and clairvoyant)
    assert window > 0.0 and max_replicas >= 1

    batch_lat = lat if isinstance(lat, BatchLatencyModel) else None
    single = lat if isinstance(lat, LatencyModel) else single_from_batch(lat)
    wl = policy.sample_workload(lam, dist, num_requests, seed)
    wl = warp_workload(wl, traffic, seed)
    arr, tok, pred = wl.arrivals, wl.tokens, wl.predicted
    n = len(arr)
    work = np.asarray(single.service_time(wl.predicted_or_true), np.float64)
    horizon = float(arr[-1]) if n else window
    n_windows = int(horizon // window) + 1

    adaptive = fixed is None and not clairvoyant
    if adaptive:
        assert batch_lat is not None and dist is not None, \
            "adaptive control needs a BatchLatencyModel and a dist"
        if controller is None:
            controller = _default_controller(lam, window, single, batch_lat,
                                             policy, max_replicas,
                                             controller_kwargs)
        r0 = pow2_replicas(recommend_replicas(
            lam, dist, batch_lat,
            target_util=controller.replica_target_util,
            max_replicas=max_replicas), max_replicas)
        cur = (r0, router_default, 0.0, None)
    elif fixed is not None:
        R_fix = pow2_replicas(int(fixed[0]), max_replicas)
        cur = (R_fix, str(fixed[1]), 0.0, None)
    else:
        cand_R = []
        p = 1
        while p <= max_replicas:
            cand_R.append(p)
            p *= 2
        cur = (cand_R[0], str(candidate_routers[0]), 0.0, None)

    sim = simulate_policy_fast if fast else simulate_policy

    def _run_window(idx: np.ndarray, t0: float, R: int, router_name: str,
                    bin_edges, free: np.ndarray):
        """Route + simulate one window's requests on R active replicas
        from carry state ``free`` (absolute busy-until per slot).
        Returns (per-request waits, new free array)."""
        free = free.copy()
        if not len(idx):
            return np.zeros(0), free
        a_w, w_w = arr[idx], work[idx]
        router = router_from_spec(router_name)
        if R == 1:
            rep = np.zeros(len(idx), np.int64)
        elif router.state_dependent:
            rep = _carry_backlog_assign(
                a_w, router._work_units(w_w), R,
                np.maximum(free[:R] - t0, 0.0), t0)
        else:
            rep = np.asarray(router.assign(a_w, w_w, R, (seed, len(idx))),
                             np.int64)
        pol_w = _with_bin_edges(policy, bin_edges)
        lat_eff = single if pol_w.uses_single_latency else lat
        waits_w = np.empty(len(idx))
        for r in range(R):
            mask = rep == r
            if not mask.any():
                continue
            ai = a_w[mask]
            ti = tok[idx][mask]
            pi = None if pred is None else pred[idx][mask]
            syn = free[r] - t0 > single.c + 1e-12
            if syn:
                t_s = t0 - 1e-9
                l0 = (free[r] - t_s - single.c) / single.a
                ai = np.concatenate(([t_s], ai))
                ti = np.concatenate(([l0], ti))
                if pi is not None:
                    pi = np.concatenate(([l0], pi))
            sub = Workload(arrivals=ai, tokens=ti,
                           inter=np.diff(ai, prepend=0.0), predicted=pi)
            with no_warmup():
                res = sim(pol_w, lam, dist, lat, workload=sub)
            w_all = np.asarray(res["waits"], np.float64)
            starts = ai + w_all
            # busy-until = end of the LAST batch (serial server): members
            # share a start; 1e-6 absorbs float reconstruction noise
            # (real batch gaps are >= one prefill, orders larger)
            s_last = float(starts.max())
            members = ti[np.abs(starts - s_last)
                         <= 1e-6 * max(1.0, abs(s_last))]
            free[r] = s_last + float(pol_w.batch_time(members, lat_eff))
            waits_w[mask] = w_all[1:] if syn else w_all
        return waits_w, free

    free = np.zeros(max_replicas)
    waits = np.full(n, np.nan)
    lost = np.ones(n, bool)
    actions: List[WindowAction] = []
    windows: List[dict] = []
    rep_time = 0.0

    for w_i in range(n_windows):
        t0, t1 = w_i * window, (w_i + 1) * window
        lo = int(np.searchsorted(arr, t0, side="left"))
        hi = int(np.searchsorted(arr, t1, side="left"))
        idx = np.arange(lo, hi)

        if clairvoyant:
            best = None
            for R_c in cand_R:
                for rt in candidate_routers:
                    w_c, f_c = _run_window(idx, t0, int(R_c), str(rt),
                                           None, free)
                    mw = float(w_c.mean()) if len(w_c) else 0.0
                    score = mw + replica_cost * R_c
                    if best is None or score < best[0] - 1e-12:
                        best = (score, int(R_c), str(rt), w_c, f_c)
            _, R_w, rt_w, waits_w, free_new = best
            shed_p, edges_w = 0.0, None
            adm = idx
        else:
            R_w, rt_w, shed_p, edges_w = cur
            adm = idx
            if shed_p > 0.0 and len(idx):
                keep = _traffic_rng(seed, _SHED_LANE, w_i
                                    ).random(len(idx)) >= shed_p
                adm = idx[keep]
            waits_w, free_new = _run_window(adm, t0, R_w, rt_w, edges_w,
                                            free)

        actions.append(WindowAction(w_i, t0, t1, R_w, rt_w, shed_p,
                                    edges_w))
        if len(adm):
            waits[adm] = waits_w
            lost[adm] = False
        free = free_new
        dur = max(min(t1, horizon) - t0, 0.0) or (t1 - t0)
        rep_time += R_w * dur
        backlog = float(np.maximum(free - t1, 0.0).sum())
        windows.append({
            "window": w_i, "t0": t0, "t1": t1, "replicas": R_w,
            "router": rt_w, "shed_prob": shed_p,
            "arrived": int(len(idx)), "shed": int(len(idx) - len(adm)),
            "mean_wait": float(waits_w.mean()) if len(waits_w) else 0.0,
            "backlog": backlog,
        })

        if adaptive:
            for a in arr[idx]:
                controller.observe_arrival(float(a))
            for t in tok[adm]:
                controller.observe_completion(int(t))
            rec = controller.recommendation()
            if rec.details.get("reason") != "warmup":
                cur = (pow2_replicas(max(rec.replicas, 1), max_replicas),
                       rec.router or router_default,
                       float(rec.shed_prob),
                       rec.bin_edges if policy.name == "multibin" else None)

    served = int((~lost).sum())
    shed = int(n - served)
    mean_wait = float(waits[~lost].mean()) if served else 0.0
    total_t = max(n_windows * window, 1e-12)
    avg_rep = rep_time / total_t
    objective = (mean_wait + replica_cost * avg_rep
                 + shed_cost * (shed / max(n, 1)))
    return ControlledResult(
        waits=waits, lost=lost, actions=actions, windows=windows,
        mean_wait=mean_wait, served=served, shed=shed,
        avg_replicas=float(avg_rep), replica_cost=float(replica_cost),
        shed_cost=float(shed_cost), objective=float(objective))
