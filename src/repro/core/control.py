"""Adaptive control plane: the paper's analytics as a first-class feature.

``AdaptiveController`` watches the live request stream (arrival times,
completed output-token counts), maintains an empirical output-token
distribution and arrival-rate estimate, and derives the serving
configuration from the paper's models:

  * ``n_max``  — optimal max-token limit (V1 or V2, Eqs 10-13)
  * ``b_max``  — optimal dynamic-batching cap: b* from the M/D^b/1 analysis
                 when the tail is heavy (paper §IV-C finding), unbounded for
                 light tails
  * ``policy`` — 'elastic' when the engine supports early-exit batching
                 (minimal delay for every distribution, paper §IV-D);
                 otherwise 'multibin' for heavy tails (binning by length
                 recovers most of elastic's win under padded decode,
                 Guldogan et al. 2024) and 'dynamic' for light tails
  * ``bin_edges`` — load-dependent multi-bin boundaries
                 (:func:`repro.core.bulk.optimize_bin_edges`) whenever the
                 recommended policy is 'multibin'
  * ``predictor`` — which length predictor
                 (:mod:`repro.core.predictors` registry name) should feed
                 the recommended policy's length-based routing; set
                 whenever the policy or router consumes predicted lengths
                 ('multibin', 'least_work'), None otherwise — a
                 recommendation is only actionable together with the
                 estimator that powers it
  * ``replicas`` / ``router`` — the fleet axis (:mod:`repro.core.fleet`):
                 the smallest replica count keeping per-replica batched
                 utilization under ``replica_target_util``
                 (``fleet.recommend_replicas``), and the router to put in
                 front of it — 'least_work' (predicted-work balancing)
                 for heavy tails, 'jsq' (burst balancing) otherwise;
                 enabled by ``max_replicas > 1``

The serving engine polls ``recommendation()`` between batches; hysteresis
avoids thrashing.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.distributions import EmpiricalTokens, TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policy_opt import optimize_token_limit_v1, optimize_token_limit_v2
from repro.core.bulk import (
    optimal_fixed_batch, dynamic_batching_bound, optimize_bin_edges)


@dataclasses.dataclass
class Recommendation:
    n_max: Optional[int]
    b_max: Optional[int]
    policy: str
    heavy_tailed: bool
    lam_hat: float
    details: dict
    bin_edges: Optional[tuple] = None   # set when policy == 'multibin'
    predictor: Optional[str] = None     # registry name, when the policy
    #                                     routes on predicted length
    replicas: int = 1                   # fleet size (repro.core.fleet)
    router: Optional[str] = None        # fleet router registry name, when
    #                                     replicas > 1
    availability: float = 1.0           # learned replica availability
    shed_prob: float = 0.0              # admission drop prob. keeping the
    #                                     surviving fleet under target util


def tail_index(dist: TokenDistribution) -> float:
    """Heavy-tail heuristic: squared coefficient of variation of N."""
    m, v = dist.mean(), dist.var()
    return v / max(m * m, 1e-12)


class AdaptiveController:
    def __init__(self, single_lat: LatencyModel, batch_lat: BatchLatencyModel,
                 *, theta: float = 0.95, tau: Optional[float] = None,
                 loss_cost: float = 4.0, elastic_available: bool = True,
                 window: int = 4096, min_samples: int = 64,
                 heavy_tail_scv: float = 0.5, b_search: int = 64,
                 num_bins: int = 4, length_predictor: str = "oracle",
                 max_replicas: int = 1,
                 replica_target_util: float = 0.7):
        self.single_lat = single_lat
        self.batch_lat = batch_lat
        self.theta = theta
        self.tau = tau
        self.loss_cost = loss_cost
        self.elastic_available = elastic_available
        self.min_samples = min_samples
        self.heavy_tail_scv = heavy_tail_scv
        self.b_search = b_search
        self.num_bins = num_bins
        # which length predictor backs length-based routing; validated
        # against the predictor registry so recommendations stay actionable
        from repro.core.predictors import PREDICTORS
        assert length_predictor in PREDICTORS, length_predictor
        self.length_predictor = length_predictor
        assert max_replicas >= 1
        assert 0.0 < replica_target_util < 1.0
        self.max_replicas = int(max_replicas)
        self.replica_target_util = float(replica_target_util)
        self._tokens = deque(maxlen=window)
        self._arrivals = deque(maxlen=window)
        self._episodes = deque(maxlen=window)   # (up_seconds, down_seconds)
        self._last: Optional[Recommendation] = None

    # ---------------- stream ingestion ----------------
    def observe_arrival(self, t: float):
        self._arrivals.append(t)

    def observe_completion(self, output_tokens: int):
        self._tokens.append(int(output_tokens))

    def observe_episode(self, up_seconds: float, down_seconds: float):
        """One replica failure/repair renewal cycle: ``up_seconds`` of
        service followed by ``down_seconds`` of repair (the serving layer
        reports each :class:`~repro.serving.resilience.ResilienceReport`
        kill event this way; a scale-down drain is a planned episode)."""
        self._episodes.append((float(up_seconds), float(down_seconds)))

    def availability_hat(self) -> float:
        """Empirical availability MTBF/(MTBF+MTTR); 1.0 before any
        observed failure (the fault-free prior)."""
        if not self._episodes:
            return 1.0
        up = sum(u for u, _ in self._episodes)
        down = sum(d for _, d in self._episodes)
        return up / max(up + down, 1e-12)

    def shed_probability(self, lam: float, dist) -> float:
        """Admission drop probability keeping the AVAILABLE fleet under
        ``replica_target_util``: per-request marginal work is the elastic
        envelope slope alpha = k1 + k3*E[N] (the same capacity law as
        ``fleet.recommend_replicas``), each of the ``max_replicas``
        replicas contributes ``availability_hat()`` of a server, so shed
        p = max(0, 1 - a*R*target/(lam*alpha))."""
        if lam <= 0 or dist is None:
            return 0.0
        alpha = self.batch_lat.k1 + self.batch_lat.k3 * dist.mean()
        cap = (self.availability_hat() * self.max_replicas
               * self.replica_target_util)
        return float(max(0.0, 1.0 - cap / max(lam * alpha, 1e-12)))

    def lam_hat(self) -> float:
        if len(self._arrivals) < 2:
            return 0.0
        span = self._arrivals[-1] - self._arrivals[0]
        return (len(self._arrivals) - 1) / max(span, 1e-9)

    def empirical_dist(self) -> Optional[TokenDistribution]:
        if len(self._tokens) < self.min_samples:
            return None
        return EmpiricalTokens(list(self._tokens))

    # ---------------- recommendation ----------------
    def recommendation(self, force: bool = False) -> Recommendation:
        dist = self.empirical_dist()
        lam = self.lam_hat()
        if dist is None or lam <= 0:
            return Recommendation(n_max=None, b_max=None,
                                  policy="dynamic", heavy_tailed=False,
                                  lam_hat=lam, details={"reason": "warmup"})

        scv = tail_index(dist)
        heavy = scv > self.heavy_tail_scv

        # optimal token limit (paper Eqs 10-13)
        if self.tau is None:
            ch = optimize_token_limit_v1(dist, self.single_lat, lam, self.theta)
        else:
            ch = optimize_token_limit_v2(dist, self.single_lat, lam,
                                         self.theta, self.tau, self.loss_cost)
        n_max = ch.n_max

        # batching policy (paper §IV conclusions + Guldogan et al. 2024)
        clipped = dist.clip(n_max)
        b_max = None
        policy = "elastic" if self.elastic_available else "dynamic"
        if heavy:
            fb = optimal_fixed_batch(clipped, self.batch_lat, lam,
                                     b_max=self.b_search)
            b_max = fb["b_star"]
            if not self.elastic_available:
                # padded decode pays the full max-token padding on a heavy
                # tail: route by predicted length instead (bin_edges below)
                policy = "multibin"

        # fleet axis (repro.core.fleet): smallest replica count keeping
        # per-replica batched utilization under target; a heavy tail wants
        # length-aware dispatch (predicted-work balancing), a light tail
        # only needs burst balancing
        replicas, router = 1, None
        avail = self.availability_hat()
        if self.max_replicas > 1:
            from repro.core.fleet import ROUTERS, recommend_replicas
            # availability-discounted effective-lambda transfer
            # (repro.core.faults.effective_lambda): a replica that is up a
            # fraction `avail` of the time sizes like load lam/avail
            replicas = recommend_replicas(
                lam / max(avail, 1e-12), clipped, self.batch_lat,
                target_util=self.replica_target_util,
                max_replicas=self.max_replicas)
            if replicas > 1:
                router = "least_work" if heavy else "jsq"
                assert router in ROUTERS, router

        rec = Recommendation(
            n_max=n_max, b_max=b_max, policy=policy, heavy_tailed=heavy,
            lam_hat=lam, replicas=replicas, router=router,
            availability=avail,
            shed_prob=self.shed_probability(lam, clipped),
            details={"scv": scv, "objective": ch.objective,
                     "expected_wait": ch.wait, "loss_frac": ch.loss_frac},
            # multibin and least_work route on predicted length: name the
            # predictor that should feed them (repro.core.predictors)
            predictor=(self.length_predictor
                       if policy == "multibin" or router == "least_work"
                       else None))
        # hysteresis: ignore <10% n_max moves (bin_edges revert alongside,
        # so the recommendation stays internally consistent)
        if (not force and self._last is not None
                and self._last.n_max and n_max
                and abs(n_max - self._last.n_max) < 0.1 * self._last.n_max):
            rec = dataclasses.replace(
                rec, n_max=self._last.n_max, b_max=self._last.b_max,
                bin_edges=(self._last.bin_edges
                           if rec.policy == "multibin" else None))
        if rec.policy == "multibin" and rec.bin_edges is None:
            # the coordinate descent is the expensive step: reuse the last
            # edges unless the operating point (n_max, lam) actually moved
            last = self._last
            if (last is not None and last.bin_edges is not None
                    and last.n_max == rec.n_max
                    and abs(lam - last.lam_hat)
                    < 0.1 * max(last.lam_hat, 1e-9)):
                edges = last.bin_edges
            else:
                edges = tuple(optimize_bin_edges(
                    dist.clip(rec.n_max), self.batch_lat, lam,
                    num_bins=self.num_bins))
            rec = dataclasses.replace(rec, bin_edges=edges)
        self._last = rec
        return rec
