"""M/G/1 with impatient users (paper §III-B, Eqs 6-9).

Users abandon if their queueing wait would exceed ``tau``. Two solvers:

1. ``dekok_tijms`` — the paper's approach: interpolate between the
   deterministic-service and exponential-service endpoints with the squared
   coefficient of variation zeta^2 (De Kok & Tijms 1985, Eqs 6-8), requiring
   0 <= zeta^2 <= 1.

2. ``level_crossing`` — beyond-paper exact solver: the stationary virtual
   waiting time density of M/G/1+D satisfies the level-crossing Volterra
   equation

       f(x) = lam * [ P0 * Bbar(x) + int_0^{min(x,tau)} f(y) Bbar(x-y) dy ]

   which is linear in P0; we solve u = f/P0 by forward substitution on a
   grid and normalize. Works for ANY service distribution (including the
   actual clipped token-latency law) with no zeta^2 restriction. The
   deterministic/exponential endpoints of (1) are computed with this same
   solver; the exponential endpoint has a closed form used as a unit test.

Both are validated against the event-driven simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import LatencyModel


@dataclasses.dataclass(frozen=True)
class ImpatienceResult:
    lam: float
    tau: float
    pi: float            # loss fraction pi(tau)
    wq_all: float        # E[W_q]: served + lost users  (lost wait tau)
    wq_served: float     # E[W_qs]
    p0: float            # P(V = 0)
    rho_offered: float   # lam * E[S]


def _service_survival_from_dist(dist: TokenDistribution, lat: LatencyModel,
                                n_max: Optional[int]):
    d = dist if n_max is None else dist.clip(n_max)
    atoms = lat.service_time(d.support)       # sorted ascending
    cdf = d.cdf

    def surv(u):
        # P(S > u): S takes value atoms[n] w.p. pmf[n]
        idx = np.searchsorted(atoms, u, side="right") - 1
        idx = np.clip(idx, -1, len(cdf) - 1)
        out = np.where(idx < 0, 1.0, 1.0 - cdf[np.maximum(idx, 0)])
        return out

    s_max = float(atoms[-1])
    return surv, s_max


def level_crossing(surv: Callable, lam: float, tau: float, s_max: float,
                   h: float = None) -> ImpatienceResult:
    """Solve the M/G/1+D virtual-wait density; see module docstring."""
    x_max = tau + s_max + 1e-9
    if h is None:
        h = max(x_max / 8000.0, 1e-4)
    n = int(np.ceil(x_max / h)) + 1
    xs = np.arange(n) * h
    i_tau = min(int(np.floor(tau / h)), n - 1)
    bbar = np.asarray(surv(xs), np.float64)

    trapz = np.trapezoid if hasattr(np, "trapezoid") else np.trapz

    u = np.zeros(n)
    u[0] = lam * bbar[0]
    denom = 1.0 - lam * h * 0.5 * bbar[0]
    for i in range(1, n):
        jmax = min(i, i_tau)
        # trapezoid sum of u_j * bbar_{i-j} over j = 0..jmax (known part)
        acc = 0.5 * u[0] * bbar[i]
        if jmax >= 2:
            js = np.arange(1, jmax)
            acc += float(u[js] @ bbar[i - js])
        if jmax == i:
            # endpoint j == i involves the unknown u_i: solve implicitly
            u[i] = lam * (bbar[i] + h * acc) / denom
        else:
            acc += 0.5 * u[jmax] * bbar[i - jmax]
            u[i] = lam * (bbar[i] + h * acc)
    # normalize: P0 * (1 + int u) = 1
    integral_u = float(trapz(u, dx=h))
    p0 = 1.0 / (1.0 + integral_u)
    f = p0 * u
    # loss fraction: P(V >= tau)
    pi = float(trapz(f[i_tau:], dx=h))
    head_x = float(trapz(f[: i_tau + 1] * xs[: i_tau + 1], dx=h))
    wq_all = head_x + tau * pi
    p_served = max(1.0 - pi, 1e-12)
    wq_served = (wq_all - tau * pi) / p_served
    return ImpatienceResult(lam=lam, tau=tau, pi=pi, wq_all=wq_all,
                            wq_served=wq_served, p0=p0,
                            rho_offered=float("nan"))


def exact_impatience(dist: TokenDistribution, lat: LatencyModel, lam: float,
                     tau: float, n_max: Optional[int] = None,
                     h: float = None) -> ImpatienceResult:
    """Level-crossing solve with the actual (clipped) service distribution."""
    surv, s_max = _service_survival_from_dist(dist, lat, n_max)
    res = level_crossing(surv, lam, tau, s_max, h)
    es, _ = lat.moments(dist, n_max)
    return dataclasses.replace(res, rho_offered=lam * es)


def mm1_impatience_closed_form(lam: float, mu: float, tau: float) -> ImpatienceResult:
    """Closed-form M/M/1+D endpoint (unit-test oracle).

    f(x) = lam*P0*e^{-(mu-lam)x} on (0,tau); lam*P0*e^{lam*tau}e^{-mu x} beyond.
    """
    rho = lam / mu
    d = mu - lam
    if abs(d) < 1e-12:
        d = 1e-12
    e = np.exp(-d * tau)
    z = 1.0 + (rho / (1.0 - rho)) * (1.0 - e) + rho * e if rho != 1.0 else np.inf
    p0 = 1.0 / z
    pi = rho * p0 * e
    # E[min(V,tau)] = P0 * int_0^tau x lam e^{-dx} dx + tau*pi
    integ = lam * (1.0 - e * (1.0 + d * tau)) / d ** 2
    wq_all = p0 * integ + tau * pi
    wq_served = (wq_all - tau * pi) / max(1.0 - pi, 1e-12)
    return ImpatienceResult(lam=lam, tau=tau, pi=pi, wq_all=wq_all,
                            wq_served=wq_served, p0=p0, rho_offered=rho)


def dekok_tijms(dist: TokenDistribution, lat: LatencyModel, lam: float,
                tau: float, n_max: Optional[int] = None,
                h: float = None) -> ImpatienceResult:
    """Paper Eqs (6)-(9): zeta^2 interpolation between det and exp endpoints."""
    es, es2 = lat.moments(dist, n_max)
    zeta2 = (es2 - es ** 2) / max(es ** 2, 1e-300)
    zeta2 = float(np.clip(zeta2, 0.0, 1.0))   # approximation's validity range

    mu = 1.0 / es
    # deterministic endpoint: service == es
    det = level_crossing(lambda u: (u < es).astype(np.float64), lam, tau, es, h)
    # exponential endpoint (closed form; also available via the solver)
    ex = mm1_impatience_closed_form(lam, mu, tau)

    pi = (1.0 - zeta2) * det.pi + zeta2 * ex.pi
    wq_all = (1.0 - zeta2) * det.wq_all + zeta2 * ex.wq_all
    wq_served = (wq_all - tau * pi) / max(1.0 - pi, 1e-12)   # Eq (9)
    return ImpatienceResult(lam=lam, tau=tau, pi=pi, wq_all=wq_all,
                            wq_served=wq_served,
                            p0=(1.0 - zeta2) * det.p0 + zeta2 * ex.p0,
                            rho_offered=lam * es)
