"""M/G/1 FCFS queueing delay with max-token clipping (paper §III-A, Eqs 1-5).

The Pollaczek-Khinchine mean waiting time

    E[W] = lambda * E[S^2] / (2 * (1 - rho)),   rho = lambda * E[S]

with the service time S = a*n + c driven by the (clipped) output-token
distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import LatencyModel


@dataclasses.dataclass(frozen=True)
class MG1Result:
    lam: float
    n_max: Optional[int]
    es: float          # E[S]
    es2: float         # E[S^2]
    rho: float
    wait: float        # E[W] queueing delay (excluding service)
    sojourn: float     # E[W] + E[S]
    stable: bool
    scv: float         # squared coefficient of variation zeta^2 (Eq 8)


def pollaczek_khinchine(lam: float, es: float, es2: float) -> float:
    rho = lam * es
    if rho >= 1.0:
        return np.inf
    return lam * es2 / (2.0 * (1.0 - rho))


def mg1_wait(dist: TokenDistribution, lat: LatencyModel, lam: float,
             n_max: Optional[int] = None) -> MG1Result:
    """Paper Eqs (1)-(5): queueing delay under a max-token limit n_max."""
    es, es2 = lat.moments(dist, n_max)
    rho = lam * es
    wait = pollaczek_khinchine(lam, es, es2)
    scv = (es2 - es ** 2) / max(es ** 2, 1e-300)
    return MG1Result(lam=lam, n_max=n_max, es=es, es2=es2, rho=rho,
                     wait=wait, sojourn=wait + es, stable=rho < 1.0, scv=scv)


def wait_curve(dist: TokenDistribution, lat: LatencyModel, lam: float,
               n_max_grid) -> np.ndarray:
    """E[W] as a function of the max-token limit (paper Fig 4a)."""
    return np.array([mg1_wait(dist, lat, lam, int(n)).wait for n in n_max_grid])


def mg1_feedback_wait(dist: TokenDistribution, lat: LatencyModel, lam: float,
                      sessions, n_max: Optional[int] = None) -> MG1Result:
    """M/G/1 with feedback (re-entrant sessions): a session of K turns
    visits the queue K times, so the server sees the effective arrival
    rate λ_eff = λ·E[K] with UNCHANGED per-visit service moments —
    Takács' feedback decomposition reduces the per-visit mean wait to
    P-K at λ_eff (exact for Poisson re-entry, and the think-time delays
    of :mod:`repro.core.sessions` push re-arrivals toward Poisson — the
    Kleinrock independence approximation).  ``sessions`` is a
    :mod:`repro.core.sessions` model, name, or spec; stability is
    ρ_eff = λ·E[K]·E[S] < 1."""
    from repro.core.sessions import session_from_spec
    model = session_from_spec(sessions)
    return mg1_wait(dist, lat, lam * model.mean_turns(), n_max)
