"""Bulk-service queueing models for batched LLM inference (paper §IV).

* Inoue's dynamic-batching M/G/1 bound (Eqs 14-16): service all waiting
  requests in one batch; batch time linear in batch size H[b] = alpha*b+beta;
  mean wait bounded by phi(lam, alpha, beta).
* LLM dynamic batching (Eqs 17-23): batch time additionally depends on the
  max output token length l in the batch, H[b,l] = k1 b + k2 + (k3 b + k4) l;
  linearized via order-statistic envelopes to reuse Eq (16).
* Fixed batching M/D^b/1 (Eqs 24-25): deterministic bulk service of exactly
  b requests; mean wait via the roots of z^b = exp(lam*H*(z-1)); the paper's
  truncated Lagrange series for the roots is provided alongside an exact
  Newton solve (beyond-paper robustness; they agree for rho < 0.9).
* Elastic batching (Eq 26): early-exit replies shrink the effective batch;
  completion time k1 b + k2 + k3*sum(n_i) + k4*max(n_i), again linearized.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel


# ----------------------------------------------------------------------------
# Inoue bound (Eq 16)
# ----------------------------------------------------------------------------

def inoue_bound(lam: float, alpha: float, beta: float) -> float:
    """min(phi_0, phi_1) upper bound on E[W] for dynamic batching with
    H[b] = alpha*b + beta (Inoue 2021, paper Eq 16). Stability: lam*alpha < 1."""
    if lam * alpha >= 1.0:
        return np.inf
    den = 2.0 * (1.0 - lam ** 2 * alpha ** 2)
    phi0 = lam * (alpha + beta) ** 2 / den
    phi1 = (lam * alpha * beta + lam * alpha ** 2 + beta) / den
    return float(min(phi0, phi1))


def dynamic_batching_bound(dist: TokenDistribution, lat: BatchLatencyModel,
                           lam: float, mode: str = "envelope",
                           quantile: float = 1.0,
                           b_range=None) -> dict:
    """Paper Eqs (19)-(20) generalized: linearize H^[b] then apply Eq (16)."""
    alpha, beta = lat.linear_envelope(dist, mode=mode, quantile=quantile,
                                      b_range=b_range)
    return {
        "alpha": alpha,
        "beta": beta,
        "wait_bound": inoue_bound(lam, alpha, beta),
        "stable": lam * alpha < 1.0,
    }


def elastic_batching_bound(dist: TokenDistribution, lat: BatchLatencyModel,
                           lam: float, quantile: float = 1.0) -> dict:
    """Paper Eq (26) + Eq (16): H_el[b] <= (k1 + k3*E[N])*b + k2 + k4*L_inf."""
    en = dist.mean()
    linf = dist.max_order_stat_limit(quantile)
    alpha = lat.k1 + lat.k3 * en
    beta = lat.k2 + lat.k4 * linf
    return {
        "alpha": alpha,
        "beta": beta,
        "wait_bound": inoue_bound(lam, alpha, beta),
        "stable": lam * alpha < 1.0,
    }


# ----------------------------------------------------------------------------
# Fixed batching: M/D^b/1 (Eq 25)
# ----------------------------------------------------------------------------

def _mdb1_roots_newton(lam_h: float, b: int, iters: int = 5000):
    """The b-1 roots (inside the unit disk, z != 1) of z^b = e^{lam_h (z-1)}.

    Fixed-point iteration on the branch form z = w_k * exp(lam_h (z-1)/b),
    w_k the k-th root of unity: a contraction for lam_h < b (|d/dz| =
    (lam_h/b)|z| < 1 on the closed unit disk), so it cannot escape to the
    spurious root z=1 the way Newton can."""
    ks = np.arange(1, b)
    w = np.exp(2j * np.pi * ks / b)
    z = w.copy()
    for _ in range(iters):
        z_new = w * np.exp(lam_h * (z - 1.0) / b)
        if np.max(np.abs(z_new - z)) < 1e-15:
            z = z_new
            break
        z = z_new
    return z


def _mdb1_roots_series(lam_h: float, b: int, terms: int = 20):
    """Paper Eq (25): truncated Lagrange series
    Z_k = sum_m exp(-lam_h m / b) (lam_h m / b)^{m-1} / m! * w_k^m."""
    ks = np.arange(1, b)
    w = np.exp(2j * np.pi * ks / b)
    ms = np.arange(1, terms + 1)
    x = lam_h / b
    log_c = (-x * ms + (ms - 1) * np.log(np.maximum(x * ms, 1e-300))
             - np.array([np.sum(np.log(np.arange(1, m + 1))) for m in ms]))
    c = np.exp(log_c)
    return (c[None, :] * (w[:, None] ** ms[None, :])).sum(axis=1)


def mdb1_wait_paper(lam: float, h_b: float, b: int,
                    method: str = "newton") -> float:
    """Paper Eq (25) EXACTLY as printed:

        E[W] = (1/lam) [ (b - (b - lam H)^2) / (2 (b - lam H))
                         + sum_{k=1}^{b-1} 1/(1 - Z_k) ]

    Notes recorded in EXPERIMENTS.md: at b=1 this equals the M/D/1 *sojourn*
    (wait + service), and the simulator shows the same +H(b) offset for
    general b — i.e. Eq (25) measures delay-until-departure. Use
    ``mdb1_wait_exact`` for the queue-wait; both are exposed so the
    reproduction is faithful AND correct.
    """
    lam_h = lam * h_b
    if lam_h >= b:
        return np.inf
    d = b - lam_h
    first = (b - d ** 2) / (2.0 * d)
    s = 0.0
    if b > 1:
        z = (_mdb1_roots_newton(lam_h, b) if method == "newton"
             else _mdb1_roots_series(lam_h, b))
        s = float(np.sum(1.0 / (1.0 - z)).real)
    return float((first + s) / lam)


def mdb1_queue_stationary(lam: float, h_b: float, b: int,
                          n_trunc: int = None) -> np.ndarray:
    """Stationary distribution of the number waiting at batch completions
    for the wait-until-b M/D^b/1 queue (embedded chain; exact up to
    truncation). L' = L - b + A if L >= b else A, with A ~ Poisson(lam*H)."""
    from scipy import stats as st
    lam_h = lam * h_b
    if lam_h >= b:
        raise ValueError("unstable")
    if n_trunc is None:
        n_trunc = int(max(20 * b, 40 * lam_h, 200))
    a_pmf = st.poisson(lam_h).pmf(np.arange(n_trunc + 1))
    P = np.zeros((n_trunc + 1, n_trunc + 1))
    for l in range(n_trunc + 1):
        base = max(l - b, 0) if l >= b else 0
        room = n_trunc - base
        P[l, base:] = a_pmf[: room + 1]
        P[l, n_trunc] += max(0.0, 1.0 - a_pmf[: room + 1].sum())
    # power iteration
    pi = np.ones(n_trunc + 1) / (n_trunc + 1)
    for _ in range(20000):
        new = pi @ P
        if np.abs(new - pi).sum() < 1e-13:
            pi = new
            break
        pi = new
    return pi / pi.sum()


def mdb1_wait_exact(lam: float, h_b: float, b: int) -> float:
    """Exact mean queue-wait for the wait-until-b M/D^b/1 the paper
    *describes* in §IV-C (beyond-paper: the printed Eq 25 does not track the
    simulated model away from the optimum — see EXPERIMENTS.md).

    Renewal-reward over completion epochs with stationary leftover
    distribution pi_l (``mdb1_queue_stationary``):

      cycle(l)   = H                      if l >= b
                   (b-l)/lam + H          if l <  b   (wait for b-l arrivals)
      intQ(l)    = sum_{i=l}^{b-1} i/lam  (idle accumulation)   [l < b only]
                   + s0(l)*H + lam*H^2/2  (during service),  s0 = max(l-b, 0)

      E[W] = E[Q]/lam = (sum_l pi_l intQ(l)) / (lam * sum_l pi_l cycle(l)).
    """
    lam_h = lam * h_b
    if lam_h >= b:
        return np.inf
    pi = mdb1_queue_stationary(lam, h_b, b)
    ls = np.arange(len(pi))
    below = ls < b
    cycle = np.where(below, (b - ls) / lam + h_b, h_b)
    # idle-phase integral: sum_{i=l}^{b-1} i / lam = (b(b-1)/2 - l(l-1)/2)/lam
    idle_q = np.where(below, (b * (b - 1) / 2.0 - ls * (ls - 1) / 2.0) / lam, 0.0)
    s0 = np.maximum(ls - b, 0)
    svc_q = s0 * h_b + lam * h_b ** 2 / 2.0
    eq = float((pi * (idle_q + svc_q)).sum())
    et = float((pi * cycle).sum())
    return eq / (lam * et)


def optimal_fixed_batch(dist: TokenDistribution, lat: BatchLatencyModel,
                        lam: float, b_max: int = 64,
                        method: str = "paper") -> dict:
    """Paper §IV-C: b* = argmin_b E[W] for M/D^b/1 with
    H^[b] = k1 b + k2 + (k3 b + k4) E[L_b]  (paper uses Eq 25)."""
    waits = {}
    for b in range(1, b_max + 1):
        h = float(lat.mean_batch_time(dist, b))
        if lam * h >= b:
            waits[b] = np.inf
            continue
        waits[b] = (mdb1_wait_paper(lam, h, b) if method == "paper"
                    else mdb1_wait_exact(lam, h, b))
    finite = {b: w for b, w in waits.items() if np.isfinite(w)}
    if not finite:
        return {"b_star": None, "wait": np.inf, "waits": waits}
    b_star = min(finite, key=finite.get)
    return {"b_star": b_star, "wait": finite[b_star], "waits": waits}


def service_rate_curve(dist: TokenDistribution, lat: BatchLatencyModel,
                       bs) -> np.ndarray:
    """mu^[b] = b / H^[b] (paper Eq 24 / Fig 3b)."""
    return lat.service_rate(dist, np.asarray(bs))


# ----------------------------------------------------------------------------
# WAIT threshold admission (Dai et al. 2025): holding + clearing envelope
# ----------------------------------------------------------------------------

def _mean_capped_gamma(m: int, lam: float, cap: Optional[float]) -> float:
    """E[min(X, cap)] for X ~ Gamma(m, scale=1/lam) (the time until the
    m-th subsequent Poisson arrival); m=0 -> 0.  Uses the identity
    x·f_m(x) = (m/λ)·f_{m+1}(x):  E[X·1{X<=c}] = (m/λ)·F_{m+1}(c)."""
    if m == 0:
        return 0.0
    if cap is None:
        return m / lam
    from scipy import stats as st
    below = float(st.gamma(a=m, scale=1.0 / lam).cdf(cap))
    mass = float(st.gamma(a=m + 1, scale=1.0 / lam).cdf(cap))
    return (m / lam) * mass + cap * (1.0 - below)


def wait_bound(dist: TokenDistribution, lat: BatchLatencyModel, lam: float,
               k: int, timeout: Optional[float] = None) -> dict:
    """Mean-delay envelope for WAIT threshold admission (hold batch
    formation until ``k`` requests are buffered or the head has waited
    ``timeout``; then serve everything arrived, no batch cap) — the
    M/D^k/1-like holding view with a timer cap:

    * **Holding arm.**  Couple each request to the group of ``k``
      consecutive arrivals it triggers with: the request in position j
      (from the group head) is held at most until the group's trigger —
      ``min(sum of its k-1-j subsequent interarrivals, timeout)`` — even
      when the server is busy (a busy server only replaces holding with
      queueing, which the second arm pays for).  Under Poisson arrivals
      the positional hold is E[min(Gamma(k-1-j, 1/λ), timeout)], averaged
      over j; without a timer it telescopes to (k-1)/(2λ), the mean
      residual of the deterministic-count trigger.

    * **Clearing arm.**  Once triggered, WAIT serves ALL arrived requests
      — the serve-all-waiting discipline whose backlog is dominated by
      Inoue's Eq-16 bound on the same (α, β) linear envelope dynamic
      batching uses (holding only *coalesces* work into larger, more
      amortized batches; it never adds work).

    The sum of the arms is an envelope (coupling) argument like
    ``multibin_bound``'s, not a closed form — Dai et al. prove throughput
    optimality, not a delay formula — and is validated for dominance and
    non-vacuousness against the simulator by ``tests/test_policies.py``
    (``WaitPolicy.analytic_kind == 'bound'``).  Stability is the dynamic-
    batching condition λ·α < 1 (holding does not change the drift)."""
    assert k >= 1
    holds = [_mean_capped_gamma(k - 1 - j, lam, timeout) for j in range(k)]
    hold = float(np.mean(holds))
    clearing = dynamic_batching_bound(dist, lat, lam)
    return {
        "wait_bound": hold + clearing["wait_bound"],
        "hold_arm": hold,
        "clearing_arm": clearing["wait_bound"],
        "alpha": clearing["alpha"],
        "beta": clearing["beta"],
        "stable": clearing["stable"],
    }


# ----------------------------------------------------------------------------
# Multi-bin batching (Guldogan et al. 2024): per-bin envelopes, delay bound,
# load-dependent boundary optimization
# ----------------------------------------------------------------------------

def multibin_split(dist: TokenDistribution, edges):
    """Split ``dist`` at ``edges`` into per-bin pieces.

    Returns a list of ``(p_j, dist_j, pad_j)``: the bin probability, the
    conditional token distribution (None when the bin is empty) and the
    bin's padding level — its upper boundary (the last bin pads to the
    distribution's max support).  Bin membership matches
    ``MultiBinPolicy.bin_of``: bin j holds tokens n with
    ``edges[j-1] < n <= edges[j]`` (searchsorted side='left')."""
    edges = np.asarray(edges, np.float64)
    bin_of = np.searchsorted(edges, dist.support, side="left")
    out = []
    for j in range(len(edges) + 1):
        mask = bin_of == j
        p = float(dist.pmf[mask].sum())
        pad = float(edges[j]) if j < len(edges) else float(dist.max_tokens)
        if p <= 0.0:
            out.append((0.0, None, pad))
        else:
            out.append((p, TokenDistribution(np.where(mask, dist.pmf, 0.0)),
                        pad))
    return out


def multibin_bound(dist: TokenDistribution, lat: BatchLatencyModel,
                   lam: float, edges, quantile: float = 1.0) -> dict:
    """Inoue-style mean-delay upper bound for multi-bin batching
    (serve-all-waiting within the picked bin, no batch cap), as the
    minimum of two envelope arms:

    * **Arm A — singleton padding** (tight at low load).  Pad every
      request to its bin's upper boundary and serve it ALONE, FCFS:
      ``S_pad = (k1 + k2) + (k3 + k4) * pad(N)``.  A bin-j batch of m
      requests costs ``k1 m + k2 + (k3 m + k4) L <= m * S_pad`` (L <=
      pad_j), so multi-bin only coalesces this work; the work-conserving
      M/G/1 on S_pad dominates and Pollaczek-Khinchine (paper Eq 1) gives
      its delay.

    * **Arm B — clearing rounds** (tight at high load).  Whenever the
      server frees, every bin that is non-empty gets cleared within one
      round of at most B batches (the earliest-head rule never revisits a
      bin before the others' older heads are served), and the round is
      dominated by one bulk service with ``H~[m] = alpha~ m + beta~``,
      ``alpha~ = max_j (k1 + k3 pad_j)``, ``beta~ = sum_j (k2 + k4
      pad_j)`` — the aggregate-utilization coupling: all bins share the
      alpha~ per-request rate, and one round pays every bin's per-batch
      overhead once.  Inoue's Eq-16 bound applies to that envelope
      system.

    Both arms are envelope (coupling) arguments, not closed-form exact
    results; ``tests/test_policies.py`` validates dominance against the
    simulator across loads.  Returns the arms alongside the combined
    ``wait_bound``.

    ``quantile`` (like ``dynamic_batching_bound``'s) caps the *round
    arm's* per-bin padding levels at the distribution's ``quantile``-point
    instead of its max support.  The open last bin is what breaks the arm
    on heavy tails: lognormal(7, 0.7) has max support ~32768, so
    ``alpha~ = max_j (k1 + k3 pad_j)`` makes ``lam * alpha~ >= 1`` and the
    arm returns inf at loads where the simulator is perfectly stable.
    With ``quantile < 1`` the envelope ignores the top ``(1-q)`` tail of
    the padding support — no longer a strict bound (pair it with
    ``analytic_kind='approx'``), but finite and useful across the heavy-
    tail operating range.  The singleton arm keeps the exact pads: it
    integrates over the pmf, so the tail's mass — not its support —
    enters, and it stays finite regardless."""
    parts = multibin_split(dist, edges)
    k1, k2, k3, k4 = lat.k1, lat.k2, lat.k3, lat.k4
    # Arm A: P-K on the bin-padded singleton service
    pads = np.asarray([pad for _, _, pad in parts])
    edges = np.asarray(edges, np.float64)
    pad_of = pads[np.searchsorted(edges, dist.support, side="left")]
    s = (k1 + k2) + (k3 + k4) * pad_of
    es = float((dist.pmf * s).sum())
    es2 = float((dist.pmf * s ** 2).sum())
    from repro.core.mg1 import pollaczek_khinchine
    wait_a = pollaczek_khinchine(lam, es, es2)
    # Arm B: one clearing round as a single bulk service (pads optionally
    # capped at the quantile envelope; quantile=1.0 keeps the strict arm)
    pad_cap = dist.max_order_stat_limit(quantile)
    occupied = [(p, min(pad, pad_cap)) for p, _, pad in parts if p > 0]
    alpha = max(k1 + k3 * pad for _, pad in occupied)
    beta = sum(k2 + k4 * pad for _, pad in occupied)
    wait_b = inoue_bound(lam, alpha, beta)
    return {
        "wait_bound": float(min(wait_a, wait_b)),
        "wait_singleton_arm": float(wait_a),
        "wait_round_arm": float(wait_b),
        "alpha": float(alpha),
        "beta": float(beta),
        "quantile": float(quantile),
        "stable": lam * alpha < 1.0,
    }


def multibin_saturated_service(dist: TokenDistribution,
                               lat: BatchLatencyModel, edges, b) -> float:
    """Mean per-request service time at saturation with per-bin batches of
    size ``b``:  sbar = k1 + k2/b + (k3 + k4/b) * sum_j p_j E[max of b
    draws | bin j].  Its reciprocal is the system's service capacity, so
    minimizing sbar over the boundaries maximizes throughput — the
    Guldogan et al. objective.  Binning exists exactly to shrink the
    E[max] term: members of one bin have similar lengths, so the batch max
    hugs the bin mean instead of the global tail."""
    el = sum(p * d.max_order_stat_mean(b)
             for p, d, _ in multibin_split(dist, edges) if p > 0)
    return float(lat.k1 + lat.k2 / b + (lat.k3 + lat.k4 / b) * el)


def optimize_bin_edges(dist: TokenDistribution, lat: BatchLatencyModel,
                       lam: float, num_bins: int = 4, b_cap: int = 64,
                       sweeps: int = 2, grid: int = 65) -> np.ndarray:
    """Load-dependent bin boundaries (Guldogan et al. 2024), replacing the
    equal-probability-mass quantiles ``MultiBinPolicy`` defaults to.

    The load enters through the **effective batch size** ``b(lam)``: the
    smallest per-bin batch size whose saturated per-request service time
    keeps the system stable (``lam * sbar_b < 1``, evaluated at the
    quantile boundaries; capped at ``b_cap``).  Light load => b(lam)=1 and
    every boundary choice is equivalent (sbar_1 telescopes to the global
    mean — the quantile start is returned unchanged); heavy load => large
    b(lam), the per-bin batch maxima dominate, and boundaries matter.

    Given b(lam), coordinate descent over a support-quantile candidate
    grid minimizes ``sbar(edges; b)``; starting from the equal-mass
    quantiles and only accepting improvements, so the result never loses
    to the quantile default on the objective.  Returns ascending float
    edges of length ``num_bins - 1``."""
    assert num_bins >= 2
    qs = np.arange(1, num_bins) / num_bins
    edges = np.asarray([float(np.searchsorted(dist.cdf, q)) for q in qs])
    b = 1
    while b < b_cap and lam * multibin_saturated_service(
            dist, lat, edges, b) >= 1.0:
        b += 1
    cand = np.unique(np.asarray(
        [float(np.searchsorted(dist.cdf, q))
         for q in np.linspace(0.005, 0.995, grid)]))
    best = multibin_saturated_service(dist, lat, edges, b)
    for _ in range(sweeps):
        improved = False
        for i in range(len(edges)):
            lo = edges[i - 1] if i > 0 else 0.0
            hi = edges[i + 1] if i + 1 < len(edges) else float(dist.max_tokens)
            for c in cand[(cand > lo) & (cand < hi)]:
                trial = edges.copy()
                trial[i] = c
                val = multibin_saturated_service(dist, lat, trial, b)
                if val < best - 1e-12:
                    best, edges, improved = val, trial, True
        if not improved:
            break
    return edges


# ----------------------------------------------------------------------------
# SRPT-like shortest-predicted-first batching: size-interval envelope
# ----------------------------------------------------------------------------

def srpt_bound(dist: TokenDistribution, lat: BatchLatencyModel, lam: float,
               b_max: Optional[int], num_classes: int = 8) -> dict:
    """Mean-delay envelope for capped shortest-predicted-first batching
    (:class:`~repro.core.policies.SRPTPolicy` under oracle ordering), via
    the size-interval decomposition classic SRPT analysis uses
    (Harchol-Balter), adapted to batched non-preemptive service:

    * **Class arm.**  Split the token support into ``num_classes``
      equal-mass classes with upper edges ``e_1 < ... < e_J``.  While a
      class-j request waits, shortest-first formation only starts batches
      of shorter-or-equal requests, so its backlog is the system restricted
      to classes <= j: Poisson ``lam_j = lam * F(e_j)`` with every member
      padded to ``e_j``.  With the cap ``b``, clearing a backlogged room
      amortizes the per-batch overhead over at most ``b`` members, so the
      per-request envelope is ``alpha'_j = k1 + k3 e_j + (k2 + k4 e_j)/b``
      with per-batch overhead ``beta_j = k2 + k4 e_j``, and Inoue's Eq-16
      bound applies to that (alpha'_j, beta_j) system.  The arm is the
      class-probability mixture of the per-class bounds.

    * **Residual arm.**  Formation never preempts a running batch, so an
      arrival can additionally find a batch of LONGER requests in service
      — at most one, ever (every batch formed after it arrives is
      shorter-or-equal or includes it).  The stationary residual of that
      batch is bounded by ``rho * H(b, e_J) / 2`` with ``rho = min(1,
      lam * alpha'_J)`` the amortized-utilization envelope.

    Like :func:`wait_bound` and :func:`multibin_bound` this is an envelope
    (coupling) argument, not a closed form — no exact mean-delay result is
    known for batched SRPT — and ``tests/test_policies.py`` validates
    dominance and non-vacuousness against the simulator across loads.
    With ``b_max=None`` membership degenerates to dynamic batching (the
    policy serves every waiting request; order inside a padded batch is
    irrelevant) and the exact dynamic envelope is returned instead.
    Stability is the top class's ``lam * alpha'_J < 1``."""
    if b_max is None:
        d = dynamic_batching_bound(dist, lat, lam)
        return {
            "wait_bound": d["wait_bound"],
            "class_arm": d["wait_bound"],
            "residual_arm": 0.0,
            "edges": [float(dist.max_tokens)],
            "stable": d["stable"],
        }
    assert b_max >= 1
    J = num_classes
    k1, k2, k3, k4 = lat.k1, lat.k2, lat.k3, lat.k4
    edges = sorted({int(np.searchsorted(dist.cdf, j / J))
                    for j in range(1, J)} | {int(dist.max_tokens)})
    class_arm, prev_f = 0.0, 0.0
    for e in edges:
        f = float(dist.cdf[e])
        p, prev_f = f - prev_f, f
        if p <= 0.0:
            continue
        beta = k2 + k4 * e
        alpha_p = k1 + k3 * e + beta / b_max
        class_arm += p * inoue_bound(lam * f, alpha_p, beta)
    e_top = edges[-1]
    beta_top = k2 + k4 * e_top
    alpha_top = k1 + k3 * e_top + beta_top / b_max
    rho = min(1.0, lam * alpha_top)
    residual = rho * float(lat.batch_time(b_max, e_top)) / 2.0
    return {
        "wait_bound": float(class_arm + residual),
        "class_arm": float(class_arm),
        "residual_arm": float(residual),
        "edges": [float(e) for e in edges],
        "stable": lam * alpha_top < 1.0,
    }


# ----------------------------------------------------------------------------
# Prefill/decode tandem with a KV-memory budget: decomposition bound
# ----------------------------------------------------------------------------

def tandem_bound(dist: TokenDistribution, lat: BatchLatencyModel, lam: float,
                 memory=None, quantile: float = 1.0) -> dict:
    """Mean-delay envelope for the memory-gated prefill/decode tandem
    (:mod:`repro.core.memory`), decomposed by which resource binds:

    * **Slack arm** (budget never binds).  The pipelined tandem starts
      every batch no later than the serial single-stage system would
      (prefill frees before the decode tail), so with unconstrained
      memory the serial dynamic-batching envelope
      (:func:`dynamic_batching_bound`) dominates.  This is the
      ``wait_bound`` for a null budget.

    * **Memory arm** (budget binds).  The SERIAL-gated envelope: pad
      every request to the ``quantile``-capped max support ``L_q``, cap
      batches at ``b_mem = floor(M / footprint(L_q))`` — the largest
      batch GUARANTEED to fit (``MemoryBudget.max_batch``) — and admit
      only after the previous batch completes and frees its KV, so the
      capped clearing amortizes to ``alpha' = k1 + k3 L_q + (k2 + k4
      L_q)/b_mem``, ``beta = k2 + k4 L_q``, bounded by Inoue's Eq 16.
      This is the constrained ``wait_bound``; the slack arm is reported
      alongside as the M -> inf reference (it is NOT valid when memory
      binds: the gate forces smaller batches than serve-all forms, and
      constrained cells simulate above it).

    A finding the validation suite pins down: pipelining is NOT
    uniformly dominated by this serial coupling.  At *intermediate*
    budgets the prefill stage races ahead of the slow decode stage,
    fills the budget with the KV of admitted-but-undecoded batches, and
    subsequent admissions fragment into small, poorly amortized batches
    — the simulated tandem then sits ABOVE the serial envelope (e.g.
    lam=0.12, M=8000 on the standard UNI/LAT constants) while remaining
    stable.  The bound therefore certifies the admission-dominated
    regime (small ``b_mem``, where gated admission serializes the
    pipeline and the coupling is tight); ``tests/test_memory.py``
    validates multi-seed dominance and tightness there, plus the
    instability flag where the worst-case certificate ``lam * alpha' <
    1`` fails (the cell may still simulate stably — mixed-size batches
    pack better than the ``L_q`` worst case — but no envelope guarantee
    exists, and the bound is inf)."""
    from repro.core.memory import memory_from_spec
    budget = memory_from_spec(memory)
    slack = dynamic_batching_bound(dist, lat, lam, quantile=quantile)
    if budget.is_null:
        return {
            "wait_bound": slack["wait_bound"],
            "slack_arm": slack["wait_bound"],
            "memory_arm": None,
            "b_mem": None,
            "quantile": float(quantile),
            "stable": slack["stable"],
        }
    b_mem = budget.max_batch(dist, quantile)
    lq = float(dist.max_order_stat_limit(quantile))
    # the prompt enters the FOOTPRINT (via max_batch) but not the decode
    # clock: H depends on generated tokens only
    beta = lat.k2 + lat.k4 * lq
    alpha_p = lat.k1 + lat.k3 * lq + beta / b_mem
    mem_arm = inoue_bound(lam, alpha_p, beta)
    return {
        "wait_bound": float(mem_arm),
        "slack_arm": slack["wait_bound"],
        "memory_arm": float(mem_arm),
        "b_mem": int(b_mem),
        "alpha": float(alpha_p),
        "beta": float(beta),
        "quantile": float(quantile),
        "stable": lam * alpha_p < 1.0,
    }


# ----------------------------------------------------------------------------
# Server breakdowns (beyond paper; M/G/1 with interruptions)
# ----------------------------------------------------------------------------

def breakdown_wait(dist: TokenDistribution, lat, lam: float,
                   mtbf: float, mttr: float, R: int = 1,
                   policy=None) -> dict:
    """Mean queueing delay on a breaking server — the analytic transfer
    for the ``crash`` fault model (:mod:`repro.core.faults`) under
    preemptive-resume semantics (``lose_work=False``) on a random-split
    fleet of R replicas (each replica = the single-server model at λ/R,
    the PR 5 superposition argument).

    ``policy=None`` (FCFS): the classic M/G/1-with-breakdowns
    completion-time decomposition (Gaver 1962).  With exponential
    up-times (rate ξ = 1/mtbf) and exponential repairs (mean r = mttr),
    a job of service S has completion time C = S + sum of repairs begun
    during it:

        E[C]  = (1 + ξ r) E[S] = E[S] / a,      a = mtbf / (mtbf + mttr)
        E[C²] = (1 + ξ r)² E[S²] + 2 ξ r² E[S]

    and the wait is Pollaczek–Khinchine on the C-moments plus the
    residual repair an arrival finds in progress (PASTA, memoryless):

        E[W] = λ E[C²] / (2 (1 − λ E[C])) + (1 − a) r

    ``policy`` set (a bulk/batched BatchPolicy): the **envelope arm** —
    the availability-discounted effective-λ transfer
    (:func:`repro.core.faults.effective_lambda`): the policy's own
    ``analytic_delay`` at λ/(R·a), time-dilated back by 1/a, plus the
    same residual-repair term.  Exact to first order (it equals the
    FCFS form when the completion-time burst correction vanishes);
    validated against the fault-injected sim within the same tolerance
    bands as the existing analytic cross-checks."""
    assert mtbf > 0 and mttr > 0 and R >= 1
    a = mtbf / (mtbf + mttr)
    xi, r = 1.0 / mtbf, mttr
    lam_r = lam / R
    out = {"availability": a, "lam_eff": lam_r / a, "R": R}
    if policy is None:
        from repro.core.mg1 import pollaczek_khinchine
        from repro.core.policies import single_from_batch
        single = lat if not isinstance(lat, BatchLatencyModel) \
            else single_from_batch(lat)
        es, es2 = single.moments(dist, None)
        ec = (1.0 + xi * r) * es
        ec2 = (1.0 + xi * r) ** 2 * es2 + 2.0 * xi * r * r * es
        out.update(kind="exact", stable=lam_r * ec < 1.0,
                   wait=float(pollaczek_khinchine(lam_r, ec, ec2)
                              + (1.0 - a) * r))
        return out
    base = policy.analytic_delay(lam_r / a, dist, lat)
    out.update(kind="envelope",
               stable=base is not None and np.isfinite(base),
               wait=None if base is None
               else float(base / a + (1.0 - a) * r))
    return out


# ----------------------------------------------------------------------------
# Re-entrant sessions (beyond paper; M/G/1 with feedback)
# ----------------------------------------------------------------------------

def feedback_policy_delay(policy, lam: float, dist: TokenDistribution,
                          lat, sessions) -> dict:
    """Per-visit mean queueing delay of a batched policy under
    re-entrant sessions (:mod:`repro.core.sessions`): a session of K
    turns visits the queue K times, so the policy's own closed form is
    evaluated at the effective arrival rate

        λ_eff = λ · E[K]

    with unchanged per-visit service moments — the same effective-λ
    transfer as :func:`repro.core.mg1.mg1_feedback_wait`, lifted to any
    policy with an ``analytic_delay`` (FCFS P-K, dynamic/elastic bulk
    forms, multibin envelopes).  Exact when the superposed re-arrival
    stream is Poisson; think-time delays decorrelate re-arrivals from
    the queue state (Kleinrock independence), and the conformance suite
    validates the band against multi-seed sim.  Returns ``{"wait",
    "lam_eff", "mean_turns", "stable"}`` with ``wait=None`` when the
    policy has no closed form (``analytic_kind=None``)."""
    from repro.core.sessions import session_from_spec
    model = session_from_spec(sessions)
    mt = float(model.mean_turns())
    lam_eff = lam * mt
    wait = policy.analytic_delay(lam_eff, dist, lat)
    return {
        "wait": None if wait is None else float(wait),
        "lam_eff": float(lam_eff),
        "mean_turns": mt,
        "stable": wait is not None and np.isfinite(wait),
    }
