"""Event-driven simulators (paper §V's validation methodology).

Every analytic quantity in ``mg1``, ``impatience`` and ``bulk`` is validated
against these simulators in the test-suite and benchmarks. They model:

  * FCFS M/G/1 with max-token clipping and (optionally) deterministic
    impatience tau  (paper Figs 4a-4c)
  * dynamic batching (all waiting requests, optionally capped at b_max)
    with padded batch time H[b, l]         (paper Figs 5, 6b)
  * fixed batching (wait until exactly b)  (paper Fig 6a)
  * elastic batching (early-exit replies, Eq 26)  (paper Figs 5, 6b)

Waits are *queueing delays* (arrival -> service start), matching the paper.

These interpreted event loops are the REFERENCE ORACLE: they favour
obviousness over speed. Production sweeps (λ grids, policy search) should
use :mod:`repro.core.fastsim`, whose compiled scan/closed-form twins sample
with the same rng call order and are pinned trajectory-equal to these loops
by ``tests/test_fastsim.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel


def _warm(arr, frac=0.1):
    k = int(len(arr) * frac)
    return np.asarray(arr[k:])


# ----------------------------------------------------------------------------
# M/G/1 FCFS
# ----------------------------------------------------------------------------

def simulate_mg1(lam: float, dist: TokenDistribution, lat: LatencyModel,
                 n_max: Optional[int] = None, tau: Optional[float] = None,
                 num_requests: int = 200_000, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / lam, num_requests)
    tokens = dist.sample(rng, num_requests)
    if n_max is not None:
        tokens = np.minimum(tokens, n_max)
    service = lat.service_time(tokens)

    if tau is None:
        # vectorized Lindley recursion: W_{n+1} = max(0, W_n + S_n - A_{n+1})
        x = service[:-1] - inter[1:]
        c = np.concatenate([[0.0], np.cumsum(x)])
        waits = c - np.minimum.accumulate(c)
        waits = _warm(waits)
        return {
            "mean_wait": float(waits.mean()),
            "mean_wait_served": float(waits.mean()),
            "loss_frac": 0.0,
            "p95_wait": float(np.percentile(waits, 95)),
            "waits": waits,
        }

    # impatience: workload recursion with admission only when V < tau
    waits = np.empty(num_requests)
    lost = np.zeros(num_requests, bool)
    v = 0.0
    t = 0.0
    for i in range(num_requests):
        t += inter[i]
        v = max(0.0, v - inter[i])
        if v >= tau:
            waits[i] = tau          # lost users spend tau in queue (Eq 9)
            lost[i] = True
        else:
            waits[i] = v
            v += service[i]
    waits_w, lost_w = _warm(waits), _warm(lost)
    served = waits_w[~lost_w]
    return {
        "mean_wait": float(waits_w.mean()),
        "mean_wait_served": float(served.mean()) if served.size else 0.0,
        "loss_frac": float(lost_w.mean()),
        "p95_wait": float(np.percentile(waits_w, 95)),
        "waits": waits_w,
    }


# ----------------------------------------------------------------------------
# Batching simulators
# ----------------------------------------------------------------------------

def simulate_dynamic_batching(lam: float, dist: TokenDistribution,
                              lat: BatchLatencyModel,
                              b_max: Optional[int] = None,
                              elastic: bool = False,
                              n_max: Optional[int] = None,
                              num_requests: int = 200_000,
                              seed: int = 0) -> dict:
    """Dynamic batching: when the server frees, take min(waiting, b_max)
    requests in one batch (all of them when b_max is None). elastic=True uses
    the Eq-26 completion time instead of padded H[b, max]."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, num_requests))
    tokens = dist.sample(rng, num_requests).astype(np.float64)
    if n_max is not None:
        tokens = np.minimum(tokens, n_max)

    waits = np.empty(num_requests)
    batch_sizes = []
    head = 0                  # next unserved request
    t_free = 0.0
    while head < num_requests:
        # requests that have arrived by t_free
        if arrivals[head] >= t_free:
            # idle: serve the next arrival alone at its arrival time
            start = arrivals[head]
            hi = head + 1
        else:
            start = t_free
            hi = int(np.searchsorted(arrivals, t_free, side="right"))
        if b_max is not None:
            hi = min(hi, head + b_max)
        ns = tokens[head:hi]
        waits[head:hi] = start - arrivals[head:hi]
        h = (lat.elastic_batch_time(ns) if elastic
             else float(lat.batch_time(len(ns), ns.max())))
        batch_sizes.append(len(ns))
        t_free = start + h
        head = hi
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(np.mean(batch_sizes)),
        "waits": w,
    }


def simulate_fixed_batching(lam: float, b: int,
                            dist: Optional[TokenDistribution],
                            lat: Optional[BatchLatencyModel] = None,
                            batch_time: Optional[Callable] = None,
                            num_requests: int = 200_000,
                            seed: int = 0) -> dict:
    """Fixed batching: the server waits until exactly b requests are present
    (paper §IV-C), then serves them together."""
    rng = np.random.default_rng(seed)
    num_requests = (num_requests // b) * b
    arrivals = np.cumsum(rng.exponential(1.0 / lam, num_requests))
    if dist is not None:
        tokens = dist.sample(rng, num_requests).astype(np.float64)
    else:
        tokens = np.zeros(num_requests)
    if batch_time is None:
        assert lat is not None
        batch_time = lambda ns: float(lat.batch_time(len(ns), ns.max()))

    waits = np.empty(num_requests)
    t_free = 0.0
    for head in range(0, num_requests, b):
        batch_arr = arrivals[head:head + b]
        start = max(t_free, batch_arr[-1])   # need all b present
        waits[head:head + b] = start - batch_arr
        t_free = start + batch_time(tokens[head:head + b])
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "waits": w,
    }


def simulate_policy_sweep(lam_grid, dist, lat, policies: dict,
                          num_requests: int = 100_000, seed: int = 0) -> dict:
    """Convenience: mean wait for each policy over an arrival-rate grid.
    policies: name -> dict(kind='dynamic'|'fixed'|'elastic', **kwargs)."""
    out = {name: [] for name in policies}
    for lam in lam_grid:
        for name, spec in policies.items():
            kind = spec.get("kind")
            if kind == "dynamic":
                r = simulate_dynamic_batching(
                    lam, dist, lat, b_max=spec.get("b_max"),
                    num_requests=num_requests, seed=seed)
            elif kind == "elastic":
                r = simulate_dynamic_batching(
                    lam, dist, lat, b_max=spec.get("b_max"), elastic=True,
                    num_requests=num_requests, seed=seed)
            elif kind == "fixed":
                r = simulate_fixed_batching(
                    lam, spec["b"], dist, lat,
                    num_requests=num_requests, seed=seed)
            else:
                raise ValueError(kind)
            out[name].append(r["mean_wait"])
    return {k: np.asarray(v) for k, v in out.items()}
