"""Policy-driven reference oracle (paper §V's validation methodology).

Since the batching-policy refactor every serving discipline is defined ONCE
in :mod:`repro.core.policies` (formation trigger, member selection,
clipping, service law); this module contributes the *event loops* that
drive a policy on a sampled workload:

  * ``_oracle_mg1``        — single-server Lindley / workload recursion
    (FCFS with optional deterministic impatience tau; paper Figs 4a-4c)
  * ``_oracle_batches``    — the generic batch-formation loop shared by
    dynamic, fixed, elastic, multi-bin, WAIT and SRPT batching (paper
    Figs 5-6; the policy's ``formation()`` supplies trigger+membership,
    its ``batch_time()`` the service law — WAIT and SRPT needed zero new
    oracle code)
  * ``_oracle_continuous`` — iteration-level slot refill on a virtual
    clock (beyond paper; mirrors the engine's fused chunked decode)

``simulate_policy(policy, ...)`` dispatches on ``policy.oracle_kind``; the
``ORACLES`` table is extensible, so a new policy family can register its
own loop without touching existing ones.  The legacy entry points
(``simulate_mg1``, ``simulate_dynamic_batching``, ...) are thin wrappers
that construct the corresponding policy — they remain trajectory-equal
(bit-equal waits) to the pre-refactor loops.

Waits are *queueing delays* (arrival -> service start), matching the paper.

These interpreted loops are the REFERENCE ORACLE: they favour obviousness
over speed.  Production sweeps (λ grids, policy search) should use
:mod:`repro.core.fastsim`, whose compiled kernels sample with the same rng
call order and are pinned trajectory-equal to these loops by
``tests/test_fastsim.py`` and ``tests/test_policies.py``.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    BatchPolicy, ContinuousPolicy, DynamicPolicy, ElasticPolicy, FCFSPolicy,
    FixedPolicy, Workload, policy_from_spec)


# Warmup trimming is host-side in every oracle AND every fastsim kernel
# (both call the one ``_warm`` below), so one stack-scoped switch disables
# it for callers that need per-request waits aligned to the full workload —
# the fault-injection driver (:mod:`repro.core.faults`) re-runs replicas on
# growing retry multisets and must map waits back to individual requests.
_WARMUP_ENABLED = [True]


@contextlib.contextmanager
def no_warmup():
    """Inside this context every oracle/kernel returns FULL per-request
    waits (no 10% warmup trim); summary stats then cover the full stream.
    Used by :mod:`repro.core.faults` for request-level bookkeeping."""
    _WARMUP_ENABLED.append(False)
    try:
        yield
    finally:
        _WARMUP_ENABLED.pop()


def _warm(arr, frac=0.1):
    if not _WARMUP_ENABLED[-1]:
        return np.asarray(arr)
    k = int(len(arr) * frac)
    return np.asarray(arr[k:])


ORACLES: Dict[str, Callable] = {}


def oracle(kind: str):
    def deco(fn):
        ORACLES[kind] = fn
        return fn
    return deco


def simulate_policy(policy: BatchPolicy, lam: float,
                    dist: Optional[TokenDistribution], lat,
                    num_requests: int = 200_000, seed: int = 0,
                    workload: Optional[Workload] = None,
                    fault_trace=None, traffic=None, sessions=None,
                    prefix_discount: float = 0.0, memory=None) -> dict:
    """Run ``policy`` through its reference event loop.  ``lat`` is the
    policy's latency law (``LatencyModel`` for single-service policies,
    ``BatchLatencyModel`` otherwise — a batch law handed to a
    single-service policy is converted via ``single_from_batch``).

    ``workload`` overrides the policy's own sampling (``lam``,
    ``num_requests`` and ``seed`` are then ignored) — the fleet layer
    (:mod:`repro.core.fleet`) uses this to run a routed sub-stream through
    the unchanged single-server event loops.

    ``fault_trace`` (a :class:`repro.core.faults.ReplicaTrace`) injects
    failure epochs into the event loop via the operational-time
    transform: arrivals are mapped onto the server's cumulative-capacity
    clock, the UNCHANGED loop runs in operational time (formation timers
    freeze while the server is down), and service starts are mapped back
    to wall-clock — exactly a work-conserving queue on a breaking server
    (preemptive-resume).  Crash-mode work loss is layered on top by
    :func:`repro.core.faults.simulate_fleet_faulty`.

    ``traffic`` (a :mod:`repro.core.traffic` model, name or spec)
    modulates the arrival rate by warping the sampled arrivals through
    the model's time-rescaling transform; a null model leaves the
    trajectory bit-identical (the warp is never applied).

    ``sessions`` (a :mod:`repro.core.sessions` model, name or spec)
    makes requests RE-ENTER: completed turns re-arrive at ``completion +
    think`` via the feedback fixed point in
    :func:`repro.core.sessions.simulate_policy_sessions`.  A null model
    (``single`` / zero feedback) takes this exact code path — bit
    equality by construction.

    ``memory`` (a :class:`repro.core.memory.MemoryBudget`, bare capacity
    number, or spec dict) switches batch service to the prefill/decode
    TANDEM with KV-budget admission (:func:`repro.core.memory.
    tandem_oracle`).  A null budget (capacity None/inf) takes this exact
    code path — bit equality by construction, because an unconstrained
    tandem pipeline is a different (faster) system than the serial
    ``H(b, l)`` gate, not a degenerate case of it."""
    mem = None
    if memory is not None:
        from repro.core.memory import check_policy_supports_memory, \
            memory_from_spec
        mem = memory_from_spec(memory)
        if mem.is_null:
            mem = None
        else:
            check_policy_supports_memory(policy)
    if sessions is not None:
        from repro.core.sessions import (session_from_spec,
                                         simulate_policy_sessions)
        model = session_from_spec(sessions)
        if not model.is_null:
            if mem is not None:
                raise ValueError(
                    "sessions= x memory= is not supported: turn re-entry "
                    "holds KV across think times (a different occupancy "
                    "law); run the tandem on the expanded per-turn stream "
                    "instead")
            if workload is not None:
                raise ValueError("sessions= expands its own workload; "
                                 "pass lam/num_requests/seed instead of "
                                 "workload=")
            return simulate_policy_sessions(
                policy, lam, dist, lat, num_requests, seed, model,
                fault_trace=fault_trace, traffic=traffic,
                prefix_discount=prefix_discount, fast=False)
    if policy.uses_single_latency and isinstance(lat, BatchLatencyModel):
        from repro.core.policies import single_from_batch
        lat = single_from_batch(lat)
    wl = workload if workload is not None else \
        policy.sample_workload(lam, dist, num_requests, seed)
    if traffic is not None:
        from repro.core.traffic import warp_workload
        wl = warp_workload(wl, traffic, seed)
    if mem is not None:
        from repro.core.memory import tandem_oracle
        run = lambda w: tandem_oracle(policy, w, lat, dist, mem)
    else:
        run = lambda w: ORACLES[policy.oracle_kind](policy, w, lat, dist)
    if fault_trace is not None and not fault_trace.empty:
        # operational-time transform composes: the tandem (and its KV
        # admission clock) runs on the server's cumulative-capacity time
        return _with_fault_trace(run, wl, fault_trace)
    return run(wl)


def _with_fault_trace(run, wl: Workload, trace) -> dict:
    """Shared breakdown wrapper (oracle AND fast layers): run the
    fault-free simulator on the operational-time workload, then map the
    service starts back through the trace's inverse transform.  Works
    with or without warmup trimming (trimmed waits align to the stream
    tail)."""
    op_arr = trace.op_time(wl.arrivals)
    op_wl = Workload(arrivals=op_arr, tokens=wl.tokens,
                     inter=np.diff(op_arr, prepend=0.0),
                     predicted=wl.predicted)
    res = run(op_wl)
    op_waits = np.asarray(res["waits"], np.float64)
    off = len(wl.arrivals) - len(op_waits)          # warmup offset
    start_wall = trace.wall_time(op_arr[off:] + op_waits)
    waits = start_wall - np.asarray(wl.arrivals)[off:]
    out = dict(res)
    out.update({
        "waits": waits,
        "mean_wait": float(waits.mean()) if waits.size else 0.0,
        "p95_wait": float(np.percentile(waits, 95)) if waits.size else 0.0,
    })
    if "mean_wait_served" in res:
        out["mean_wait_served"] = out["mean_wait"]
    return out


# ----------------------------------------------------------------------------
# M/G/1 FCFS (single-service policies)
# ----------------------------------------------------------------------------

@oracle("mg1")
def _oracle_mg1(policy, wl: Workload, lat, dist) -> dict:
    inter, tokens = wl.inter, wl.tokens
    service = lat.service_time(tokens)
    tau = policy.tau
    num_requests = len(tokens)

    if tau is None:
        # vectorized Lindley recursion: W_{n+1} = max(0, W_n + S_n - A_{n+1})
        x = service[:-1] - inter[1:]
        c = np.concatenate([[0.0], np.cumsum(x)])
        waits = c - np.minimum.accumulate(c)
        waits = _warm(waits)
        return {
            "mean_wait": float(waits.mean()),
            "mean_wait_served": float(waits.mean()),
            "loss_frac": 0.0,
            "p95_wait": float(np.percentile(waits, 95)),
            "waits": waits,
        }

    # impatience: workload recursion with admission only when V < tau
    waits = np.empty(num_requests)
    lost = np.zeros(num_requests, bool)
    v = 0.0
    for i in range(num_requests):
        v = max(0.0, v - inter[i])
        if v >= tau:
            waits[i] = tau          # lost users spend tau in queue (Eq 9)
            lost[i] = True
        else:
            waits[i] = v
            v += service[i]
    waits_w, lost_w = _warm(waits), _warm(lost)
    served = waits_w[~lost_w]
    return {
        "mean_wait": float(waits_w.mean()),
        "mean_wait_served": float(served.mean()) if served.size else 0.0,
        "loss_frac": float(lost_w.mean()),
        "p95_wait": float(np.percentile(waits_w, 95)),
        "waits": waits_w,
    }


# ----------------------------------------------------------------------------
# Generic batch-formation loop (dynamic / fixed / elastic / multi-bin / ...)
# ----------------------------------------------------------------------------

@oracle("batches")
def _oracle_batches(policy, wl: Workload, lat, dist) -> dict:
    arr, tok = wl.arrivals, wl.tokens
    # membership/ordering sees the predicted column; batch_time below sees
    # the TRUE tokens (predicted-vs-true convention, repro.core.predictors)
    fs = policy.formation(arr, tok, dist, predicted=wl.predicted)
    waits = np.empty(len(arr))
    batch_sizes = []
    t_free = 0.0
    while (nb := fs.next_batch(t_free)) is not None:
        start, idx = nb
        waits[idx] = start - arr[idx]
        h = policy.batch_time(tok[idx], lat)
        batch_sizes.append(len(idx))
        t_free = start + h
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(np.mean(batch_sizes)),
        "waits": w,
    }


# ----------------------------------------------------------------------------
# Continuous (iteration-level) batching on a virtual clock
# ----------------------------------------------------------------------------

@oracle("continuous")
def _oracle_continuous(policy, wl: Workload, lat: BatchLatencyModel,
                       dist) -> dict:
    from repro.serving.scheduler import run_continuous_virtual
    waits, _e2e, _makespan = run_continuous_virtual(
        wl.arrivals, wl.tokens.astype(np.int64), slots=policy.slots,
        chunk=policy.chunk,
        prefill_time=lambda b: float(lat.k1 * b + lat.k2),
        decode_step_time=lambda b: float(lat.k3 * b + lat.k4))
    w = _warm(waits)
    return {
        "mean_wait": float(w.mean()),
        "p95_wait": float(np.percentile(w, 95)),
        "mean_batch": float(policy.slots),
        "waits": w,
    }


# ----------------------------------------------------------------------------
# Legacy entry points (thin policy wrappers; trajectory-equal to pre-refactor)
# ----------------------------------------------------------------------------

def simulate_mg1(lam: float, dist: TokenDistribution, lat: LatencyModel,
                 n_max: Optional[int] = None, tau: Optional[float] = None,
                 num_requests: int = 200_000, seed: int = 0) -> dict:
    return simulate_policy(FCFSPolicy(n_max=n_max, tau=tau), lam, dist, lat,
                           num_requests=num_requests, seed=seed)


def simulate_dynamic_batching(lam: float, dist: TokenDistribution,
                              lat: BatchLatencyModel,
                              b_max: Optional[int] = None,
                              elastic: bool = False,
                              n_max: Optional[int] = None,
                              num_requests: int = 200_000,
                              seed: int = 0) -> dict:
    """Dynamic batching: when the server frees, take min(waiting, b_max)
    requests in one batch (all of them when b_max is None). elastic=True uses
    the Eq-26 completion time instead of padded H[b, max]."""
    cls = ElasticPolicy if elastic else DynamicPolicy
    return simulate_policy(cls(n_max=n_max, b_max=b_max), lam, dist, lat,
                           num_requests=num_requests, seed=seed)


def simulate_fixed_batching(lam: float, b: int,
                            dist: Optional[TokenDistribution],
                            lat: Optional[BatchLatencyModel] = None,
                            batch_time: Optional[Callable] = None,
                            num_requests: int = 200_000,
                            seed: int = 0) -> dict:
    """Fixed batching: the server waits until exactly b requests are present
    (paper §IV-C), then serves them together.  ``batch_time`` overrides the
    policy's service law (used by the M/D^b/1 validation tests)."""
    pol = FixedPolicy(b=b)
    if batch_time is not None:
        pol.batch_time = lambda ns, _lat: float(batch_time(ns))
    else:
        assert lat is not None
    return simulate_policy(pol, lam, dist, lat,
                           num_requests=num_requests, seed=seed)


def simulate_policy_sweep(lam_grid, dist, lat, policies: dict,
                          num_requests: int = 100_000, seed: int = 0) -> dict:
    """Mean wait for each policy over an arrival-rate grid.  ``policies``:
    name -> BatchPolicy instance or legacy dict(kind=..., **kwargs)."""
    insts = {name: (spec if isinstance(spec, BatchPolicy)
                    else policy_from_spec(spec))
             for name, spec in policies.items()}
    out = {name: [] for name in insts}
    for lam in lam_grid:
        for name, pol in insts.items():
            r = simulate_policy(pol, lam, dist, lat,
                                num_requests=num_requests, seed=seed)
            out[name].append(r["mean_wait"])
    return {k: np.asarray(v) for k, v in out.items()}
