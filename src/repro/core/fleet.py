"""Fleet layer: prediction-aware routing across parallel batched replicas.

Everything below :mod:`repro.core.policies` describes ONE server; the
heavy-traffic regime the ROADMAP targets (millions of users) is served by
R replicas behind a dispatcher.  Dai et al. 2025 analyze exactly this
multi-server WAIT setting, and AugServe (Wang et al. 2025) shows adaptive
request routing is where real serving systems win.  This module makes the
*router* a first-class registered component, mirroring the policy and
predictor registries: a :class:`RoutingPolicy` splits one Poisson(λ)
arrival stream across R replicas, and EACH replica runs any registered
:class:`~repro.core.policies.BatchPolicy` unchanged.

The architectural decision that keeps every layer simple: a router is a
function of the *arrival stream and its (predicted) per-request work* —
never of the replicas' internal service evolution.  A real dispatcher
cannot see inside a replica's batch formation anyway; it tracks what it
assigned.  The state-dependent routers therefore carry a **virtual work
backlog** per replica (a Lindley-style recursion on single-request service
estimates: decay by elapsed time, add the assigned request's estimated
work), which is computable on arrivals alone.  Consequence: routing can be
computed FIRST and each replica's sub-stream then runs through the
existing single-server machinery unchanged — ``_oracle_batches`` on the
oracle layer, the compiled kernels on the fast layer, ``PolicyScheduler``
on the serving layer.

Registered routers (``ROUTERS``; docs/fleet.md is CI-gated to mention
every one):

  * ``random``       — iid uniform replica choice.  On the sampled-workload
    layers it is realized by *exact superposition*: R independent
    Poisson(λ/R) single-server workloads merged into one stream (the
    superposition theorem: this IS a Poisson(λ) stream with iid uniform
    routing), so each replica is bit-equal to the existing single-server
    model at λ/R and **every** ``analytic_kind`` transfers for free — the
    exact M/G/R split.
  * ``round_robin``  — request i -> replica i mod R; each replica sees an
    Erlang-R arrival stream (no analytic form, delay between jsq and
    random).
  * ``power_of_d``   — hashed power-of-d choices: a salted rng draws d
    candidate replicas per request and the one with the fewest requests
    *assigned so far* wins.  State-independent in the queue sense (the
    balance counter is assignment history, not service state), so it
    lowers to split-then-kernel exactly like random/round_robin.
  * ``jsq``          — join-shortest-queue on the virtual work backlog
    with a length-BLIND work estimate (every request costs the stream's
    mean single-request service time): queue length measured in mean
    service units.
  * ``least_work``   — join-least-predicted-work: the backlog increments
    by the request's PREDICTED single-request service time, using any
    registered :class:`~repro.core.predictors.LengthPredictor` (the
    router's own ``predictor`` overrides the workload's predicted column;
    oracle semantics otherwise) — length-aware dispatch, the second
    consumer of the predictor subsystem.

Three layers, mirroring the policy core:

  1. :func:`route_oracle` — NumPy reference: split, then reuse the
     single-server oracle event loops per replica, unchanged.
  2. ``repro.core.fastsim.simulate_fleet_fast`` — same split (the backlog
     recursion is a jitted ``lax.scan`` carrying the per-replica backlog
     vector), then the per-policy compiled kernels per replica;
     :func:`sweep` runs (R, λ) grids for scaling curves.
  3. :func:`fleet_analytic_delay` — the analytic cross-check surface:
     ``random`` transfers the per-replica single-server closed form at
     λ/R with the policy's own ``analytic_kind``; ``jsq`` gets a
     Whitt-style two-moment balanced-split approximation
     (:func:`split_qna_wait`, QNA scaling of the same P-K service
     moments) for single-service policies, ``analytic_kind='approx'``;
     the pooled M/G/R Erlang-C form (:func:`mgr_whitt_wait`) is exposed
     as the resource-pooling delay floor every router is compared
     against.

``tests/test_fleet.py`` pins router-oracle ≡ fastsim trajectory equality
per (router, policy) pair, the bit-equal λ/R transfer, the routing-quality
ordering (jsq <= round_robin <= random; power-of-d in between), and that
an R=1 fleet degenerates to the existing single-server path for every
registered policy.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Type

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    BatchPolicy, FCFSPolicy, Workload, single_from_batch)

# Salt for router rng streams (random assignment, power-of-d candidates):
# independent of both the workload stream and the predictor stream.
_ROUTE_SALT = 0x5DEECE66
# Key-lane for a router-owned predictor, so its noise draw is independent
# of a policy-owned predictor keyed on the same workload seed.
_ROUTE_PRED_LANE = 7919


def _route_rng(seed) -> np.random.Generator:
    parts = [int(k) for k in seed] if isinstance(seed, (tuple, list)) \
        else [int(seed)]
    return np.random.default_rng(np.random.SeedSequence([_ROUTE_SALT] + parts))


# ----------------------------------------------------------------------------
# Fleet workload: one arrival stream, split across R replicas
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetWorkload:
    """The routed stream: per-replica single-server sub-workloads plus the
    merged global view.  ``replicas[r]`` is a plain
    :class:`~repro.core.policies.Workload`, so every single-server layer
    consumes it unchanged; ``replica_of`` maps each global request (in
    arrival order) to its replica."""

    replicas: List[Workload]
    replica_of: np.ndarray       # int replica id per global request
    arrivals: np.ndarray         # merged global arrival times (sorted)
    R: int

    @property
    def counts(self) -> np.ndarray:
        return np.bincount(self.replica_of, minlength=self.R)


def _sub_workload(wl: Workload, idx: np.ndarray) -> Workload:
    """Replica sub-stream of a global workload.  ``inter`` is re-derived
    from the sub-arrivals (gap from t=0 for the first request), which is
    what the FCFS oracle's recursions expect."""
    arr = wl.arrivals[idx]
    return Workload(
        arrivals=arr,
        tokens=wl.tokens[idx],
        inter=np.diff(arr, prepend=0.0),
        predicted=None if wl.predicted is None else wl.predicted[idx],
        session=None if wl.session is None else wl.session[idx],
        turn=None if wl.turn is None else wl.turn[idx])


def served_slice(policy: BatchPolicy, wl: Workload) -> Workload:
    """Truncate a sub-workload to what the policy actually serves (fixed
    batching serves a multiple of b; everything else serves all)."""
    n = len(wl.arrivals)
    m = policy.schedule_length(n)
    if m == n:
        return wl
    return Workload(
        arrivals=wl.arrivals[:m], tokens=wl.tokens[:m],
        inter=None if wl.inter is None else wl.inter[:m],
        predicted=None if wl.predicted is None else wl.predicted[:m],
        session=None if wl.session is None else wl.session[:m],
        turn=None if wl.turn is None else wl.turn[:m])


# ----------------------------------------------------------------------------
# Routing-policy protocol + registry
# ----------------------------------------------------------------------------

ROUTERS: Dict[str, Type["RoutingPolicy"]] = {}


def register_router(cls: Type["RoutingPolicy"]) -> Type["RoutingPolicy"]:
    ROUTERS[cls.name] = cls
    return cls


def get_router(name: str, **kwargs) -> "RoutingPolicy":
    return ROUTERS[name](**kwargs)


def router_from_spec(spec) -> "RoutingPolicy":
    """``RoutingPolicy`` | name | ``{"kind": name, **params}`` -> instance."""
    if isinstance(spec, RoutingPolicy):
        return spec
    if isinstance(spec, str):
        return get_router(spec)
    spec = dict(spec)
    return get_router(spec.pop("kind"), **spec)


def default_routers(d: int = 2) -> Dict[str, "RoutingPolicy"]:
    """One representative instance per registered router — the set the
    fleet agreement tests and the registry-driven benchmarks iterate."""
    return {
        "random": RandomRouter(),
        "round_robin": RoundRobinRouter(),
        f"power_of_{d}": PowerOfDRouter(d=d),
        "jsq": JSQRouter(),
        "least_work": LeastWorkRouter(),
        "session_affinity": SessionAffinityRouter(),
    }


class RoutingPolicy:
    """One dispatch discipline, defined once for every layer.

    Class attributes (the structural dispatch surface):
      name              registry key
      state_dependent   True -> assignment is the virtual-backlog recursion
                        (the fast layer lowers it to a jitted ``lax.scan``)

    ``predictor`` (a :class:`repro.core.predictors.LengthPredictor`,
    registry name, or spec dict) overrides the workload's predicted column
    for the router's work estimate — None uses ``Workload.predicted`` when
    the POLICY carries a predictor, and the true lengths otherwise (oracle
    semantics).  Only the work estimate is affected: membership inside
    each replica still follows the policy's own predicted column.
    """

    name = "base"
    state_dependent = False

    def __init__(self, predictor=None):
        if predictor is not None:
            from repro.core.predictors import predictor_from_spec
            predictor = predictor_from_spec(predictor)
        self.predictor = predictor

    # -------------------- work estimate --------------------
    def routing_work(self, wl: Workload, lat, seed,
                     prompts=None) -> np.ndarray:
        """Per-request work estimate in single-request service seconds:
        ``S(pred) = (k1+k2) + (k3+k4)·pred`` on the router's predicted
        lengths.  ``lat=None`` (uncalibrated serving layers) falls back to
        raw predicted tokens as the work unit.  ``prompts`` reaches a
        router-owned predictor (the serving layers pass the request
        prompts, so prompt-feature predictors actually see them; the
        sampled-workload layers have none)."""
        key = wl.predicted_or_true
        if self.predictor is not None:
            key = self.predictor.predict((seed, _ROUTE_PRED_LANE),
                                         wl.tokens, prompts)
        return self.work_from_lengths(key, lat)

    @staticmethod
    def work_from_lengths(lengths: np.ndarray, lat) -> np.ndarray:
        lengths = np.asarray(lengths, np.float64)
        if lat is None:
            return lengths
        single = lat if isinstance(lat, LatencyModel) else \
            single_from_batch(lat)
        return np.asarray(single.service_time(lengths), np.float64)

    # -------------------- assignment law --------------------
    def assign(self, arrivals: np.ndarray, work: np.ndarray, R: int,
               seed, fast: bool = False, sessions=None) -> np.ndarray:
        """Replica id per request.  Must depend only on (arrivals, work,
        R, seed) — never on downstream service state — so that routing
        can be computed before any replica is simulated.  ``sessions``
        is the workload's session-id column (None on session-free
        streams): sticky routers key on it, everything else ignores it."""
        raise NotImplementedError

    # -------------------- fleet workload --------------------
    def fleet_workload(self, policy: BatchPolicy, lam: float,
                       dist: Optional[TokenDistribution], lat,
                       num_requests: int, seed: int, R: int,
                       fast: bool = False, traffic=None) -> FleetWorkload:
        """Sample the global stream through the policy's workload law and
        split it.  R=1 passes the policy's native workload through
        untouched, so a one-replica fleet is bit-equal to the
        single-server path for every router.

        ``traffic`` (a :mod:`repro.core.traffic` model, name or spec)
        warps the sampled arrivals through the modulation's
        time-rescaling transform BEFORE routing — every router sees the
        same modulated instants; a null model leaves the stream
        bit-identical."""
        wl = policy.sample_workload(lam, dist, num_requests, seed)
        if traffic is not None:
            from repro.core.traffic import warp_workload
            wl = warp_workload(wl, traffic, seed)
        if R == 1:
            return FleetWorkload([wl], np.zeros(len(wl.arrivals), np.int64),
                                 wl.arrivals, 1)
        work = self.routing_work(wl, lat, seed)
        rep = np.asarray(self.assign(wl.arrivals, work, R, seed, fast=fast,
                                     sessions=wl.session),
                         np.int64)
        subs = [_sub_workload(wl, np.nonzero(rep == r)[0]) for r in range(R)]
        return FleetWorkload(subs, rep, wl.arrivals, R)

    def __repr__(self):
        keys = {k: v for k, v in vars(self).items() if v is not None}
        return f"{type(self).__name__}({keys})"


def _backlog_assign_np(arrivals: np.ndarray, work: np.ndarray,
                       R: int) -> np.ndarray:
    """Reference virtual-backlog recursion: decay every replica's backlog
    by the elapsed time, join the least-loaded (first index on ties), add
    the request's work."""
    v = np.zeros(R)
    t_prev = 0.0
    out = np.empty(len(arrivals), np.int64)
    for i, (a, w) in enumerate(zip(arrivals, work)):
        v = np.maximum(0.0, v - (a - t_prev))
        t_prev = a
        r = int(np.argmin(v))
        v[r] += w
        out[i] = r
    return out


def _masked_backlog_assign_np(arrivals: np.ndarray, work: np.ndarray,
                              R: int, up: np.ndarray) -> np.ndarray:
    """Availability-masked reference backlog recursion
    (:mod:`repro.core.faults`): a replica that is down at an arrival
    instant (``up[i, r]`` False) has its virtual backlog masked to +inf
    in the argmin, so it receives no work until it recovers.  With every
    replica up this is bit-equal to :func:`_backlog_assign_np`; the
    jitted twin is ``fastsim.masked_backlog_route``."""
    v = np.zeros(R)
    t_prev = 0.0
    out = np.empty(len(arrivals), np.int64)
    for i, (a, w) in enumerate(zip(arrivals, work)):
        v = np.maximum(0.0, v - (a - t_prev))
        t_prev = a
        r = int(np.argmin(np.where(up[i], v, np.inf)))
        v[r] += w
        out[i] = r
    return out


class _BacklogRouter(RoutingPolicy):
    """Shared base for the state-dependent routers (jsq / least_work)."""

    state_dependent = True

    def _work_units(self, work: np.ndarray) -> np.ndarray:
        return work

    def assign(self, arrivals, work, R, seed, fast: bool = False,
               sessions=None):
        w = self._work_units(np.asarray(work, np.float64))
        if fast:
            from repro.core.fastsim import backlog_route
            return backlog_route(arrivals, w, R)
        return _backlog_assign_np(np.asarray(arrivals, np.float64), w, R)


@register_router
class RandomRouter(RoutingPolicy):
    """iid uniform replica choice.  On the sampled-workload layers the
    fleet workload is built by exact superposition (R independent λ/R
    single-server streams merged), so each replica IS the single-server
    model at λ/R — bit-equal, with the full analytic transfer.  On the
    request-list serving layers, where the stream is given, ``assign``
    draws from the salted router rng (the same law)."""

    name = "random"

    def assign(self, arrivals, work, R, seed, fast: bool = False,
               sessions=None):
        return _route_rng(seed).integers(0, R, len(arrivals))

    def fleet_workload(self, policy, lam, dist, lat, num_requests, seed, R,
                       fast: bool = False, traffic=None) -> FleetWorkload:
        if R == 1:
            return super().fleet_workload(policy, lam, dist, lat,
                                          num_requests, seed, R, fast,
                                          traffic=traffic)
        n_per = max(num_requests // R, 1)
        subs = [policy.sample_workload(lam / R, dist, n_per, (seed, r))
                for r in range(R)]
        if traffic is not None:
            # superposition transfers to modulated arrivals: each λ/R
            # sub-stream is warped through the SAME profile (base seed,
            # one shared environment), so the merge is the inhomogeneous
            # Poisson(λ·m(t)) process with iid uniform replica marks
            from repro.core.traffic import warp_workload
            subs = [warp_workload(wl, traffic, seed) for wl in subs]
        arr = np.concatenate([wl.arrivals for wl in subs])
        rep = np.concatenate([np.full(len(wl.arrivals), r, np.int64)
                              for r, wl in enumerate(subs)])
        order = np.argsort(arr, kind="stable")
        return FleetWorkload(subs, rep[order], arr[order], R)


@register_router
class RoundRobinRouter(RoutingPolicy):
    """Request i -> replica i mod R: perfectly balanced counts, blind to
    burstiness and lengths; each replica sees Erlang-R interarrivals."""

    name = "round_robin"

    def assign(self, arrivals, work, R, seed, fast: bool = False,
               sessions=None):
        return np.arange(len(arrivals), dtype=np.int64) % R


@register_router
class PowerOfDRouter(RoutingPolicy):
    """Hashed power-of-d choices: the salted rng draws ``d`` candidate
    replicas per request; the candidate with the fewest requests assigned
    so far wins (first on ties).  The balance counter is assignment
    history — computable without simulating service — so the router stays
    state-independent in the queue sense and splits-then-vmaps like
    random/round_robin, while interpolating between them and jsq in
    balance quality (Mitzenmacher's power of two choices)."""

    name = "power_of_d"

    def __init__(self, d: int = 2, predictor=None):
        super().__init__(predictor)
        assert d >= 1
        self.d = int(d)

    def assign(self, arrivals, work, R, seed, fast: bool = False,
               sessions=None):
        cands = _route_rng(seed).integers(0, R, (len(arrivals), self.d))
        counts = np.zeros(R, np.int64)
        out = np.empty(len(arrivals), np.int64)
        for i in range(len(arrivals)):
            c = cands[i]
            r = int(c[np.argmin(counts[c])])
            counts[r] += 1
            out[i] = r
        return out


@register_router
class JSQRouter(_BacklogRouter):
    """Join-shortest-queue on the virtual work backlog, with a
    length-BLIND work estimate: every request costs the stream's mean
    single-request service time, so the backlog is queue length measured
    in mean service units.  Not length-aware (that is ``least_work``),
    and with CONSTANT increments the argmin cycles replicas in strict
    rotation while no backlog drains to the max(0, ·) clamp — at
    utilizations where interarrival gaps stay below the mean service
    time, jsq's assignments coincide with round_robin's exactly (the
    committed ``pr5_fleet`` router comparison shows identical numbers
    for the two at the heavy-tail operating point).  It departs from
    round robin only when idle gaps drain a replica, i.e. at low load or
    under bursty lulls — the regime where joining the truly-emptiest
    replica matters."""

    name = "jsq"

    def _work_units(self, work):
        return np.full(len(work), float(np.mean(work)) if len(work) else 0.0)


@register_router
class LeastWorkRouter(_BacklogRouter):
    """Join-least-predicted-work: the backlog increments by the request's
    PREDICTED single-request service time — length-aware dispatch driven
    by any registered :mod:`repro.core.predictors` instance (``predictor``
    on the router; the workload's predicted column otherwise).  The
    prediction-aware twin of jsq: with an oracle predictor it is the
    classic least-workload rule; predictor noise erodes it exactly the way
    ``benchmarks/bench_fleet.py`` measures."""

    name = "least_work"


def _seed_fold(seed) -> int:
    """Fold a scalar or tuple seed into one 64-bit salt word."""
    parts = [int(k) for k in seed] if isinstance(seed, (tuple, list)) \
        else [int(seed)]
    acc = np.uint64(0x9E3779B97F4A7C15)
    with np.errstate(over="ignore"):
        for p in parts:
            acc = (acc ^ np.uint64(p & 0xFFFFFFFFFFFFFFFF)) \
                * np.uint64(0xBF58476D1CE4E5B9)
    return int(acc)


def _affinity_hash(keys: np.ndarray, seed) -> np.ndarray:
    """splitmix64-style avalanche of per-request sticky keys (vectorized,
    deterministic, layer-independent — no rng stream is consumed)."""
    z = keys.astype(np.uint64)
    with np.errstate(over="ignore"):
        z = z + np.uint64(_seed_fold(seed))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    return z


@register_router
class SessionAffinityRouter(RoutingPolicy):
    """Sticky hashing: replica = hash(session id) mod R, so every turn of
    a session lands on the same replica and its KV/prefix cache — the
    affinity side of the affinity-vs-``least_work`` trade-off
    (prefix reuse shrinks service; blind stickiness forgoes load
    balancing).  On session-free streams (``sessions=None``) each
    request is its own session — the hash of the request index, an iid
    uniform split in law.  Assignment depends only on (session id, seed):
    deterministic, identical on the oracle and fast layers, and STABLE
    across the feedback fixed point's re-sorted passes (arrival times
    never enter the hash).  Dead replicas fall back through the PR 6
    masking: :meth:`masked_assign` probes ``hash + k`` until an
    up replica is found, so only turns whose home replica is down move."""

    name = "session_affinity"

    def assign(self, arrivals, work, R, seed, fast: bool = False,
               sessions=None):
        keys = np.arange(len(arrivals), dtype=np.uint64) \
            if sessions is None else np.asarray(sessions, np.uint64)
        return (_affinity_hash(keys, seed) % np.uint64(R)).astype(np.int64)

    def masked_assign(self, arrivals, work, R, seed, up, fast: bool = False,
                      sessions=None):
        """Availability-masked stickiness (hook consumed by
        :func:`repro.core.faults.masked_assign`): linear probing from the
        home replica, so sessions keep their home whenever it is up and
        deterministically overflow to ``home + k`` while it is down."""
        rep = np.asarray(self.assign(arrivals, work, R, seed, fast=fast,
                                     sessions=sessions), np.int64)
        up = np.asarray(up, bool)
        offs = np.zeros(len(rep), np.int64)
        rows = np.arange(len(rep))
        for _ in range(R):
            cur = (rep + offs) % R
            bad = ~up[rows, cur]
            if not bad.any():
                break
            offs[bad] += 1
        return (rep + offs) % R


# ----------------------------------------------------------------------------
# Layer 1: the NumPy reference oracle (reuses the single-server event loops)
# ----------------------------------------------------------------------------

def _aggregate(per: List[Optional[dict]], fw: FleetWorkload) -> dict:
    """Fleet-level stats from per-replica single-server results.  Each
    replica's result is already warmup-trimmed by its own oracle/kernel;
    the aggregate concatenates the trimmed waits (request-weighted)."""
    live = [p for p in per if p is not None]
    waits = np.concatenate([p["waits"] for p in live]) if live else \
        np.zeros(0)
    out = {
        "mean_wait": float(waits.mean()) if waits.size else 0.0,
        "p50_wait": float(np.percentile(waits, 50)) if waits.size else 0.0,
        "p95_wait": float(np.percentile(waits, 95)) if waits.size else 0.0,
        "p99_wait": float(np.percentile(waits, 99)) if waits.size else 0.0,
        "per_replica": per,
        "replica_counts": fw.counts,
        "replica_of": fw.replica_of,
    }
    if live and all("mean_batch" in p for p in live):
        # total requests / total batches across the fleet
        nb = sum(len(p["waits"]) / max(p["mean_batch"], 1e-12) for p in live)
        out["mean_batch"] = float(waits.size / max(nb, 1e-12))
    if live and all("memory" in p for p in live):
        ms = [p["memory"] for p in live]
        ws = np.array([max(len(p["waits"]), 1) for p in live], np.float64)
        out["memory"] = {
            "capacity": ms[0]["capacity"],           # per-replica budget
            "kv_peak": max(m["kv_peak"] for m in ms),
            "kv_mean": float(np.average([m["kv_mean"] for m in ms],
                                        weights=ws)),
            "utilization": max(m["utilization"] for m in ms),
            "allocated": float(sum(m["allocated"] for m in ms)),
            "freed": float(sum(m["freed"] for m in ms)),
            "blocked_batches": int(sum(m["blocked_batches"] for m in ms)),
            "blocked_time": float(sum(m["blocked_time"] for m in ms)),
            "deferred_requests": int(sum(m["deferred_requests"]
                                         for m in ms)),
        }
    return out


def run_fleet(fw: FleetWorkload, policy: BatchPolicy, lat,
              dist: Optional[TokenDistribution],
              runner: Callable[[BatchPolicy, Workload], dict]) -> dict:
    """Drive every replica's sub-workload through ``runner`` (the oracle
    or the fast twin) and aggregate.  Empty replicas contribute None."""
    per = []
    for wl in fw.replicas:
        wl = served_slice(policy, wl)
        per.append(runner(policy, wl) if len(wl.arrivals) else None)
    return _aggregate(per, fw)


def route_oracle(router, policy: BatchPolicy, lam: float, R: int,
                 dist: Optional[TokenDistribution], lat,
                 num_requests: int = 100_000, seed: int = 0,
                 traffic=None, sessions=None,
                 prefix_discount: float = 0.0, memory=None) -> dict:
    """Fleet reference oracle: route, then reuse the single-server
    reference event loops (``repro.core.simulate``) per replica,
    unchanged.  ``router``: a RoutingPolicy, registry name, or spec.
    ``traffic`` modulates the arrival stream before routing.
    ``sessions`` / ``prefix_discount`` re-enter completed turns through
    the fleet feedback fixed point
    (:func:`repro.core.sessions.simulate_fleet_sessions`); a null model
    takes this exact code path (bit-equality by construction).
    ``memory`` gives EACH replica its own KV budget (per-replica HBM)
    through the unchanged single-server tandem oracle."""
    from repro.core.simulate import simulate_policy
    router = router_from_spec(router)
    if sessions is not None:
        from repro.core.sessions import (session_from_spec,
                                         simulate_fleet_sessions)
        model = session_from_spec(sessions)
        if not model.is_null:
            return simulate_fleet_sessions(
                router, policy, lam, R, dist, lat, num_requests, seed,
                model, prefix_discount=prefix_discount, traffic=traffic,
                fast=False)
    fw = router.fleet_workload(policy, lam, dist, lat, num_requests, seed, R,
                               traffic=traffic)
    return run_fleet(fw, policy, lat, dist,
                     lambda pol, wl: simulate_policy(
                         pol, lam, dist, lat, workload=wl, memory=memory))


# ----------------------------------------------------------------------------
# Layer 2 entry point (compiled kernels live in repro.core.fastsim)
# ----------------------------------------------------------------------------

def sweep(R_grid, lam_grid, router, policy: BatchPolicy,
          dist: Optional[TokenDistribution], lat,
          num_requests: int = 50_000, seed: int = 0) -> dict:
    """Scaling curves on the fast path: mean wait over the (R, λ) grid —
    λ is the TOTAL fleet arrival rate, so reading along R at fixed λ is
    the 'how many replicas do I need' question.  Returns
    ``{"mean_wait": [len(R_grid), len(lam_grid)], "R_grid", "lams"}``."""
    from repro.core.fastsim import simulate_fleet_fast
    router = router_from_spec(router)
    R_grid = [int(r) for r in R_grid]
    lam_grid = [float(l) for l in lam_grid]
    out = np.empty((len(R_grid), len(lam_grid)))
    for ri, R in enumerate(R_grid):
        for li, lam in enumerate(lam_grid):
            out[ri, li] = simulate_fleet_fast(
                router, policy, lam, R, dist, lat,
                num_requests=num_requests, seed=seed)["mean_wait"]
    return {"mean_wait": out, "R_grid": np.asarray(R_grid),
            "lams": np.asarray(lam_grid)}


# ----------------------------------------------------------------------------
# Layer 3: analytic cross-checks
# ----------------------------------------------------------------------------

def erlang_c(R: int, a: float) -> float:
    """Erlang-C delay probability for M/M/R at offered load a = λ·E[S]
    (stable only for a < R), via the numerically-stable Erlang-B
    recursion B(j) = a·B(j-1) / (j + a·B(j-1))."""
    if a >= R:
        return 1.0
    b = 1.0
    for j in range(1, R + 1):
        b = a * b / (j + a * b)
    rho = a / R
    return b / (1.0 - rho + rho * b)


def mgr_whitt_wait(lam: float, R: int, es: float, es2: float) -> float:
    """Two-moment *pooled* M/G/R mean-wait approximation (Whitt 1993):

        E[W] ≈ (1 + C_s²)/2 · E[W_{M/M/R}]
             = (1 + C_s²)/2 · C(R, a) · E[S] / (R − a)

    with a = λ·E[S] and C_s² = Var[S]/E[S]² from the SAME service moments
    the single-server P-K terms use (``LatencyModel.moments``).  The
    pooled single-queue system dominates every dispatch rule (resource
    pooling), so this is the fleet's delay *floor* — the reference line
    ``benchmarks/bench_fleet.py`` plots under the router comparison."""
    a = lam * es
    if a >= R:
        return np.inf
    cs2 = max(es2 - es ** 2, 0.0) / max(es ** 2, 1e-300)
    return 0.5 * (1.0 + cs2) * erlang_c(R, a) * es / (R - a)


def split_qna_wait(lam: float, R: int, es: float, es2: float) -> float:
    """Two-moment mean-wait approximation for a *balanced split* of a
    Poisson(λ) stream across R single servers — Whitt's QNA scaling of
    the P-K terms:

        E[W] ≈ (C_a² + C_s²)/2 · ρ/(1−ρ) · E[S],   ρ = (λ/R)·E[S]

    with arrival SCV C_a² = 1/R: a deterministic 1-in-R count split of a
    Poisson stream gives each replica exactly Erlang-R interarrivals
    (that part is exact for ``round_robin``; the G/G/1 two-moment formula
    is the approximation).  The backlog ``jsq`` router balances
    assignment counts the same way at steady state, so the same formula
    serves as its two-moment handle."""
    rho = (lam / R) * es
    if rho >= 1.0:
        return np.inf
    ca2 = 1.0 / R
    cs2 = max(es2 - es ** 2, 0.0) / max(es ** 2, 1e-300)
    return 0.5 * (ca2 + cs2) * rho / (1.0 - rho) * es


def fleet_analytic_kind(router, policy: BatchPolicy) -> Optional[str]:
    """How literally to read :func:`fleet_analytic_delay`:

      * ``random`` — exact superposition split: each replica is the
        single-server model at λ/R, so the POLICY's own ``analytic_kind``
        transfers verbatim ('exact' stays exact, 'bound' stays a bound).
      * ``jsq`` — 'approx' for single-service (FCFS-family) policies via
        the two-moment balanced-split formula (:func:`split_qna_wait`):
        the backlog rule balances assignment counts, so each replica sees
        ≈ Erlang-R interarrivals at λ/R; the G/G/1 two-moment scaling is
        the approximation (within ~10% at the cross-checked loads).
      * everything else — None (no closed form; round_robin's exactly-
        Erlang arrivals sit in the regime where the two-moment formula
        degrades, power_of_d feeds back assignment history, least_work
        couples backlogs to lengths, and batched policies couple the
        split to batch formation)."""
    router = router_from_spec(router)
    if router.name == "random":
        return policy.analytic_kind
    if router.name == "jsq" and isinstance(policy, FCFSPolicy) \
            and policy.tau is None:
        return "approx"
    return None


def fleet_analytic_delay(router, policy: BatchPolicy, lam: float, R: int,
                         dist: TokenDistribution, lat) -> Optional[float]:
    """Mean queueing delay of the fleet from the transferred single-server
    closed forms; None when :func:`fleet_analytic_kind` is None."""
    router = router_from_spec(router)
    kind = fleet_analytic_kind(router, policy)
    if kind is None:
        return None
    if router.name == "random":
        return policy.analytic_delay(lam / R, dist, lat)
    # jsq + single-service policy: QNA balanced split on the P-K moments
    single = lat if isinstance(lat, LatencyModel) else single_from_batch(lat)
    es, es2 = single.moments(dist, policy.n_max)
    return split_qna_wait(lam, R, es, es2)


def recommend_replicas(lam: float, dist: TokenDistribution,
                       lat: BatchLatencyModel, target_util: float = 0.7,
                       max_replicas: int = 64) -> int:
    """Smallest replica count keeping the per-replica batched utilization
    under ``target_util``.  The per-request marginal work at large batch
    is the elastic envelope slope α = k1 + k3·E[N] (paper Eq 26): one
    replica's capacity is 1/α requests per second, so
    R = ceil(λ·α / target_util)."""
    alpha = lat.k1 + lat.k3 * dist.mean()
    return int(min(max(1, math.ceil(lam * alpha / target_util)),
                   max_replicas))


__all__ = [
    "FleetWorkload", "JSQRouter", "LeastWorkRouter", "PowerOfDRouter",
    "ROUTERS", "RandomRouter", "RoundRobinRouter", "RoutingPolicy",
    "SessionAffinityRouter",
    "default_routers", "erlang_c", "fleet_analytic_delay",
    "fleet_analytic_kind", "get_router", "mgr_whitt_wait",
    "recommend_replicas", "register_router", "route_oracle",
    "router_from_spec", "run_fleet", "served_slice", "split_qna_wait",
    "sweep",
]
