"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax blocked attention with causal + sliding-window masking and
GQA via index-map head folding (KV stays at kv_heads in HBM; no expansion).

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks) — kv innermost so the
(m, l, acc) state lives in VMEM scratch across the kv sweep. Causally
fully-masked kv blocks are SKIPPED via @pl.when (this is the 2x FLOP saving
the pure-JAX scan path cannot express; DESIGN.md §7).

Block shapes are (block_q, head_dim) / (block_kv, head_dim): head_dim is the
lane dim (128-multiple for every assigned arch: 64/128/256), block_q/block_kv
default 128/256 — q block + 2 kv blocks + accumulators comfortably fit VMEM
(e.g. 128x128 + 2*256x128 f32 tiles ~ 0.4 MiB << 16 MiB/core, leaving room
for double buffering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                 scale, block_q, block_kv, num_kv_blocks, causal, window,
                 kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = qi * block_q
    k_start = ki * block_kv

    # skip blocks that are fully masked (strictly above the diagonal, or
    # strictly left of the sliding window)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_kv - 1 >
                              q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bkv, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bkv]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]                        # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv_blocks - 1)
    def _fin():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal: bool = True, window=None,
                       block_q: int = 128, block_kv: int = 256,
                       interpret: bool = True):
    """q: [B*Hq, S, D]; k/v: [B*Hkv, S, D] (same B ordering, Hq % Hkv == 0).

    Returns [B*Hq, S, D]."""
    bh, s, d = q.shape
    bhk = k.shape[0]
    group = bh // bhk
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0
    nq, nkv = s // block_q, s // block_kv
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        num_kv_blocks=nkv, causal=causal, window=window, kv_len=s)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
