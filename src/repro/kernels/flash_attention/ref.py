"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_reference(q, k, v, *, causal: bool = True, window=None):
    """q: [B,S,Hq,D]; k/v: [B,S,Hkv,D] -> [B,S,Hq,D] (fp32 softmax)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
