"""jit'd public wrapper: [B,S,H,D] layout <-> kernel's [B*H,S,D] layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_bh


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_kv: int = 256,
                    interpret: Optional[bool] = None):
    """q: [B,S,Hq,D]; k/v: [B,S,Hkv,D] -> [B,S,Hq,D].

    TPU target; ``interpret=None`` resolves via
    ``kernels.default_interpret`` — compiled on TPU, interpreted (the
    kernel body as pure JAX) on CPU validation runs."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    out = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_kv=block_kv,
                             interpret=resolve_interpret(interpret))
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
