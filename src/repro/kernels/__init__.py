"""Shared kernel-package helpers.

Every Pallas kernel wrapper in this package takes ``interpret=None`` and
resolves it through :func:`default_interpret`, so the decision "compile on
TPU, interpret everywhere else" lives in exactly one place.  Callers that
need to force a mode (tests pinning interpret semantics, a TPU host
debugging a kernel) pass an explicit bool.
"""

from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreted (pure
    JAX emulation of the kernel body) on every other backend.  The single
    source of truth consumed by all kernel ``ops.py`` wrappers and the
    model layers — TPU runs must never silently interpret."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> :func:`default_interpret`; an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)
