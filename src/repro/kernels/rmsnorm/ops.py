"""jit'd wrapper: [..., D] layout flattened to rows."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import resolve_interpret
from repro.kernels.rmsnorm.kernel import fused_rmsnorm_2d


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(x, residual, weight, *, eps: float = 1e-6,
                  block_rows: int = 256,
                  interpret: Optional[bool] = None):
    shape = x.shape
    d = shape[-1]
    t = 1
    for s in shape[:-1]:
        t *= s
    block = block_rows
    while t % block:
        block //= 2
    res, normed = fused_rmsnorm_2d(
        x.reshape(t, d), residual.reshape(t, d), weight,
        eps=eps, block_rows=max(block, 1),
        interpret=resolve_interpret(interpret))
    return res.reshape(shape), normed.reshape(shape)
