"""Pure-jnp oracle for fused residual + RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_reference(x, residual, weight, eps: float = 1e-6):
    s = x.astype(jnp.float32) + residual.astype(jnp.float32)
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    n = s * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return s.astype(x.dtype), n.astype(x.dtype)
