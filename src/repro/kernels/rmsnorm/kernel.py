"""Fused residual-add + RMSNorm Pallas kernel (memory-bound hot spot: runs
2x per layer; fusing the residual add saves one full HBM round-trip).

Row-block tiling: [block_rows, d_model] tiles in VMEM, fp32 accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, res_ref, w_ref, y_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    s = x + r
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    n = s * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    y_ref[...] = s.astype(y_ref.dtype)           # carried residual stream
    o_ref[...] = n.astype(o_ref.dtype)           # normed branch input


def fused_rmsnorm_2d(x, residual, weight, *, eps: float = 1e-6,
                     block_rows: int = 256, interpret: bool = True):
    """x, residual: [T, D]; weight: [D] (stored as w-1, gemma convention).

    Returns (residual_out = x+residual, normed)."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    grid = (t // block_rows,)
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t, d), x.dtype),
        ],
        interpret=interpret,
    )(x, residual, weight)
