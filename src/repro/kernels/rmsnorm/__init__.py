from repro.kernels.rmsnorm.ops import fused_rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_reference

__all__ = ["fused_rmsnorm", "rmsnorm_reference"]
