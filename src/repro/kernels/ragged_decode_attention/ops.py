"""jit'd public wrapper for ragged decode attention."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import resolve_interpret
from repro.kernels.ragged_decode_attention.kernel import (
    ragged_decode_attention_kernel)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def ragged_decode_attention(q, k_cache, v_cache, lengths, *,
                            block_kv: int = 256,
                            interpret: Optional[bool] = None):
    """q: [B,Hq,D] one new token per request; caches [B,S,Hkv,D];
    lengths [B] valid KV entries per request. Returns [B,Hq,D].

    Per-request early exit over KV blocks = elastic batching at the kernel
    level (no padding compute for short requests).  ``interpret=None``
    resolves via ``kernels.default_interpret`` (compiled on TPU,
    interpreted elsewhere)."""
    return ragged_decode_attention_kernel(
        q, k_cache, v_cache, lengths.astype("int32"),
        block_kv=block_kv, interpret=resolve_interpret(interpret))
