"""Pure-jnp oracle for ragged decode attention."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def decode_attention_reference(q, k_cache, v_cache, lengths):
    """q: [B,Hq,D]; caches: [B,S,Hkv,D]; lengths: [B] -> [B,Hq,D]."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    k = jnp.repeat(k_cache, g, axis=2) if g > 1 else k_cache
    v = jnp.repeat(v_cache, g, axis=2) if g > 1 else v_cache
    scores = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
