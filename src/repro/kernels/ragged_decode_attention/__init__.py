from repro.kernels.ragged_decode_attention.ops import ragged_decode_attention
from repro.kernels.ragged_decode_attention.ref import decode_attention_reference

__all__ = ["ragged_decode_attention", "decode_attention_reference"]
