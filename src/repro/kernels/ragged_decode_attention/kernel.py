"""Ragged decode attention Pallas TPU kernel.

The kernel-level realization of the paper's elastic-batching insight: in a
decode batch each request has its own KV length; padded attention pays for
the longest. This kernel streams each request's KV cache in VMEM blocks and
STOPS at that request's length (``@pl.when(block_start < length)``), so a
short request costs only its own tokens — no padding compute, mirroring
Eq (26)'s per-request early exit.

Layout: q [B, Hq, D] (one new token per request), caches [B, S, Hkv, D],
lengths [B] via scalar prefetch (drives the skip predicate before the DMA
is issued). Grid: (B, Hkv, num_kv_blocks), kv innermost; flash-decoding
online softmax in VMEM scratch; GQA handled by processing a whole q-head
group (G = Hq/Hkv rows) per kv head — the [G, D] q tile rides VMEM easily.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, block_kv, num_kv_blocks):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = lengths_ref[b]
    k_start = ki * block_kv

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [bkv, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [G, bkv]
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv_blocks - 1)
    def _fin():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def ragged_decode_attention_kernel(q, k_cache, v_cache, lengths, *,
                                   block_kv: int = 256,
                                   interpret: bool = True):
    """q: [B, Hq, D]; caches: [B, S, Hkv, D]; lengths: [B] int32.

    Returns [B, Hq, D]."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    block_kv = min(block_kv, s)
    assert s % block_kv == 0
    nkv = s // block_kv
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_kernel, scale=scale, block_kv=block_kv,
                               num_kv_blocks=nkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b, h, j, lens: (b, j, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b, h, j, lens: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
