"""jit'd public wrappers: fused elastic-bucket compaction.

``fused_compact`` is the device-resident twin of ``Engine.compact``: ONE
jitted call that (1) derives the keep indices on device from the per-slot
``produced``/``targets`` counters (``nonzero(size=nb, fill_value=0)``
matches the host's zero-padded keep array bit for bit), then (2) gathers
every cache leaf plus the ``kv_lens``/token/per-slot-PRNG-key vectors
through the scalar-prefetch Pallas gather kernel.  Nothing crosses the
host boundary, so compaction adds zero ``host_syncs``.

Every gathered array funnels through the SAME kernel: cache leaves as
[G, B, F] row blocks, the per-slot vectors reshaped to [1, B, F] rows.
F is lane-padded to a multiple of 128 (TPU tiling) and sliced back — the
pad columns never reach the output, so results stay bit-equal to
``leaf[:, idx]``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.compaction.kernel import gather_rows_kernel

_LANE = 128        # TPU lane tile; pad the flattened row dim to a multiple


def _gather3(src, idx, interpret: bool):
    """[G, B, F] gather at rows ``idx`` via the Pallas kernel, handling
    lane padding for arbitrary F."""
    g, b, f = src.shape
    fp = max(-(-f // _LANE) * _LANE, _LANE)
    if fp != f:
        src = jnp.pad(src, ((0, 0), (0, 0), (0, fp - f)))
    block_f = 512 if fp % 512 == 0 else _LANE
    out = gather_rows_kernel(src, idx, block_f=block_f, interpret=interpret)
    return out[..., :f] if fp != f else out


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(src, idx, *, interpret: Optional[bool] = None):
    """Public row gather: src [G, B, ...] -> [G, NB, ...] at batch rows
    ``idx`` [NB]; bit-equal to ``src[:, idx]``."""
    g, b = src.shape[:2]
    flat = src.reshape(g, b, -1)
    out = _gather3(flat, idx.astype(jnp.int32),
                   resolve_interpret(interpret))
    return out.reshape((g, idx.shape[0]) + src.shape[2:])


@functools.partial(jax.jit, static_argnames=("nb", "interpret"))
def fused_compact(cache, kv_lens, tokens, slot_keys, produced, targets, *,
                  nb: int, interpret: Optional[bool] = None):
    """Compact the live slots of a decode bucket into bucket size ``nb``.

    ``produced``/``targets`` are the per-slot counters the fused decode
    chunk already keeps on device; a slot is live iff it still owes tokens
    (``produced < targets`` — padding slots carry 0/0 and finished slots
    fail the test, exactly the host's ``still`` selection).  Returns
    ``(cache, kv_lens, tokens, slot_keys, keep)`` with every array
    gathered at the first ``nb`` live slots in slot order, zero-filled
    past the live count — bit-equal to ``Engine.compact``.  ``slot_keys``
    may be None (greedy decoding has no sampling streams to carry)."""
    interp = resolve_interpret(interpret)
    live = (targets - produced) > 0
    keep = jnp.nonzero(live, size=nb, fill_value=0)[0].astype(jnp.int32)

    def gather_leaf(leaf):
        if leaf.ndim < 2:
            return leaf
        g, b = leaf.shape[0], leaf.shape[1]
        flat = leaf.reshape(g, b, -1)
        return _gather3(flat, keep, interp).reshape(
            (g, nb) + leaf.shape[2:])

    cache = jax.tree.map(gather_leaf, cache)
    kv_lens = _gather3(kv_lens.reshape(1, -1, 1), keep,
                       interp).reshape(nb)
    tokens = _gather3(tokens.reshape(1, -1, 1), keep, interp).reshape(nb)
    if slot_keys is not None:
        slot_keys = _gather3(slot_keys.reshape(1, -1, 2), keep,
                             interp).reshape(nb, 2)
    return cache, kv_lens, tokens, slot_keys, keep
