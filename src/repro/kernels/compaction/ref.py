"""Reference semantics for fused compaction: the host-driven gathers of
``Engine.compact`` as plain jnp indexing.  The equality tests pin
``ops.fused_compact`` bitwise against this (and against ``Engine.compact``
itself), including the gathered per-slot PRNG keys that carry PR 4's
sampling-invariance guarantee."""

from __future__ import annotations

import jax


def compact_reference(cache, kv_lens, tokens, gidx, slot_keys=None):
    """Gather batch axis 1 of every cache leaf (and axis 0 of the per-slot
    vectors) at the padded keep indices ``gidx`` [NB]."""
    cache = jax.tree.map(
        lambda leaf: leaf[:, gidx] if leaf.ndim >= 2 else leaf, cache)
    keys = None if slot_keys is None else slot_keys[gidx]
    return cache, kv_lens[gidx], tokens[gidx], keys
