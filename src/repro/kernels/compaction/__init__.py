"""Fused elastic-bucket compaction (Pallas gather kernel).

``ops.fused_compact`` gathers the live slots of every KV-cache leaf plus
the ``kv_lens`` / token / per-slot-PRNG-key vectors into a smaller batch
bucket in ONE jitted call, with the keep indices derived on device — the
Pallas twin of the host-visible gather loop in ``Engine.compact``.
"""

from repro.kernels.compaction.ops import fused_compact, gather_rows  # noqa: F401
from repro.kernels.compaction.ref import compact_reference  # noqa: F401
