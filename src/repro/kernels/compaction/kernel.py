"""Pallas TPU gather kernel for elastic bucket compaction.

Elastic batching's payoff on TPU is moving the surviving requests into a
smaller static bucket; the move itself is a batch-axis gather of every
KV-cache leaf.  Here the gather IS the DMA: the keep indices ride scalar
prefetch (like ``lengths`` in the ragged decode kernel), the input
BlockSpec's index map reads ``idx[i]`` to pick the source row, and the
kernel body is a straight VMEM copy — no host-visible indexing, no
per-leaf eager dispatch.

Layout: src [G, B, F] (leading layer-group stack, batch second — the
cache-leaf layout from ``models.model.cache_specs`` with trailing dims
flattened), idx [NB] int32, out [G, NB, F].  Grid (G, NB, F/block_f).
Rows may repeat in ``idx`` (the engine pads short keep sets with slot 0),
which a gather handles for free.
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, o_ref):
    # the index map already resolved idx[i] -> source row; just copy.
    o_ref[...] = src_ref[...]


def gather_rows_kernel(src, idx, *, block_f: int, interpret: bool = True):
    """src: [G, B, F] with F % block_f == 0; idx: [NB] int32 source rows.

    Returns [G, NB, F] with out[g, i] = src[g, idx[i]] (bit-identical to
    ``src[:, idx]``)."""
    g, b, f = src.shape
    nb = idx.shape[0]
    assert f % block_f == 0, (f, block_f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, nb, f // block_f),
        in_specs=[
            pl.BlockSpec((1, 1, block_f),
                         lambda gi, i, j, idx: (gi, idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_f),
                               lambda gi, i, j, idx: (gi, i, j)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, nb, f), src.dtype),
        interpret=interpret,
    )(idx, src)
