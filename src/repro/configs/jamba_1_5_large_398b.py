"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba+attention 1:7 interleave
[arXiv:2403.19887].

Stack: 9 groups x (1 attn + 7 mamba) = 72 layers; MoE on every other layer
(4 MoE + 4 dense FFN per group — DESIGN.md §10 deviation, matches the
published ~398B total / ~94B active within ~2%). FSDP + bf16 optimizer
moments keep per-chip state within v5e budgets.
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        group_pattern=(
            ("attn", "moe"), ("mamba", "dense"),
            ("mamba", "moe"), ("mamba", "dense"),
            ("mamba", "moe"), ("mamba", "dense"),
            ("mamba", "moe"), ("mamba", "dense"),
        ),
        num_experts=16,
        num_experts_per_tok=2,
        moe_d_ff=24576,
        ssm_state=128,
        ssm_d_inner=16384,
        ssm_head_dim=64,
        ssm_n_groups=8,
        ssm_chunk=256,
        ffn_activation="silu",
        gated_ffn=True,
        use_fsdp=True,
        num_microbatches=8,
        norm_eps=1e-5,
        expected_params=398_000_000_000,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_heads=8, num_kv_heads=2, num_experts=4,
                       ssm_n_groups=2, num_microbatches=1)
