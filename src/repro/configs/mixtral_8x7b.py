"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

SWA (window 4096) bounds the decode KV working set, so long_500k applies.
Experts (8) do not divide the 16-way model axis; expert FFN dims shard
instead (``expert_ffn -> model`` rule override).
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        group_pattern=(("attn", "moe"),),
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=14336,
        sliding_window=4096,
        ffn_activation="silu",
        gated_ffn=True,
        rope_theta=1_000_000.0,
        norm_eps=1e-5,
        sharding_overrides=(("expert_ffn", "model"),),
        expected_params=46_702_792_704,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_experts=4, num_kv_heads=2)
