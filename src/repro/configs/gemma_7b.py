"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU activation, head_dim=256, tied embeddings with sqrt(d) input scaling
[arXiv:2403.08295].
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        ffn_activation="gelu",
        gated_ffn=True,
        tie_embeddings=True,
        scale_embeddings=True,
        norm_eps=1e-6,
        expected_params=8_537_680_896,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_kv_heads=4, head_dim=32)
