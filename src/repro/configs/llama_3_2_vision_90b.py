"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attention image layers
[hf:meta-llama/Llama-3.2-90B-Vision; unverified tier].

Stack: 20 groups x (4 self-attn + 1 cross-attn) = 100 layers. The vision
tower is a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings [batch, vision_seq=6400, d_model] that feed the
cross-attention K/V. Uses FSDP rules (embed dim sharded over data) so the
~90B weights + optimizer state fit per-chip budgets.
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        group_pattern=(
            ("attn", "dense"), ("attn", "dense"), ("attn", "dense"),
            ("attn", "dense"), ("cross_attn", "dense"),
        ),
        vision_seq=6400,
        ffn_activation="silu",
        gated_ffn=True,
        rope_theta=500_000.0,
        use_fsdp=True,
        num_microbatches=8,
        norm_eps=1e-5,
        expected_params=88_600_000_000,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_heads=8, num_kv_heads=2, num_microbatches=1)
