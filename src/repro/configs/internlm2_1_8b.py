"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297]."""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92544,
        ffn_activation="silu",
        gated_ffn=True,
        rope_theta=1_000_000.0,
        norm_eps=1e-5,
        expected_params=1_889_110_016,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config())
