"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284]. Per the
assignment the modality frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings; the backbone is the transformer implemented
here (MHA, non-gated GELU FFN, sinusoidal positions).
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        ffn_activation="gelu",
        gated_ffn=False,
        pos_embedding="sinusoidal",
        embeddings_input=True,
        norm_eps=1e-5,
        expected_params=2_022_801_408,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_kv_heads=4, vocab_size=256)
