"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

LLaMA-architecture GQA [arXiv:2403.04652].
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        ffn_activation="silu",
        gated_ffn=True,
        rope_theta=5_000_000.0,
        norm_eps=1e-6,
        expected_params=8_829_407_232,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_heads=8, num_kv_heads=2)
