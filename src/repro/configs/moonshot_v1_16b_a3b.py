"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64 experts top-6 + 2 shared experts
[hf:moonshotai/Moonlight-16B-A3B, deepseek-v3-style].

Deviation (DESIGN.md §10): ``first_k_dense_replace=1`` omitted so the layer
stack stays homogeneous for the scan (<0.5% of parameters). Note the
assignment's 48L x 64e config implies ~28.9B total parameters — the real
Moonlight-16B has 27 layers; we implement the ASSIGNED config verbatim and
record its exact computed parameter count.
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=11264,              # dense-equivalent (unused; all layers MoE)
        vocab_size=163840,
        group_pattern=(("attn", "moe"),),
        num_experts=64,
        num_experts_per_tok=6,
        num_shared_experts=2,
        moe_d_ff=1408,
        ffn_activation="silu",
        gated_ffn=True,
        rope_theta=50_000.0,
        norm_eps=1e-5,
        expected_params=28_888_467_456,   # assigned 48L config (see docstring)
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_experts=8, num_kv_heads=4)
