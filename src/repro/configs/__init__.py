"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full production config;
``get_smoke_config(arch_id)`` returns the reduced same-family variant used by
CPU smoke tests.
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "gemma-7b",
    "yi-9b",
    "qwen2.5-3b",
    "internlm2-1.8b",
    "musicgen-large",
    "moonshot-v1-16b-a3b",
    "mixtral-8x7b",
    "llama-3.2-vision-90b",
    "jamba-1.5-large-398b",
    "mamba2-2.7b",
)

_MODULES = {
    "gemma-7b": "gemma_7b",
    "yi-9b": "yi_9b",
    "qwen2.5-3b": "qwen2_5_3b",
    "internlm2-1.8b": "internlm2_1_8b",
    "musicgen-large": "musicgen_large",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-2.7b": "mamba2_2_7b",
}

SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()


def shape_applicable(cfg, shape_id: str) -> bool:
    """long_500k requires sub-quadratic decode-context cost (see DESIGN.md)."""
    if shape_id == "long_500k":
        return cfg.subquadratic
    return True
