"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified tier].

d_inner = 2*d_model = 5120, head_dim 64 -> 80 heads. Vocab padded
50280 -> 50304 (divisible by 128 and the 16-way model axis; DESIGN.md §4).
O(1) decode state, so long_500k applies.
"""

from repro.models.config import ModelConfig, scaled_down


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        group_pattern=(("mamba", "none"),),
        ssm_state=128,
        ssm_d_inner=5120,
        ssm_head_dim=64,
        ssm_n_groups=1,
        ssm_chunk=256,
        tie_embeddings=True,
        norm_eps=1e-5,
        expected_params=2_702_599_680,
    )


def smoke_config() -> ModelConfig:
    return scaled_down(config(), num_heads=0, num_kv_heads=0, head_dim=0,
                       d_ff=0, ssm_n_groups=1)
