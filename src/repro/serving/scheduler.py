"""Batch schedulers implementing the paper's serving disciplines.

All schedulers consume a list of ``Request``s (Poisson arrivals, iid output
token requirements) and drive a *virtual timeline*: the next batch starts at
max(server_free, trigger), exactly like the event-driven simulator — but the
batch duration comes from a ``ServiceClock``, which is either

  * ``ModelClock``   — the calibrated BatchLatencyModel (paper-scale
                       experiments in milliseconds of host time), or
  * ``EngineClock``  — the real jitted engine on a tiny model (wall-clock
                       ground truth; validates that the policy ordering the
                       analytics predict holds on real executables).

Policies:
  FCFSScheduler            M/G/1 single-request service    (paper §III)
  DynamicBatchScheduler    batch all waiting (cap b_max)   (paper §IV-A/B)
  FixedBatchScheduler      wait for exactly b              (paper §IV-C)
  ElasticBatchScheduler    early-exit batches (Eq 26)      (paper §IV-D)
  ContinuousBatchScheduler iteration-level refill [beyond paper; Orca-style]
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.data.pipeline import Request


# ----------------------------------------------------------------------------
# Clocks
# ----------------------------------------------------------------------------

class ModelClock:
    def __init__(self, single: LatencyModel, batch: BatchLatencyModel):
        self.single = single
        self.batch = batch

    def single_time(self, n_tokens: int) -> float:
        return float(self.single.service_time(n_tokens))

    def batch_time(self, ns) -> float:
        ns = np.asarray(ns, np.float64)
        return float(self.batch.batch_time(len(ns), ns.max()))

    def elastic_times(self, ns) -> np.ndarray:
        """Per-request completion offsets, ordered like sorted(ns)."""
        return self.batch.elastic_completion_times(ns)

    def decode_step_time(self, b: int) -> float:
        return float(self.batch.k3 * b + self.batch.k4)

    def prefill_time(self, b: int) -> float:
        return float(self.batch.k1 * b + self.batch.k2)


class EngineClock:
    """Wall-clock service times from the real engine."""

    def __init__(self, engine):
        self.engine = engine

    def run_batch(self, reqs: List[Request], elastic: bool,
                  n_max: Optional[int]):
        res = self.engine.generate(
            [r.prompt_tokens for r in reqs],
            [r.target_output_tokens for r in reqs],
            elastic=elastic, n_max=n_max)
        return res["completion_seconds"], res["batch_seconds"]

    def single_time(self, req: Request, n_max):
        comp, total = self.run_batch([req], False, n_max)
        return total


# ----------------------------------------------------------------------------
# Schedulers (virtual timeline)
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class ScheduleResult:
    waits: np.ndarray           # queueing delay per request (paper's E[W])
    e2e: np.ndarray             # arrival -> reply complete
    lost: np.ndarray            # impatience abandonments (bool)
    batch_sizes: List[int]
    makespan: float


def _clip(reqs, n_max):
    return [min(r.target_output_tokens, n_max) if n_max else
            r.target_output_tokens for r in reqs]


class _Base:
    def __init__(self, clock: ModelClock, n_max: Optional[int] = None,
                 tau: Optional[float] = None):
        self.clock = clock
        self.n_max = n_max
        self.tau = tau


class FCFSScheduler(_Base):
    """Single-request FCFS: the paper's M/G/1 (§III), incl. impatience."""

    def run(self, reqs: List[Request]) -> ScheduleResult:
        n = len(reqs)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        lost = np.zeros(n, bool)
        t_free = 0.0
        for i, r in enumerate(reqs):
            ns = _clip([r], self.n_max)[0]
            wait = max(0.0, t_free - r.arrival)
            if self.tau is not None and wait >= self.tau:
                waits[i] = self.tau
                lost[i] = True
                continue
            svc = self.clock.single_time(ns)
            waits[i] = wait
            e2e[i] = wait + svc
            t_free = r.arrival + wait + svc
        return ScheduleResult(waits, e2e, lost, [1] * n, t_free)


class DynamicBatchScheduler(_Base):
    """Batch everything waiting when the server frees (cap b_max); padded
    decode: the batch runs to its longest member (paper Eq 18)."""

    def __init__(self, clock, n_max=None, b_max: Optional[int] = None):
        super().__init__(clock, n_max)
        self.b_max = b_max

    def run(self, reqs: List[Request]) -> ScheduleResult:
        n = len(reqs)
        arr = np.array([r.arrival for r in reqs])
        ns = np.array(_clip(reqs, self.n_max), np.float64)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        sizes = []
        head, t_free = 0, 0.0
        while head < n:
            if arr[head] >= t_free:
                start, hi = arr[head], head + 1
            else:
                start = t_free
                hi = int(np.searchsorted(arr, t_free, side="right"))
            if self.b_max:
                hi = min(hi, head + self.b_max)
            h = self.clock.batch_time(ns[head:hi])
            waits[head:hi] = start - arr[head:hi]
            e2e[head:hi] = start + h - arr[head:hi]
            sizes.append(hi - head)
            t_free = start + h
            head = hi
        return ScheduleResult(waits, e2e, np.zeros(n, bool), sizes, t_free)


class FixedBatchScheduler(_Base):
    """Wait until exactly b requests are present (paper §IV-C)."""

    def __init__(self, clock, b: int, n_max=None):
        super().__init__(clock, n_max)
        self.b = b

    def run(self, reqs: List[Request]) -> ScheduleResult:
        b = self.b
        n = (len(reqs) // b) * b
        arr = np.array([r.arrival for r in reqs[:n]])
        ns = np.array(_clip(reqs[:n], self.n_max), np.float64)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        t_free = 0.0
        for head in range(0, n, b):
            batch_arr = arr[head:head + b]
            start = max(t_free, batch_arr[-1])
            h = self.clock.batch_time(ns[head:head + b])
            waits[head:head + b] = start - batch_arr
            e2e[head:head + b] = start + h - batch_arr
            t_free = start + h
        return ScheduleResult(waits, e2e, np.zeros(n, bool),
                              [b] * (n // b), t_free)


class ElasticBatchScheduler(_Base):
    """Paper §IV-D: batch like dynamic batching, but short replies exit
    early (per-request completion via Eq 26) and the batch ends at the
    slowest member's completion."""

    def __init__(self, clock, n_max=None, b_max: Optional[int] = None):
        super().__init__(clock, n_max)
        self.b_max = b_max

    def run(self, reqs: List[Request]) -> ScheduleResult:
        n = len(reqs)
        arr = np.array([r.arrival for r in reqs])
        ns = np.array(_clip(reqs, self.n_max), np.float64)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        sizes = []
        head, t_free = 0, 0.0
        while head < n:
            if arr[head] >= t_free:
                start, hi = arr[head], head + 1
            else:
                start = t_free
                hi = int(np.searchsorted(arr, t_free, side="right"))
            if self.b_max:
                hi = min(hi, head + self.b_max)
            batch_ns = ns[head:hi]
            comp = self.clock.elastic_times(batch_ns)      # sorted order
            order = np.argsort(batch_ns, kind="stable")
            comp_by_req = np.empty(hi - head)
            comp_by_req[order] = comp
            waits[head:hi] = start - arr[head:hi]
            e2e[head:hi] = start + comp_by_req - arr[head:hi]
            sizes.append(hi - head)
            t_free = start + comp.max()
            head = hi
        return ScheduleResult(waits, e2e, np.zeros(n, bool), sizes, t_free)


class ContinuousBatchScheduler(_Base):
    """Beyond paper: iteration-level scheduling (Orca/vLLM). ``slots``
    decode streams run concurrently; a finished slot is refilled immediately
    from the queue (one prefill joins the running batch). Queue wait ends
    when the request's prefill starts.

    ``chunk`` mirrors the real engine's fused decode loop
    (``Engine.decode_chunk``): admission and refill only happen at chunk
    boundaries, and — like ``serve_continuous`` — a chunk is cut short at
    the earliest remaining completion while work is queued, so the freed
    slot refills without idle decode. ``chunk=1`` is the legacy per-step
    discipline."""

    def __init__(self, clock: ModelClock, slots: int, n_max=None,
                 chunk: int = 1):
        super().__init__(clock, n_max)
        self.slots = slots
        assert chunk >= 1
        self.chunk = chunk

    def run(self, reqs: List[Request]) -> ScheduleResult:
        n = len(reqs)
        arr = np.array([r.arrival for r in reqs])
        ns = np.array(_clip(reqs, self.n_max), np.int64)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        remaining = {}                 # slot -> tokens_left
        t = 0.0
        head = 0
        while head < n or remaining:
            # admit (chunk boundary)
            while head < n and arr[head] <= t and len(remaining) < self.slots:
                waits[head] = t - arr[head]
                t += self.clock.prefill_time(1)   # prefill piggybacked
                remaining[head] = ns[head]
                head += 1
            if not remaining:
                t = max(t, arr[head])
                continue
            # one fused chunk of decode iterations for all active slots
            b = len(remaining)
            rem = list(remaining.values())
            steps = min(self.chunk, min(rem) if head < n else max(rem))
            steps = max(int(steps), 1)
            dt_step = self.clock.decode_step_time(b)
            done = []
            for rid in list(remaining):
                if remaining[rid] <= steps:
                    # completes mid-chunk; the real engine interpolates the
                    # same way from the scan's per-step active mask
                    e2e[rid] = t + remaining[rid] * dt_step - arr[rid]
                    done.append(rid)
                else:
                    remaining[rid] -= steps
            t += steps * dt_step
            for rid in done:
                del remaining[rid]
        return ScheduleResult(waits, e2e, np.zeros(n, bool), [], t)


def run_schedule(scheduler, reqs: List[Request]) -> ScheduleResult:
    return scheduler.run(reqs)
