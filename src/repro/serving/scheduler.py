"""Virtual-timeline schedulers: a BatchPolicy bound to a ServiceClock.

Since the batching-policy refactor the serving disciplines themselves live
in :mod:`repro.core.policies` — ONE definition each of trigger, member
selection, clipping and service law.  This module binds a policy to a
*clock* and drives the virtual timeline: the next batch starts at
max(server_free, trigger), exactly like the reference oracle — but the
batch duration comes from a ``ServiceClock``, which is either

  * ``ModelClock``   — the calibrated BatchLatencyModel (paper-scale
                       experiments in milliseconds of host time), or
  * ``EngineClock``  — the real jitted engine on a tiny model (wall-clock
                       ground truth; validates that the policy ordering the
                       analytics predict holds on real executables).

``PolicyScheduler(policy, clock)`` is the generic adapter; the named
scheduler classes are one-line bindings kept for compatibility and
readability:

  FCFSScheduler            FCFSPolicy      (M/G/1, incl. impatience tau)
  DynamicBatchScheduler    DynamicPolicy   (paper §IV-A/B)
  FixedBatchScheduler      FixedPolicy     (paper §IV-C)
  ElasticBatchScheduler    ElasticPolicy   (paper §IV-D, Eq 26)
  MultiBinBatchScheduler   MultiBinPolicy  (Guldogan et al. 2024)
  WaitBatchScheduler       WaitPolicy      (threshold admission, Dai et al.)
  SRPTBatchScheduler       SRPTPolicy      (shortest-predicted-first)
  ContinuousBatchScheduler iteration-level refill [beyond paper; Orca-style]

``run_engine_schedule`` executes any batch-formation policy's batches on
the REAL engine (prefill + fused chunked decode per batch), which is how
multi-bin batching reaches the engine layer.

Both the adapter and ``run_engine_schedule`` accept a *length predictor*
(:mod:`repro.core.predictors`): batch membership/ordering is driven by
PREDICTED output lengths while clipping and service use the true ones —
the same predicted-vs-true convention the simulator layers follow, so a
noisy predictor degrades the scheduler exactly like the fast sweep says
it should.  Resolution goes through the ONE shared
:func:`repro.core.predictors.resolve_predictions`; the fleet layer
(:mod:`repro.serving.router`) reuses it and drives R of these schedulers
behind a :mod:`repro.core.fleet` routing policy.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.latency_model import BatchLatencyModel, LatencyModel
from repro.core.policies import (
    BatchPolicy, DynamicPolicy, ElasticPolicy, FCFSPolicy, FixedPolicy,
    MultiBinPolicy, SRPTPolicy, WaitPolicy)
from repro.data.pipeline import Request


# ----------------------------------------------------------------------------
# Clocks
# ----------------------------------------------------------------------------

class ModelClock:
    def __init__(self, single: LatencyModel, batch: BatchLatencyModel):
        self.single = single
        self.batch = batch

    def single_time(self, n_tokens: int) -> float:
        return float(self.single.service_time(n_tokens))

    def batch_time(self, ns) -> float:
        ns = np.asarray(ns, np.float64)
        return float(self.batch.batch_time(len(ns), ns.max()))

    def elastic_times(self, ns) -> np.ndarray:
        """Per-request completion offsets, ordered like sorted(ns)."""
        return self.batch.elastic_completion_times(ns)

    def decode_step_time(self, b: int) -> float:
        return float(self.batch.k3 * b + self.batch.k4)

    def prefill_time(self, b: int) -> float:
        return float(self.batch.k1 * b + self.batch.k2)


class EngineClock:
    """Wall-clock service times from the real engine."""

    def __init__(self, engine):
        self.engine = engine

    def run_batch(self, reqs: List[Request], elastic: bool,
                  n_max: Optional[int]):
        res = self.engine.generate(
            [r.prompt_tokens for r in reqs],
            [r.target_output_tokens for r in reqs],
            elastic=elastic, n_max=n_max)
        return res["completion_seconds"], res["batch_seconds"]

    def single_time(self, req: Request, n_max):
        comp, total = self.run_batch([req], False, n_max)
        return total


# ----------------------------------------------------------------------------
# Generic policy adapter (virtual timeline)
# ----------------------------------------------------------------------------

def _request_predictions(policy: BatchPolicy, predictor, predict_seed: int,
                         ns: np.ndarray, reqs: List[Request]):
    """Predicted-length column for a request list — a thin prompt-plumbing
    wrapper over the ONE shared resolver
    (:func:`repro.core.predictors.resolve_predictions`), used by
    ``PolicyScheduler``, ``run_engine_schedule`` and the fleet layer
    (:mod:`repro.serving.router`) alike."""
    from repro.core.predictors import resolve_predictions
    prompts = [r.prompt_tokens for r in reqs[:len(ns)]]
    return resolve_predictions(policy, predictor, predict_seed, ns, prompts)


@dataclasses.dataclass
class ScheduleResult:
    waits: np.ndarray           # queueing delay per request (paper's E[W])
    e2e: np.ndarray             # arrival -> reply complete
    lost: np.ndarray            # impatience abandonments (bool)
    batch_sizes: List[int]
    makespan: float
    # per-session accounting (repro.core.sessions); None on
    # session-free runs — the historical result shape
    sessions: Optional[dict] = None
    # KV-occupancy accounting (repro.core.memory); None on
    # budget-free runs
    memory: Optional[dict] = None


class PolicyScheduler:
    """Bind a :class:`repro.core.policies.BatchPolicy` to a ServiceClock.

    The policy supplies formation (trigger + members) and per-batch
    completion semantics (``service_clock``); this adapter only walks the
    virtual timeline and collects waits / end-to-end latencies.

    ``predictor`` overrides the policy's own length predictor for this
    scheduler (None keeps it); formation sees the PREDICTED lengths while
    clipping and the service clock keep the true ``target_output_tokens``
    (the predicted-vs-true convention, :mod:`repro.core.predictors`).
    ``predict_seed`` keys the predictor's rng stream.

    ``memory`` (a :class:`repro.core.memory.MemoryBudget`, capacity
    number, or spec dict; None = unconstrained) switches the timeline to
    the memory-gated prefill/decode tandem of
    :func:`repro.core.memory.tandem_oracle`, driven through this clock's
    batch law — a null budget keeps the exact single-stage path."""

    def __init__(self, policy: BatchPolicy, clock: ModelClock,
                 predictor=None, predict_seed: int = 0, memory=None):
        self.policy = policy
        self.clock = clock
        if predictor is not None:
            from repro.core.predictors import predictor_from_spec
            predictor = predictor_from_spec(predictor)
        self.predictor = predictor
        self.predict_seed = predict_seed
        from repro.core.memory import (
            check_policy_supports_memory, memory_from_spec)
        budget = memory_from_spec(memory)
        if budget.is_null:
            self.memory = None
        else:
            check_policy_supports_memory(policy)
            self.memory = budget

    def run(self, reqs: List[Request],
            predicted: Optional[np.ndarray] = None) -> ScheduleResult:
        """``predicted`` overrides the per-request predicted lengths (the
        fleet layer passes slices of ONE globally-drawn column so routing
        and membership see the same predictions); None resolves them from
        the configured predictor."""
        pol = self.policy
        n = pol.schedule_length(len(reqs))
        arr = np.array([r.arrival for r in reqs[:n]])
        ns = np.array([pol.clip(r.target_output_tokens) for r in reqs[:n]],
                      np.float64)
        tau = getattr(pol, "tau", None)
        waits = np.zeros(n)
        e2e = np.zeros(n)
        lost = np.zeros(n, bool)
        sizes = []
        if predicted is None:
            predicted = _request_predictions(
                pol, self.predictor, self.predict_seed, ns, reqs)
        if self.memory is not None:
            return self._run_tandem(arr, ns, (
                None if predicted is None else predicted[:n]))
        fs = pol.formation(arr, ns, predicted=(
            None if predicted is None else predicted[:n]))
        t_free = 0.0
        while (nb := fs.next_batch(t_free)) is not None:
            start, idx = nb
            w = start - arr[idx]
            if tau is not None and len(idx) == 1 and w[0] >= tau:
                waits[idx] = tau        # abandoned: spends tau in queue
                lost[idx] = True
                continue                # server never starts this request
            h, offsets = pol.service_clock(ns[idx], self.clock)
            waits[idx] = w
            e2e[idx] = w + offsets
            sizes.append(len(idx))
            t_free = start + h
        return ScheduleResult(waits, e2e, lost, sizes, t_free)

    def _run_tandem(self, arr: np.ndarray, ns: np.ndarray,
                    predicted: Optional[np.ndarray]) -> ScheduleResult:
        """Memory-gated tandem timeline: the ONE reference loop
        (:func:`repro.core.memory.tandem_oracle`) driven through this
        scheduler's clock, so the serving layer inherits admission,
        deferral and occupancy accounting with no second implementation."""
        import types
        from repro.core.memory import tandem_oracle
        wl = types.SimpleNamespace(arrivals=arr, tokens=ns,
                                   predicted=predicted)
        res = tandem_oracle(self.policy, wl, self.clock.batch, None,
                            self.memory)
        waits = res["waits_all"]
        comp = res["completions"]
        return ScheduleResult(
            waits, comp - arr, np.zeros(len(arr), bool),
            res["batch_sizes"], float(comp.max()) if len(comp) else 0.0,
            memory=res["memory"])

    def run_sessions(self, reqs: List[Request],
                     predicted: Optional[np.ndarray] = None,
                     prefix_discount: float = 0.0) -> ScheduleResult:
        """Session-aware timeline: turn t+1 of a session re-enters the
        queue at turn t's completion + ``think`` (the feedback fixed
        point of :mod:`repro.core.sessions`, with :meth:`run` as the
        inner pass).  A stream with no multi-turn rows takes the plain
        :meth:`run` path — bit-equal to the session-free scheduler.

        ``prefix_discount`` γ models KV/prefix reuse: on a single
        scheduler every turn returns to the same engine, whose
        ``kv_lens`` retain the session prefix, so turns >= 2 serve
        ``tokens·(1−γ)`` (membership predictions stay undiscounted).
        Impatience (tau) sheds turns; a lost turn terminates its session
        — descendant turns never arrive and are EXCLUDED from the
        returned arrays (``sessions['turns_cancelled']`` counts them),
        so accounting closes: arrived == served + lost."""
        if all(r.turn <= 1 for r in reqs):
            return self.run(reqs, predicted)
        if self.memory is not None:
            raise ValueError(
                "sessions x memory is not supported: turn re-entry holds "
                "KV across think times, which the per-batch "
                "allocate/release ledger does not model")
        from repro.core.sessions import (
            _MAX_PASSES, _TOL, _cascade_cancel, _session_summary,
            check_policy_supports_sessions, plan_from_requests)
        pol = self.policy
        check_policy_supports_sessions(pol)
        m = len(reqs)
        turn = np.array([r.turn for r in reqs], np.int64)
        plan, order_sm, lb = plan_from_requests(reqs)
        if predicted is None:
            ns_full = np.array(
                [pol.clip(r.target_output_tokens) for r in reqs],
                np.float64)
            predicted = _request_predictions(
                pol, self.predictor, self.predict_seed, ns_full, reqs)
        tok_true = np.array([r.target_output_tokens for r in reqs],
                            np.int64)
        eff_tok = tok_true.copy()
        if prefix_discount > 0.0:
            later = turn > 1
            eff_tok[later] = np.maximum(
                1, np.round(tok_true[later]
                            * (1.0 - prefix_discount)).astype(np.int64))
        # plan row p <-> request index order_sm[p]
        arr = lb.copy()
        child = np.nonzero(plan.parent >= 0)[0]
        cancelled = np.zeros(m, bool)
        lost = np.zeros(m, bool)
        res = None
        ids = np.arange(m)
        w_row = np.zeros(m)
        comp = np.full(m, np.inf)
        canc_pass = cancelled
        seen_states = set()
        for _ in range(_MAX_PASSES):
            canc_pass = cancelled   # the set that defines this pass's ids
            active = np.nonzero(~cancelled)[0]
            ids = active[np.lexsort((active, arr[active]))]
            ridx = order_sm[ids]
            pass_reqs = [dataclasses.replace(
                reqs[i], arrival=float(arr[p]),
                target_output_tokens=int(eff_tok[i]))
                for p, i in zip(ids, ridx)]
            res = self.run(pass_reqs,
                           predicted=(None if predicted is None
                                      else predicted[ridx]))
            comp = np.full(m, np.inf)
            w_row = np.zeros(m)
            w_row[ids] = res.waits
            srv = ~res.lost
            comp[ids[srv]] = arr[ids[srv]] + res.e2e[srv]
            lost_row = np.zeros(m, bool)
            lost_row[ids] = res.lost
            new_cancelled = _cascade_cancel(plan, lost_row)
            new_arr = arr.copy()
            new_arr[child] = comp[plan.parent[child]] + plan.think[child]
            unresolved = child[~np.isfinite(new_arr[child])]
            new_arr[unresolved] = lb[unresolved]
            new_arr[new_cancelled] = lb[new_cancelled]
            live = child[~new_cancelled[child]]
            delta = float(np.max(np.abs(new_arr[live] - arr[live]))) \
                if len(live) else 0.0
            stable = (np.array_equal(new_cancelled, cancelled)
                      and np.array_equal(lost_row, lost))
            arr, cancelled, lost = new_arr, new_cancelled, lost_row
            if stable and delta <= _TOL:
                break
            if not stable:
                # shedding can cycle the lost/cancel sets (no fixed
                # point); a repeated set state never converges
                state = (new_cancelled.tobytes(), lost_row.tobytes())
                if state in seen_states:
                    break
                seen_states.add(state)
        # report the last SIMULATED pass's cancel set: identical on a
        # converged break, self-consistent on pass exhaustion (shedding
        # can cycle — see repro.core.sessions._tau_event_loop)
        cancelled = canc_pass
        return ScheduleResult(
            res.waits, res.e2e, res.lost, res.batch_sizes, res.makespan,
            sessions=_session_summary(plan, arr, w_row, comp, cancelled,
                                      lost))


class FCFSScheduler(PolicyScheduler):
    """Single-request FCFS: the paper's M/G/1 (§III), incl. impatience."""

    def __init__(self, clock, n_max: Optional[int] = None,
                 tau: Optional[float] = None):
        super().__init__(FCFSPolicy(n_max=n_max, tau=tau), clock)


class DynamicBatchScheduler(PolicyScheduler):
    """Batch everything waiting when the server frees (cap b_max); padded
    decode: the batch runs to its longest member (paper Eq 18)."""

    def __init__(self, clock, n_max=None, b_max: Optional[int] = None):
        super().__init__(DynamicPolicy(n_max=n_max, b_max=b_max), clock)


class FixedBatchScheduler(PolicyScheduler):
    """Wait until exactly b requests are present (paper §IV-C)."""

    def __init__(self, clock, b: int, n_max=None):
        super().__init__(FixedPolicy(b=b, n_max=n_max), clock)


class ElasticBatchScheduler(PolicyScheduler):
    """Paper §IV-D: batch like dynamic batching, but short replies exit
    early (per-request completion via Eq 26) and the batch ends at the
    slowest member's completion."""

    def __init__(self, clock, n_max=None, b_max: Optional[int] = None):
        super().__init__(ElasticPolicy(n_max=n_max, b_max=b_max), clock)


class MultiBinBatchScheduler(PolicyScheduler):
    """Multi-bin batching (Guldogan et al. 2024): per-bin dynamic batching
    keyed by PREDICTED output length (``predictor``: a
    :mod:`repro.core.predictors` instance/name; None = oracle); one shared
    server picks the bin whose head request arrived earliest."""

    def __init__(self, clock, num_bins: int = 4, edges=None, n_max=None,
                 b_max: Optional[int] = None, predictor=None):
        super().__init__(MultiBinPolicy(num_bins=num_bins, edges=edges,
                                        n_max=n_max, b_max=b_max,
                                        predictor=predictor), clock)


class WaitBatchScheduler(PolicyScheduler):
    """WAIT threshold admission (Dai et al. 2025): hold batch formation
    until k requests are buffered or the head has waited ``timeout``."""

    def __init__(self, clock, k: int = 8, timeout: Optional[float] = None,
                 n_max=None, b_max: Optional[int] = None):
        super().__init__(WaitPolicy(k=k, timeout=timeout, n_max=n_max,
                                    b_max=b_max), clock)


class SRPTBatchScheduler(PolicyScheduler):
    """SRPT-like shortest-predicted-first batch formation: the ``b_max``
    requests with the shortest PREDICTED lengths form the next batch
    (``predictor``: a :mod:`repro.core.predictors` instance/name; None =
    oracle)."""

    def __init__(self, clock, b_max: Optional[int] = 8, n_max=None,
                 predictor=None):
        super().__init__(SRPTPolicy(b_max=b_max, n_max=n_max,
                                    predictor=predictor), clock)


# ----------------------------------------------------------------------------
# Continuous (iteration-level) batching
# ----------------------------------------------------------------------------

def run_continuous_virtual(arrivals: np.ndarray, tokens: np.ndarray, *,
                           slots: int, chunk: int,
                           prefill_time: Callable[[int], float],
                           decode_step_time: Callable[[int], float]):
    """The continuous-batching virtual timeline, shared by the scheduler
    adapter and the reference oracle (``ContinuousPolicy``).

    ``slots`` decode streams run concurrently; a finished slot is refilled
    immediately from the queue (one prefill joins the running batch).
    Queue wait ends when the request's prefill starts.  ``chunk`` mirrors
    the engine's fused decode loop: admission/refill only at chunk
    boundaries, and a chunk is cut short at the earliest remaining
    completion while work is queued.  Returns (waits, e2e, makespan)."""
    n = len(arrivals)
    waits = np.zeros(n)
    e2e = np.zeros(n)
    remaining = {}                 # slot -> tokens_left
    t = 0.0
    head = 0
    while head < n or remaining:
        # admit (chunk boundary)
        while head < n and arrivals[head] <= t and len(remaining) < slots:
            waits[head] = t - arrivals[head]
            t += prefill_time(1)   # prefill piggybacked
            remaining[head] = tokens[head]
            head += 1
        if not remaining:
            t = max(t, arrivals[head])
            continue
        # one fused chunk of decode iterations for all active slots
        b = len(remaining)
        rem = list(remaining.values())
        steps = min(chunk, min(rem) if head < n else max(rem))
        steps = max(int(steps), 1)
        dt_step = decode_step_time(b)
        done = []
        for rid in list(remaining):
            if remaining[rid] <= steps:
                # completes mid-chunk; the real engine interpolates the
                # same way from the scan's per-step active mask
                e2e[rid] = t + remaining[rid] * dt_step - arrivals[rid]
                done.append(rid)
            else:
                remaining[rid] -= steps
        t += steps * dt_step
        for rid in done:
            del remaining[rid]
    return waits, e2e, t


class ContinuousBatchScheduler:
    """Beyond paper: iteration-level scheduling (Orca/vLLM).  Thin adapter
    over :func:`run_continuous_virtual` with the clock's prefill/decode-step
    laws; ``chunk=1`` is the legacy per-step discipline."""

    def __init__(self, clock: ModelClock, slots: int, n_max=None,
                 chunk: int = 1):
        self.clock = clock
        self.n_max = n_max
        self.slots = slots
        assert chunk >= 1
        self.chunk = chunk

    def run(self, reqs: List[Request]) -> ScheduleResult:
        n = len(reqs)
        arr = np.array([r.arrival for r in reqs])
        ns = np.array([min(r.target_output_tokens, self.n_max) if self.n_max
                       else r.target_output_tokens for r in reqs], np.int64)
        waits, e2e, t = run_continuous_virtual(
            arr, ns, slots=self.slots, chunk=self.chunk,
            prefill_time=self.clock.prefill_time,
            decode_step_time=self.clock.decode_step_time)
        return ScheduleResult(waits, e2e, np.zeros(n, bool), [], t)


# ----------------------------------------------------------------------------
# Engine layer: execute a policy's batches on the real engine
# ----------------------------------------------------------------------------

def run_engine_schedule(policy: BatchPolicy, engine, reqs: List[Request],
                        predictor=None, predict_seed: int = 0,
                        predicted: Optional[np.ndarray] = None,
                        memory=None) -> ScheduleResult:
    """Form batches with ``policy`` on the request stream's virtual arrival
    timeline, but execute each batch on the REAL engine (prefill + fused
    chunked decode); batch durations are wall-clock seconds.  Works for any
    batch-formation policy (dynamic, fixed, elastic, multi-bin).

    ``predictor`` (a :mod:`repro.core.predictors` instance, name, or spec;
    None keeps ``policy.predictor``) feeds formation's membership/ordering
    with PREDICTED lengths; the engine still decodes each request to its
    true ``target_output_tokens`` — mispredictions show up as real padded
    wall-clock, exactly like in production.  ``predicted`` bypasses the
    resolution with an explicit column (fleet layer).

    ``memory`` (budget spec, :mod:`repro.core.memory`) gates admission on
    the REAL KV footprint — prompt length + target output tokens per
    member.  Engine batches run serially to completion (one device, cache
    freed between calls), so unlike the pipelined virtual tandem the
    alive KV between batches is zero and admission reduces to capping
    each batch's total footprint at the budget: members beyond the
    longest admissible prefix are deferred via ``formation.rewind`` and
    re-offered at the next trigger.  The engine's own occupancy
    (``Engine.kv_report``) cross-checks the ledger from inside the jitted
    loop."""
    from repro.core.memory import (
        check_policy_supports_memory, memory_from_spec, occupancy_stats)
    budget = memory_from_spec(memory)
    mem = None if budget.is_null else budget
    if mem is not None:
        check_policy_supports_memory(policy)
    clock = EngineClock(engine)
    n = policy.schedule_length(len(reqs))
    arr = np.array([r.arrival for r in reqs[:n]])
    ns = np.array([policy.clip(r.target_output_tokens) for r in reqs[:n]],
                  np.float64)
    elastic = isinstance(policy, ElasticPolicy)
    waits = np.zeros(n)
    e2e = np.zeros(n)
    starts = np.zeros(n)
    comps = np.zeros(n)
    sizes = []
    deferred = 0
    fp = None
    if mem is not None:
        # the REAL footprint: actual prompt length (not the budget's
        # scalar prompt_tokens stand-in) + generated tokens
        fp = ns + np.array(
            [len(reqs[i].prompt_tokens) for i in range(n)], np.float64)
        if n and float(fp.max()) > float(budget.capacity):
            raise ValueError(
                f"kv budget {budget.capacity} cannot hold the largest "
                f"single request (footprint {float(fp.max())})")
    if predicted is None:
        predicted = _request_predictions(policy, predictor, predict_seed,
                                         ns, reqs)
    fs = policy.formation(arr, ns, predicted=(
        None if predicted is None else predicted[:n]))
    t_free = 0.0
    while (nb := fs.next_batch(t_free)) is not None:
        start, idx = nb
        if mem is not None:
            cum, admit = 0.0, 0
            for i in idx:
                if cum + fp[i] <= float(budget.capacity):
                    cum += fp[i]
                    admit += 1
                else:
                    break
            if admit < len(idx):
                fs.rewind(len(idx) - admit)
                deferred += len(idx) - admit
                idx = idx[:admit]
        comp, total = clock.run_batch([reqs[i] for i in idx], elastic,
                                      policy.n_max)
        waits[idx] = start - arr[idx]
        e2e[idx] = waits[idx] + np.asarray(comp)[:len(idx)]
        starts[idx] = start
        comps[idx] = start + np.asarray(comp)[:len(idx)]
        sizes.append(len(idx))
        t_free = start + total
    memrep = None
    if mem is not None:
        memrep = occupancy_stats(starts, comps, fp,
                                 float(budget.capacity), served=n)
        memrep["deferred_requests"] = deferred
    return ScheduleResult(waits, e2e, np.zeros(n, bool), sizes, t_free,
                          memory=memrep)


def run_schedule(scheduler, reqs: List[Request]) -> ScheduleResult:
    return scheduler.run(reqs)
