from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import (
    FCFSScheduler, DynamicBatchScheduler, FixedBatchScheduler,
    ElasticBatchScheduler, ContinuousBatchScheduler, MultiBinBatchScheduler,
    WaitBatchScheduler, SRPTBatchScheduler,
    PolicyScheduler, run_engine_schedule, run_schedule,
)
from repro.serving.metrics import summarize
from repro.serving.router import (
    FleetScheduleResult, FleetScheduler, run_fleet_schedule, summarize_fleet,
)
from repro.serving.continuous import serve_continuous, splice_cache

__all__ = [
    "Engine", "EngineConfig",
    "FCFSScheduler", "DynamicBatchScheduler", "FixedBatchScheduler",
    "ElasticBatchScheduler", "ContinuousBatchScheduler",
    "MultiBinBatchScheduler", "WaitBatchScheduler", "SRPTBatchScheduler",
    "PolicyScheduler", "run_engine_schedule", "run_schedule",
    "FleetScheduleResult", "FleetScheduler", "run_fleet_schedule",
    "summarize_fleet",
    "summarize",
    "serve_continuous", "splice_cache",
]
