"""Continuous (iteration-level) batching on the real engine — beyond paper.

A fixed pool of decode slots runs decode iterations; whenever a slot
finishes its request, the next queued request is prefilled in a size-1
bucket and its cache is SPLICED into the pool cache at that slot. Short
requests neither wait for batch formation nor pay padding decode — the
paper's elastic batching taken to per-iteration granularity (Orca/vLLM).

Chunked admission (host-sync accounting)
----------------------------------------
Decode runs through the engine's fused ``decode_chunk`` (``lax.scan`` over
up to ``chunk`` steps, one host sync per chunk) instead of one jitted call
per token. Admission happens at chunk boundaries; to keep the
refill-immediately semantics, a chunk is cut short at the *earliest*
remaining completion among active slots whenever requests are still queued
(so a freed slot is refilled before any avoidable idle decode), and runs
full ``chunk`` steps once the queue is empty. Per-request completion times
are interpolated inside a chunk from the scan's per-step active mask.
``chunk=1`` reproduces the legacy per-step loop sync for sync.

The splice uses the cache spec's logical axes to locate each leaf's batch
and kv-seq dims, so it works across attention (bshd/bhsd), Mamba state and
cross-attention leaves uniformly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import cache_specs
from repro.models.params import Spec


def _axes_tree(cfg: ModelConfig, batch: int, max_seq: int):
    return cache_specs(cfg, batch, max_seq)


def splice_cache(cfg: ModelConfig, pool, single, slot: int,
                 pool_batch: int, pool_seq: int):
    """Write request-cache `single` (batch bucket 1, seq bucket S') into
    `pool` at batch index `slot`."""
    specs = _axes_tree(cfg, pool_batch, pool_seq)

    def one(spec: Spec, big, small):
        axes = spec.axes
        b_dim = axes.index("batch")
        idx = [slice(None)] * big.ndim
        idx[b_dim] = slot
        src = jnp.take(small, 0, axis=b_dim)
        # align any seq-bearing dim (kv_seq / vis_seq) to the small bucket
        for d, name in enumerate(axes):
            if name in ("kv_seq", "vis_seq"):
                dd = d if d < b_dim else d - 1   # src lost the batch dim
                span = small.shape[d]
                idx[d] = slice(0, span)
                src = jax.lax.slice_in_dim(src, 0, span, axis=dd)
        return big.at[tuple(idx)].set(src.astype(big.dtype))

    return jax.tree.map(one, specs, pool, single,
                        is_leaf=lambda x: isinstance(x, Spec))


@dataclasses.dataclass
class ContinuousResult:
    produced: np.ndarray
    ttft: np.ndarray            # arrival-agnostic: seconds from serve start
    completion: np.ndarray      # seconds from serve start
    decode_steps: int
    wall_seconds: float
    host_syncs: int = 0


def serve_continuous(engine, prompts: List[np.ndarray],
                     target_tokens: List[int], *, slots: int = 4,
                     n_max: Optional[int] = None,
                     chunk: Optional[int] = None) -> ContinuousResult:
    """Run all requests through a `slots`-wide continuous-batching pool."""
    cfg = engine.cfg
    assert cfg.decode_cache_update in ("scatter", "onehot"), \
        "continuous batching needs per-slot (ragged) cache updates"
    chunk = int(chunk if chunk is not None else engine.ecfg.decode_chunk)
    assert chunk >= 1
    n = len(prompts)
    targets = np.asarray(target_tokens)
    if n_max is not None:
        targets = np.minimum(targets, n_max)

    pool_seq = engine.ecfg.max_seq
    pool = engine.new_cache(slots)
    kv_lens = np.zeros(slots, np.int64)
    tok = jnp.zeros((slots,), jnp.int32)
    slot_req = np.full(slots, -1)
    produced = np.zeros(n, np.int64)
    ttft = np.full(n, np.nan)
    completion = np.full(n, np.nan)

    t0 = time.perf_counter()
    syncs0 = engine.host_syncs
    queue = list(range(n))
    steps_total = 0

    def admit(slot):
        rid = queue.pop(0)
        cache1, lens1, last1, _, _ = engine.prefill_batch([prompts[rid]])
        nonlocal pool, tok
        pool = splice_cache(cfg, pool, cache1, slot, slots, pool_seq)
        kv_lens[slot] = int(lens1[0])
        tok = tok.at[slot].set(jnp.argmax(last1[0]).astype(jnp.int32))
        slot_req[slot] = rid
        produced[rid] = 1
        ttft[rid] = time.perf_counter() - t0
        if targets[rid] <= 1:
            completion[rid] = ttft[rid]
            slot_req[slot] = -1

    while queue or (slot_req >= 0).any():
        for s in range(slots):
            if slot_req[s] < 0 and queue:
                admit(s)
        active = slot_req >= 0
        if not active.any():
            continue
        rem = targets[slot_req[active]] - produced[slot_req[active]]
        # queued work pending: stop the chunk at the earliest completion so
        # the freed slot refills without idle decode; empty queue: full chunk
        steps = int(min(chunk, rem.min() if queue else rem.max()))
        steps = max(steps, 1)
        # quantize to powers of two (like Engine.generate) so at most
        # log2(chunk)+1 executables compile per pool size
        if steps < chunk:
            steps = 1 << (steps.bit_length() - 1)
        slot_prod = np.zeros(slots, np.int64)
        slot_targ = np.zeros(slots, np.int64)
        slot_prod[active] = produced[slot_req[active]]
        slot_targ[active] = targets[slot_req[active]]
        pool, tok, kv_d, prod_d, _, _, actives, dt = engine.decode_chunk(
            pool, jnp.asarray(kv_lens.astype(np.int32)), tok,
            jnp.asarray(slot_prod), jnp.asarray(slot_targ), steps)
        steps_total += steps
        kv_lens = np.asarray(kv_d).astype(np.int64)
        prod_np = np.asarray(prod_d)
        actives_np = np.asarray(actives)        # [steps, slots]
        now = time.perf_counter() - t0
        for s in np.where(active)[0]:
            rid = slot_req[s]
            produced[rid] = prod_np[s]
            if produced[rid] >= targets[rid]:
                hit = np.nonzero(actives_np[:, s])[0]
                fin = int(hit[-1]) if hit.size else 0
                completion[rid] = now - dt + dt * (fin + 1) / steps
                slot_req[s] = -1

    return ContinuousResult(
        produced=produced, ttft=ttft, completion=completion,
        decode_steps=steps_total, wall_seconds=time.perf_counter() - t0,
        host_syncs=engine.host_syncs - syncs0)
