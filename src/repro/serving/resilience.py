"""Fault-tolerant fleet serving: replica death, retry, hedging, shedding.

:mod:`repro.serving.router` runs a routed fleet on the assumption that
every replica survives the run.  This module is the degradation path: the
same router + runner machinery, but replicas can DIE mid-run (from a
:mod:`repro.core.faults` model's crash episodes or an explicit
``kill_at`` map), and the scheduler

  * drains the dead replica's backlog — every entry not completed by the
    death epoch (in-flight batch included) is killed,
  * re-dispatches killed work through the EXISTING router with the dead
    replica masked out, at ``epoch + retry_backoff * 2**attempt``
    (exponential backoff, capped at ``max_retries``),
  * hedges requests whose predicted wait exceeds ``hedge_slo`` with a
    duplicate dispatch on the next-best replica — first completion wins,
    the loser is discarded (exactly-once semantics, verified by tests),
  * sheds admission-dropped requests up front (the fault model's drop
    mask plus an explicit ``shed_prob`` drawn on the fault PRNG lane), so
    overload degrades into bounded loss instead of divergence.

Victim selection at a death epoch uses the same work-conserving FCFS
progress proxy as the core driver
(:func:`repro.core.faults.simulate_fleet_faulty`): host-side, router work
units, layer-independent — the real runner executes each replica's FINAL
entry list exactly once, so the engine fleet pays R runs, not R × epochs.

With no fault model, no kill map and ``shed_prob=0`` the scheduler is
bit-equal to the PR 5 fleet path by delegation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import (
    _DROP_LANE, _RETRY_LANE, _fault_rng, fault_from_spec, up_matrix)
from repro.core.fleet import router_from_spec
from repro.core.policies import BatchPolicy, ContinuousPolicy, Workload
from repro.data.pipeline import Request
from repro.serving.scheduler import (
    ModelClock, PolicyScheduler, ScheduleResult, run_engine_schedule)


@dataclasses.dataclass
class ResilienceReport:
    """Fault accounting for one resilient fleet run.  Conservation:
    ``served + shed + failed == arrived`` (hedged duplicates are not
    separate requests — first completion wins, the loser is discarded)."""

    arrived: int
    served: int
    shed: int
    failed: int
    retries: int
    hedged: int
    hedge_wins: int
    kill_events: List[Tuple[float, int]]
    availability: List[float]


@dataclasses.dataclass
class ResilientFleetResult:
    """``FleetScheduleResult``-compatible (``summarize`` consumes it)
    plus the fault accounting.  ``lost`` covers shed + failed requests."""

    waits: np.ndarray
    e2e: np.ndarray
    lost: np.ndarray
    batch_sizes: List[int]
    makespan: float
    replica_of: np.ndarray
    per_replica: List[Optional[ScheduleResult]]
    resilience: ResilienceReport


@dataclasses.dataclass
class _Copy:
    """One dispatch attempt of one request on the serving timeline."""
    req: int
    arrival: float
    attempt: int
    replica: int
    hedge: bool = False


def _death_spans(trace, kill_time: Optional[float],
                 horizon: float) -> List[Tuple[float, float]]:
    """Down intervals of one replica: the fault trace's zero-speed
    episodes plus an explicit kill (dead until past the horizon)."""
    spans = []
    if trace is not None and not trace.empty and trace.speed == 0.0:
        spans += [(float(s), float(e))
                  for s, e in zip(trace.starts, trace.ends)]
    if kill_time is not None:
        spans.append((float(kill_time), horizon * 2.0 + 1.0))
    return sorted(spans)


def scale_spans(schedule: List[Tuple[float, int]], R: int,
                horizon: float) -> List[List[Tuple[float, float]]]:
    """Autoscale schedule -> per-replica down spans.

    ``schedule`` is ``[(t, active), ...]``: from time ``t`` on, replicas
    ``0..active-1`` are in service (before the first entry all ``R``
    are).  Replica ``r`` is DOWN exactly while ``active <= r``, so a
    scale-down is a planned death (the drain/re-dispatch machinery of
    :func:`run_resilient_fleet` applies unchanged: backlog killed at the
    epoch, re-dispatched to surviving replicas with backoff) and a
    replica scaled up at ``t`` is masked out of routing on ``[0, t)``
    and simply receives no work until then.  Power-of-two active counts
    keep the per-replica kernel shapes compile-cached."""
    end = horizon * 2.0 + 1.0
    sched = sorted((float(t), int(a)) for t, a in schedule)
    times = [0.0] + [t for t, _ in sched] + [end]
    active = [R] + [min(max(a, 0), R) for _, a in sched]
    spans: List[List[Tuple[float, float]]] = [[] for _ in range(R)]
    for r in range(R):
        for k, a in enumerate(active):
            if a <= r and times[k] < times[k + 1]:
                if spans[r] and spans[r][-1][1] == times[k]:
                    s, _ = spans[r].pop()
                    spans[r].append((s, times[k + 1]))
                else:
                    spans[r].append((times[k], times[k + 1]))
    return spans


def _up_row(spans_of: List[List[Tuple[float, float]]], t: float
            ) -> np.ndarray:
    up = np.array([not any(s <= t < e for s, e in spans)
                   for spans in spans_of])
    if not up.any():
        # all replicas down: dispatch to the first to recover
        rec = [min((e for s, e in spans if s <= t < e), default=t)
               for spans in spans_of]
        up[int(np.argmin(rec))] = True
    return up


def _fcfs_completion(copies: List[_Copy], work_of: np.ndarray
                     ) -> np.ndarray:
    """Work-conserving FCFS progress proxy: completion time per copy
    (arrival order), victim picker of last resort (no service clock)."""
    arr = np.array([c.arrival for c in copies])
    svc = work_of[[c.req for c in copies]]
    c = np.concatenate(([0.0], np.cumsum(svc[:-1])))
    start = np.maximum.accumulate(arr - c) + c
    return start + svc


def _virtual_completion(policy, clock, reqs, copies: List[_Copy],
                        predicted, predict_seed: int) -> np.ndarray:
    """Completion time per copy from the policy's OWN virtual timeline
    (batch formation included) — the serving layers have no cross-layer
    equality constraint, so the victim picker can afford the real
    discipline.  Impatience abandonments leave the queue at
    ``arrival + tau``; the ragged tail a policy never schedules counts as
    in-queue forever."""
    sub = [dataclasses.replace(reqs[c.req], arrival=c.arrival)
           for c in copies]
    psl = None if predicted is None else \
        predicted[[c.req for c in copies]]
    res = PolicyScheduler(policy, clock, predict_seed=predict_seed).run(
        sub, predicted=psl)
    comp = np.full(len(copies), np.inf)
    arr = np.array([c.arrival for c in copies])
    m = len(res.waits)
    comp[:m] = arr[:m] + np.asarray(res.e2e[:m])
    lost = np.asarray(res.lost[:m], bool)
    comp[:m][lost] = arr[:m][lost] + np.asarray(res.waits[:m])[lost]
    return comp


def run_resilient_fleet(router, policy: BatchPolicy, reqs: List[Request],
                        work_lat, predictor, predict_seed: int, R: int,
                        runner, *, faults=None,
                        kill_at: Optional[Dict[int, float]] = None,
                        seed: int = 0, shed_prob: float = 0.0,
                        hedge_slo: Optional[float] = None,
                        max_retries: Optional[int] = None,
                        retry_backoff: Optional[float] = None,
                        scale_schedule: Optional[List[Tuple[float, int]]] = None,
                        down_spans: Optional[
                            List[List[Tuple[float, float]]]] = None,
                        batch_lat=None, clock=None) -> ResilientFleetResult:
    """The resilient twin of ``repro.serving.router._route_and_dispatch``:
    same router, same global prediction column, same per-replica
    ``runner(replica, sub_reqs, predicted_slice)`` contract — plus death
    handling, retries, hedging and shedding (module docstring).

    ``scale_schedule`` (``[(t, active), ...]``, see :func:`scale_spans`)
    and ``down_spans`` (explicit per-replica ``[(start, end), ...]``)
    overlay planned unavailability on top of fault traces: scale-downs
    drain through the same masked re-dispatch as crashes, scale-ups
    receive no traffic before their start."""
    from repro.serving.scheduler import _request_predictions

    router = router_from_spec(router)
    fault = fault_from_spec(faults)
    n = len(reqs)
    arrivals = np.array([r.arrival for r in reqs], np.float64)
    horizon = float(arrivals[-1]) * 2.0 + 1.0 if n else 1.0
    max_retries = fault.max_retries if max_retries is None else max_retries
    retry_backoff = (fault.retry_backoff if retry_backoff is None
                     else retry_backoff)

    traces = [fault.trace(seed, r, horizon) for r in range(R)]
    kill_at = dict(kill_at or {})
    spans_of = [_death_spans(traces[r], kill_at.get(r), horizon)
                for r in range(R)]
    if scale_schedule is not None:
        planned = scale_spans(list(scale_schedule), R, horizon)
        spans_of = [sorted(spans_of[r] + planned[r]) for r in range(R)]
    if down_spans is not None:
        spans_of = [sorted(spans_of[r] + [(float(s), float(e))
                                          for s, e in down_spans[r]])
                    for r in range(R)]

    # ---- admission shedding ------------------------------------------
    shed = fault.drop_mask(seed, n).copy()
    if shed_prob > 0.0:
        shed |= _fault_rng(seed, _DROP_LANE, 7).random(n) < shed_prob

    # ---- global predictions + routing work (PR 5 path, unchanged) ----
    ns = np.array([policy.clip(r.target_output_tokens) for r in reqs],
                  np.float64)
    predicted = _request_predictions(policy, predictor, predict_seed, ns,
                                     reqs)
    wl = Workload(arrivals=arrivals, tokens=ns, predicted=predicted)
    work = router.routing_work(wl, work_lat, predict_seed,
                               prompts=[r.prompt_tokens for r in reqs])
    adm = np.nonzero(~shed)[0]

    # ---- primary dispatch: availability-masked routing ---------------
    up = np.stack([_up_row(spans_of, float(t)) for t in arrivals[adm]]) \
        if len(adm) else np.ones((0, R), bool)
    from repro.core.faults import masked_assign
    rep = masked_assign(router, arrivals[adm], work[adm], R, predict_seed,
                        up) if len(adm) else np.zeros(0, np.int64)

    by_rep: List[List[_Copy]] = [[] for _ in range(R)]
    backlog = np.zeros(R)
    t_prev = 0.0
    hedged = 0
    # Progress/backlog work units: the amortized per-request batch cost
    # k1 + k3*len when a batch latency law is known (same alpha as the
    # control layer) — the single-request law overstates in-system time
    # by the batch width and would mass-kill on every death epoch.
    from repro.core.latency_model import BatchLatencyModel
    if batch_lat is None and isinstance(work_lat, BatchLatencyModel):
        batch_lat = work_lat
    if batch_lat is not None and not policy.uses_single_latency:
        wu = batch_lat.k1 + batch_lat.k3 * np.asarray(
            wl.predicted_or_true, np.float64)
    elif work_lat is not None:
        wu = router.work_from_lengths(wl.predicted_or_true, work_lat)
    else:
        wu = work
    for i, g in enumerate(adm):
        by_rep[int(rep[i])].append(
            _Copy(int(g), float(arrivals[g]), 0, int(rep[i])))
        # hedging: predicted wait = replica backlog at arrival (Lindley
        # replay of the frozen assignment); over-SLO requests get a
        # duplicate on the least-loaded OTHER up replica
        a = float(arrivals[g])
        backlog = np.maximum(0.0, backlog - (a - t_prev))
        t_prev = a
        if hedge_slo is not None and backlog[int(rep[i])] > hedge_slo:
            alt = np.where(up[i], backlog, np.inf).copy()
            alt[int(rep[i])] = np.inf
            r2 = int(np.argmin(alt))
            if np.isfinite(alt[r2]):
                by_rep[r2].append(_Copy(int(g), a, 0, r2, hedge=True))
                backlog[r2] += wu[g]
                hedged += 1
        backlog[int(rep[i])] += wu[g]

    # ---- death epochs in global time order (drain + re-dispatch) -----
    events = sorted((s, r) for r in range(R) for s, _ in spans_of[r])
    failed: set = set()
    retries = 0
    kill_events: List[Tuple[float, int]] = []
    for f, r in events:
        victims_src = [c for c in by_rep[r] if c.arrival < f]
        if not victims_src:
            continue
        victims_src.sort(key=lambda c: (c.arrival, c.req, c.attempt))
        if clock is not None and not isinstance(policy, ContinuousPolicy):
            comp = _virtual_completion(policy, clock, reqs, victims_src,
                                       predicted, predict_seed)
        else:
            comp = _fcfs_completion(victims_src, wu)
        kill = [c for c, t_c in zip(victims_src, comp) if t_c > f]
        if not kill:
            continue
        kill_events.append((f, r))
        dead = set(id(c) for c in kill)
        by_rep[r] = [c for c in by_rep[r] if id(c) not in dead]
        u = _fault_rng(seed, _RETRY_LANE, int(round(f * 1e6)) % (1 << 31)
                       ).random(len(kill))
        for j, c in enumerate(kill):
            alive = any(x.req == c.req for lst in by_rep for x in lst)
            if alive:
                continue        # hedge twin survives: first-completion-wins
            if c.attempt + 1 > max_retries:
                failed.add(c.req)
                continue
            t_new = f + retry_backoff * (2.0 ** c.attempt) + (j + 1) * 1e-9
            row = _up_row(spans_of, t_new)
            if router.state_dependent:
                flat = [x for lst in by_rep for x in lst]
                flat.sort(key=lambda x: (x.arrival, x.req, x.attempt))
                from repro.core.faults import replay_backlog
                v = replay_backlog(
                    [x.arrival for x in flat],
                    router._work_units(wu[[x.req for x in flat]]),
                    [x.replica for x in flat], R, t=t_new)
                r_new = int(np.argmin(np.where(row, v, np.inf)))
            else:
                cand = np.nonzero(row)[0]
                r_new = int(cand[int(u[j] * len(cand)) % len(cand)])
            by_rep[r_new].append(_Copy(c.req, float(t_new), c.attempt + 1,
                                       r_new, hedge=c.hedge))
            retries += 1

    # ---- one real run per replica on its FINAL entry list ------------
    waits = np.zeros(n)
    e2e = np.zeros(n)
    lost = np.ones(n, bool)
    best_e2e = np.full(n, np.inf)
    win_is_hedge = np.zeros(n, bool)
    replica_of = np.full(n, -1, np.int64)
    sizes: List[int] = []
    makespan = 0.0
    per: List[Optional[ScheduleResult]] = [None] * R
    for r in range(R):
        if not by_rep[r]:
            continue
        by_rep[r].sort(key=lambda c: (c.arrival, c.req, c.attempt))
        sub = [dataclasses.replace(reqs[c.req], arrival=c.arrival)
               for c in by_rep[r]]
        psl = None if predicted is None else \
            predicted[[c.req for c in by_rep[r]]]
        res = runner(r, sub, psl)
        per[r] = res
        sizes += list(res.batch_sizes)
        makespan = max(makespan, res.makespan)
        for i, c in enumerate(by_rep[r][:len(res.waits)]):
            if res.lost[i] or c.req in failed:
                continue
            # shift back to the request's ORIGINAL arrival
            off = c.arrival - float(arrivals[c.req])
            tot = float(res.e2e[i]) + off
            if tot < best_e2e[c.req]:        # first completion wins
                best_e2e[c.req] = tot
                waits[c.req] = float(res.waits[i]) + off
                e2e[c.req] = tot
                lost[c.req] = False
                replica_of[c.req] = r
                win_is_hedge[c.req] = c.hedge

    lost[list(failed)] = True
    lost[shed] = True
    served = int((~lost).sum())
    T = float(arrivals[-1]) if n else 0.0
    report = ResilienceReport(
        arrived=n, served=served, shed=int(shed.sum()),
        failed=int(n - served - int(shed.sum())), retries=retries,
        hedged=hedged, hedge_wins=int(win_is_hedge.sum()),
        kill_events=kill_events,
        availability=[
            1.0 - sum(min(e, T) - min(s, T) for s, e in spans_of[r])
            / max(T, 1e-12) for r in range(R)])
    return ResilientFleetResult(waits, e2e, lost, sizes, makespan,
                                replica_of, per, report)


class ResilientFleetScheduler:
    """Virtual-timeline fleet with the resilience path: the fault-aware
    twin of :class:`repro.serving.router.FleetScheduler`.  Identical
    constructor plus the fault knobs of :func:`run_resilient_fleet`."""

    def __init__(self, router, policy: BatchPolicy, clock: ModelClock,
                 R: int, predictor=None, predict_seed: int = 0, *,
                 faults=None, kill_at: Optional[Dict[int, float]] = None,
                 seed: int = 0, shed_prob: float = 0.0,
                 hedge_slo: Optional[float] = None,
                 max_retries: Optional[int] = None,
                 retry_backoff: Optional[float] = None,
                 scale_schedule: Optional[List[Tuple[float, int]]] = None,
                 down_spans: Optional[
                     List[List[Tuple[float, float]]]] = None):
        assert R >= 1
        self.router = router_from_spec(router)
        self.policy = policy
        self.clock = clock
        self.R = int(R)
        self.predictor = predictor
        self.predict_seed = predict_seed
        self.faults = faults
        self.kill_at = kill_at
        self.seed = seed
        self.shed_prob = shed_prob
        self.hedge_slo = hedge_slo
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.scale_schedule = scale_schedule
        self.down_spans = down_spans

    def run(self, reqs: List[Request]) -> ResilientFleetResult:
        pol = self.policy

        def runner(r, sub, predicted):
            if isinstance(pol, ContinuousPolicy):
                return pol.scheduler(self.clock).run(sub)
            return PolicyScheduler(pol, self.clock,
                                   predict_seed=self.predict_seed).run(
                sub, predicted=predicted)

        return run_resilient_fleet(
            self.router, pol, reqs, getattr(self.clock, "single", None),
            self.predictor, self.predict_seed, self.R, runner,
            faults=self.faults, kill_at=self.kill_at, seed=self.seed,
            shed_prob=self.shed_prob, hedge_slo=self.hedge_slo,
            max_retries=self.max_retries, retry_backoff=self.retry_backoff,
            scale_schedule=self.scale_schedule, down_spans=self.down_spans,
            batch_lat=getattr(self.clock, "batch", None),
            clock=self.clock if isinstance(self.clock, ModelClock) else None)


def run_resilient_engine_fleet(router, policy: BatchPolicy, engines,
                               reqs: List[Request],
                               R: Optional[int] = None, lat=None,
                               predictor=None, predict_seed: int = 0,
                               **fault_kw) -> ResilientFleetResult:
    """Engine-layer resilient fleet: the fault-aware twin of
    :func:`repro.serving.router.run_fleet_schedule` — each replica's
    FINAL entry list (post kill/retry/hedge) runs on a real engine."""
    if isinstance(engines, (list, tuple)):
        engine_of = list(engines)
        if R is None:
            R = len(engine_of)
        assert R == len(engine_of)
    else:
        assert R is not None and R >= 1, "pass R with a single shared engine"
        engine_of = [engines] * R

    def runner(r, sub, predicted):
        return run_engine_schedule(policy, engine_of[r], sub,
                                   predict_seed=predict_seed,
                                   predicted=predicted)

    # victim selection can use the calibrated virtual timeline when a
    # batch latency law is supplied (the engine only runs the FINAL lists)
    from repro.core.latency_model import BatchLatencyModel
    clock = None
    if isinstance(lat, BatchLatencyModel):
        from repro.core.policies import single_from_batch
        clock = ModelClock(single_from_batch(lat), lat)
    return run_resilient_fleet(router, policy, reqs, lat, predictor,
                               predict_seed, R, runner, clock=clock,
                               **fault_kw)


__all__ = ["ResilienceReport", "ResilientFleetResult",
           "ResilientFleetScheduler", "run_resilient_engine_fleet",
           "run_resilient_fleet"]
