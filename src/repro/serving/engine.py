"""Batched serving engine (TPU-style static-bucket execution).

XLA wants static shapes, so the engine compiles one executable per
(batch-bucket, seq-bucket) pair and routes work to the smallest bucket that
fits — the TPU adaptation of GPU dynamic batching (DESIGN.md §3). Elastic
batching gets its *real* speedup from bucket compaction: when enough replies
finish early, the live requests are gathered into the next-smaller batch
bucket and decoding continues there (the kernel-level analogue is the ragged
decode kernel in repro.kernels).

The engine serves two roles:
  * run actual tiny models on CPU (examples, wall-clock calibration of the
    paper's a, c, k1..k4 constants),
  * expose per-step timing hooks the schedulers use to drive policy
    experiments on a virtual clock at paper scale.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.config import ModelConfig
from repro.models.model import (
    param_specs, init_cache, prefill, decode_step)
from repro.models.params import init_params


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 16            # largest batch bucket (power of 2)
    max_seq: int = 512             # KV capacity per slot
    prompt_bucket: int = 64        # prompts padded to a multiple of this
    cache_dtype: str = "float32"
    greedy: bool = True
    min_bucket: int = 1


def _bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < n and b < hi:
        b *= 2
    return min(b, hi)


class Engine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 params=None, seed: int = 0, ctx: ShardCtx = NULL_CTX):
        self.cfg = cfg
        self.ecfg = ecfg
        self.ctx = ctx
        if params is None:
            params = init_params(param_specs(cfg), jax.random.PRNGKey(seed),
                                 jnp.float32)
        self.params = params
        self._prefill_fns: Dict[Tuple[int, int], callable] = {}
        self._decode_fns: Dict[int, callable] = {}
        self.step_log: List[dict] = []    # (kind, batch, seq, seconds)

    # ------------------------------------------------------------------
    def _get_prefill(self, b: int, s: int):
        key = (b, s)
        if key not in self._prefill_fns:
            cfg, ctx = self.cfg, self.ctx

            def fn(params, cache, tokens, prompt_lens):
                return prefill(cfg, params, tokens, cache=cache,
                               prompt_lens=prompt_lens, ctx=ctx)

            self._prefill_fns[key] = jax.jit(fn, donate_argnums=(1,))
        return self._prefill_fns[key]

    def _get_decode(self, b: int):
        if b not in self._decode_fns:
            cfg, ctx = self.cfg, self.ctx

            def fn(params, cache, tokens, kv_lens):
                return decode_step(cfg, params, cache, tokens, kv_lens, ctx=ctx)

            self._decode_fns[b] = jax.jit(fn, donate_argnums=(1,))
        return self._decode_fns[b]

    def new_cache(self, batch_bucket: int):
        return init_cache(self.cfg, batch_bucket, self.ecfg.max_seq,
                          jnp.dtype(self.ecfg.cache_dtype))

    # ------------------------------------------------------------------
    def prefill_batch(self, prompts: List[np.ndarray]):
        """Pad to buckets, run prefill. Returns (cache, kv_lens, last_logits,
        batch_bucket, wall_seconds)."""
        b = _bucket(len(prompts), self.ecfg.min_bucket, self.ecfg.max_batch)
        max_p = max(len(p) for p in prompts)
        s = min(_bucket(max_p, self.ecfg.prompt_bucket, self.ecfg.max_seq),
                self.ecfg.max_seq)
        tokens = np.zeros((b, s), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, :len(p)] = p[:s]
            lens[i] = min(len(p), s)
        lens = np.maximum(lens, 1)
        cache = self.new_cache(b)
        fn = self._get_prefill(b, s)
        t0 = time.perf_counter()
        last, cache = fn(self.params, cache, jnp.asarray(tokens),
                         jnp.asarray(lens))
        last = jax.block_until_ready(last)
        dt = time.perf_counter() - t0
        self.step_log.append(
            {"kind": "prefill", "batch": b, "seq": s, "seconds": dt})
        return cache, jnp.asarray(lens), last, b, dt

    def decode_batch(self, cache, kv_lens, tokens):
        """One decode step for the whole bucket. Returns (next_tokens, cache,
        wall_seconds)."""
        b = int(tokens.shape[0])
        fn = self._get_decode(b)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, cache, tokens, kv_lens)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.step_log.append(
            {"kind": "decode", "batch": b, "seq": int(jnp.max(kv_lens)),
             "seconds": dt})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache, dt

    def compact(self, cache, kv_lens, tokens, keep_idx: np.ndarray):
        """Gather live slots into a smaller bucket (elastic batching's real
        speedup on TPU)."""
        nb = _bucket(len(keep_idx), self.ecfg.min_bucket, self.ecfg.max_batch)
        idx = np.zeros((nb,), np.int32)
        idx[:len(keep_idx)] = keep_idx
        gidx = jnp.asarray(idx)
        cache = jax.tree.map(
            lambda leaf: leaf[:, gidx] if leaf.ndim >= 2 else leaf, cache)
        return (cache, kv_lens[gidx], tokens[gidx], nb,
                int(len(keep_idx)))

    # ------------------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], target_tokens: List[int],
                 elastic: bool = False, n_max: Optional[int] = None):
        """Run one batch to completion.

        Padded ('dynamic') mode decodes everyone for max(target) steps (the
        paper's padding semantics). Elastic mode lets finished replies exit
        and compacts buckets. Returns dict with per-request completion times
        (seconds of engine wall time after batch start) and token counts.
        """
        targets = np.asarray(target_tokens)
        if n_max is not None:
            targets = np.minimum(targets, n_max)
        nreq = len(prompts)
        cache, kv_lens, last, b, t_prefill = self.prefill_batch(prompts)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        live = np.arange(nreq)
        produced = np.ones(nreq, np.int64)    # first token from prefill
        done_at = np.full(nreq, np.nan)
        clock = t_prefill
        done_at[targets <= 1] = clock
        l_max = int(targets.max())
        for _ in range(1, l_max):
            if elastic:
                still = live[targets[live] > produced[live]]
                if len(still) == 0:
                    break
                if len(still) <= b // 2 and b > self.ecfg.min_bucket:
                    # map global ids to current slot ids
                    slot_of = {g: i for i, g in enumerate(live)}
                    keep = np.array([slot_of[g] for g in still], np.int32)
                    cache, kv_lens, tok, b, _ = self.compact(
                        cache, kv_lens, tok, keep)
                    live = still
            else:
                if np.all(produced >= targets):
                    break
            tok, cache, dt = self.decode_batch(cache, kv_lens, tok)
            kv_lens = jnp.minimum(kv_lens + 1, self.ecfg.max_seq - 1)
            clock += dt
            active = live[produced[live] < targets[live]]
            produced[active] += 1
            newly = active[produced[active] == targets[active]]
            done_at[newly] = clock
        done_at[np.isnan(done_at)] = clock
        if not elastic:
            # padded semantics (paper Eq 18): the whole batch is returned
            # when its longest member completes
            done_at[:] = clock
        return {
            "completion_seconds": done_at,
            "batch_seconds": clock,
            "produced": produced,
            "prefill_seconds": t_prefill,
        }

    # ------------------------------------------------------------------
    def calibration_log(self) -> dict:
        """Measurements for fitting the paper's latency constants."""
        pre = [(e["batch"], e["seq"], e["seconds"])
               for e in self.step_log if e["kind"] == "prefill"]
        dec = [(e["batch"], e["seconds"])
               for e in self.step_log if e["kind"] == "decode"]
        return {"prefill": pre, "decode": dec}
