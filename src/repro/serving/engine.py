"""Batched serving engine (TPU-style static-bucket execution).

XLA wants static shapes, so the engine compiles one executable per
(batch-bucket, seq-bucket) pair and routes work to the smallest bucket that
fits — the TPU adaptation of GPU dynamic batching (DESIGN.md §3). Elastic
batching gets its *real* speedup from bucket compaction: when enough replies
finish early, the live requests are gathered into the next-smaller batch
bucket and decoding continues there (the kernel-level analogue is the ragged
decode kernel in repro.kernels).

Host-sync accounting (the chunked-decode design)
------------------------------------------------
Decoding is driven by ``decode_chunk``: a ``jax.lax.scan`` of up to
``EngineConfig.decode_chunk`` decode steps compiled once per
(batch-bucket, step-count) pair. The carry — ``(cache, tok, kv_lens,
produced, per-slot sampling keys)`` — lives on device for the whole chunk, so the host blocks once
per chunk instead of once per token: O(tokens / chunk) syncs instead of
O(tokens). Each sync is counted in ``Engine.host_syncs`` and each chunk is
logged in ``step_log``; ``generate`` reports the syncs it spent so the
benchmark suite can assert the accounting. Elastic bucket compaction and
completion bookkeeping happen at chunk boundaries (per-request completion
times are interpolated inside a chunk from the per-step active mask the scan
emits). Compaction itself is device-resident by default
(``EngineConfig.compact_impl="fused"``): one jitted call around the Pallas
gather kernel in ``repro.kernels.compaction``, keep indices derived in-jit
from the chunk's produced/targets carry — zero host syncs per compaction
event. ``compact_impl="host"`` keeps the reference path (host keep indices,
per-leaf eager gathers) and counts one host-visible event per compaction.
``decode_batch`` (one step, one sync) is kept as the reference path
— ``generate(..., chunk=1)`` reproduces it step for step.

The engine serves two roles:
  * run actual tiny models on CPU (examples, wall-clock calibration of the
    paper's a, c, k1..k4 constants),
  * expose per-step timing hooks the schedulers use to drive policy
    experiments on a virtual clock at paper scale.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.config import ModelConfig
from repro.models.model import (
    param_specs, init_cache, prefill, decode_step, stack_group_cache)
from repro.models.params import init_params


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 16            # largest batch bucket (power of 2)
    max_seq: int = 512             # KV capacity per slot
    prompt_bucket: int = 64        # prompts padded to a multiple of this
    cache_dtype: str = "float32"
    greedy: bool = True
    min_bucket: int = 1
    decode_chunk: int = 32         # decode steps fused per host sync
    temperature: float = 0.0       # 0 -> greedy argmax decoding
    top_k: Optional[int] = None    # sample from the k best logits only
    # elastic bucket compaction implementation:
    #   fused - one jitted call around the Pallas gather kernel
    #           (repro.kernels.compaction); keep indices derived on device
    #           from the chunk's produced/targets counters, zero host syncs
    #   host  - reference path: host-resident keep indices + per-leaf eager
    #           gathers (one host-visible event per compaction)
    compact_impl: str = "fused"
    # KV-token budget for one engine (repro.core.memory two-resource
    # model): generate() refuses a batch whose worst-case footprint
    # (prompt + target tokens per member) exceeds it, and tracks the
    # realized occupancy from the live kv_lens at chunk boundaries
    # (Engine.kv_report).  None = unconstrained.
    kv_budget: Optional[int] = None


def _bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < n and b < hi:
        b *= 2
    return min(b, hi)


def _guard_logits(logits):
    """Per-slot non-finite guard: ``bad[b]`` is True when the slot's
    logits contain NaN/inf (one poisoned request), ``safe`` replaces
    non-finite entries with -inf so argmax/categorical stay defined.
    Finite logits pass through bit-identical."""
    finite = jnp.isfinite(logits)
    bad = ~jnp.all(finite, axis=-1)
    return jnp.where(finite, logits, -jnp.inf), bad


def _guarded_argmax(logits):
    """Greedy decode over guarded logits; returns (tokens, bad mask)."""
    safe, bad = _guard_logits(logits)
    return jnp.argmax(safe, axis=-1).astype(jnp.int32), bad


def _sample_tokens(keys, logits, temperature: float, top_k: Optional[int]):
    """Temperature / top-k sampling over [b, vocab] logits with one PRNG
    key PER SLOT (``keys``: [b, 2]); temperature is a trace-time constant
    and temperature=0 callers use argmax instead.  Sampling per slot from
    its own key — rather than one batch-wide key the categorical splits
    internally by row — is what makes sampled streams independent of the
    batch bucket a request happens to occupy.

    Slots with non-finite logits fall back to greedy over the guarded
    logits (the categorical is undefined there) and are reported in the
    returned ``bad`` mask; finite slots sample bit-identically to the
    unguarded path.  Returns (tokens, bad)."""
    safe, bad = _guard_logits(logits)
    greedy = jnp.argmax(safe, axis=-1).astype(jnp.int32)
    if top_k is not None:
        kth = jax.lax.top_k(safe, top_k)[0][..., -1:]
        safe = jnp.where(safe < kth, -jnp.inf, safe)
    sampled = jax.vmap(
        lambda k, l: jax.random.categorical(k, l / temperature, axis=-1)
    )(keys, safe).astype(jnp.int32)
    return jnp.where(bad, greedy, sampled), bad


def _split_slot_keys(keys):
    """Advance every slot's key one step: returns (carried, subkeys)."""
    split = jax.vmap(jax.random.split)(keys)
    return split[:, 0], split[:, 1]


class Engine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 params=None, seed: int = 0, ctx: ShardCtx = NULL_CTX):
        self.cfg = cfg
        self.ecfg = ecfg
        self.ctx = ctx
        if params is None:
            params = init_params(param_specs(cfg), jax.random.PRNGKey(seed),
                                 jnp.float32)
        self.params = params
        self._prefill_fns: Dict[Tuple[int, int], callable] = {}
        self._decode_fns: Dict[int, callable] = {}
        self._chunk_fns: Dict[tuple, callable] = {}
        self.step_log: List[dict] = []    # (kind, batch, seq, seconds[, steps])
        self.host_syncs = 0               # device->host blocking round-trips
        self.sample_fallbacks = 0         # non-finite-logit greedy fallbacks
        self.kv_peak = 0                  # max live KV tokens observed
        self._sample_key = jax.random.PRNGKey(seed)   # decode sampling stream

    # ------------------------------------------------------------------
    def _get_prefill(self, b: int, s: int):
        key = (b, s)
        if key not in self._prefill_fns:
            cfg, ctx = self.cfg, self.ctx

            def fn(params, cache, tokens, prompt_lens):
                return prefill(cfg, params, tokens, cache=cache,
                               prompt_lens=prompt_lens, ctx=ctx)

            self._prefill_fns[key] = jax.jit(fn, donate_argnums=(1,))
        return self._prefill_fns[key]

    def _get_decode(self, b: int):
        if b not in self._decode_fns:
            cfg, ctx = self.cfg, self.ctx

            def fn(params, cache, tokens, kv_lens):
                return decode_step(cfg, params, cache, tokens, kv_lens, ctx=ctx)

            self._decode_fns[b] = jax.jit(fn, donate_argnums=(1,))
        return self._decode_fns[b]

    def _get_decode_chunk(self, b: int, steps: int, temperature: float = 0.0,
                          top_k: Optional[int] = None):
        """Fused multi-step decode: ``steps`` decode iterations as one
        ``lax.scan``, carrying (cache, tok, kv_lens, produced, per-slot
        keys) device-side.

        PER-SLOT PRNG keys (``[b, 2]``) ride the scan carry and each slot
        splits its OWN key once per step, so temperature/top-k sampling
        inside the fused chunk consumes per-request key streams that are
        invariant to both chunk size AND batch composition: chunk=1 and
        chunk=N produce identical samples, and a request gathered into a
        smaller bucket by elastic compaction keeps its key and therefore
        its stream (the keys are gathered alongside the cache in
        ``compact``).  ``temperature=0`` (the default) is greedy argmax
        and never touches the keys.

        Emits the per-step sampled token and active mask so the caller can
        reconstruct exact token streams / completion steps after the single
        end-of-chunk sync. ``kv_lens`` advances only for slots still below
        their target (except in 'uniform' cache-update mode, which requires
        lock-step positions), so early-exited slots stop moving their ring
        pointer; with the ragged decode-attention kernel they also stop
        paying padded KV compute.
        """
        key = (b, steps, float(temperature), top_k)
        if key not in self._chunk_fns:
            cfg, ctx = self.cfg, self.ctx
            max_seq = self.ecfg.max_seq
            advance_all = cfg.decode_cache_update == "uniform"

            def fn(params, cache, tok, kv_lens, produced, targets, keys):
                def body(carry, _):
                    cache, tok, kv_lens, produced, keys = carry
                    logits, cache = decode_step(cfg, params, cache, tok,
                                                kv_lens, ctx=ctx)
                    if cfg.decode_unroll_layers:
                        # unrolled decode returns a per-group split dict;
                        # restack so the scan carry keeps one structure
                        cache = stack_group_cache(cache, cfg.num_groups)
                    if temperature > 0.0:
                        keys, subs = _split_slot_keys(keys)
                        nxt, bad = _sample_tokens(subs, logits, temperature,
                                                  top_k)
                    else:
                        nxt, bad = _guarded_argmax(logits)
                    active = produced < targets
                    produced = produced + active.astype(produced.dtype)
                    step = (jnp.ones_like(kv_lens) if advance_all
                            else active.astype(kv_lens.dtype))
                    kv_lens = jnp.minimum(kv_lens + step, max_seq - 1)
                    nbad = jnp.sum((bad & active).astype(jnp.int32))
                    return (cache, nxt, kv_lens, produced, keys), \
                        (nxt, active, nbad)

                carry, (toks, actives, nbads) = lax.scan(
                    body, (cache, tok, kv_lens, produced, keys), None,
                    length=steps)
                cache, tok, kv_lens, produced, keys = carry
                return (cache, tok, kv_lens, produced, keys, toks, actives,
                        jnp.sum(nbads))

            self._chunk_fns[key] = jax.jit(fn, donate_argnums=(1,))
        return self._chunk_fns[key]

    def new_cache(self, batch_bucket: int):
        return init_cache(self.cfg, batch_bucket, self.ecfg.max_seq,
                          jnp.dtype(self.ecfg.cache_dtype))

    # ------------------------------------------------------------------
    def prefill_batch(self, prompts: List[np.ndarray]):
        """Pad to buckets, run prefill. Returns (cache, kv_lens, last_logits,
        batch_bucket, wall_seconds)."""
        b = _bucket(len(prompts), self.ecfg.min_bucket, self.ecfg.max_batch)
        max_p = max(len(p) for p in prompts)
        s = min(_bucket(max_p, self.ecfg.prompt_bucket, self.ecfg.max_seq),
                self.ecfg.max_seq)
        tokens = np.zeros((b, s), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, :len(p)] = p[:s]
            lens[i] = min(len(p), s)
        lens = np.maximum(lens, 1)
        cache = self.new_cache(b)
        fn = self._get_prefill(b, s)
        t0 = time.perf_counter()
        last, cache = fn(self.params, cache, jnp.asarray(tokens),
                         jnp.asarray(lens))
        last = jax.block_until_ready(last)
        dt = time.perf_counter() - t0
        self.host_syncs += 1
        self.step_log.append(
            {"kind": "prefill", "batch": b, "seq": s, "seconds": dt})
        return cache, jnp.asarray(lens), last, b, dt

    def decode_batch(self, cache, kv_lens, tokens):
        """One decode step for the whole bucket (one host sync). Returns
        (next_tokens, cache, wall_seconds). Reference path for the fused
        ``decode_chunk``."""
        b = int(tokens.shape[0])
        fn = self._get_decode(b)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, cache, tokens, kv_lens)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.host_syncs += 1
        self.step_log.append(
            {"kind": "decode", "batch": b, "seq": int(jnp.max(kv_lens)),
             "seconds": dt})
        nxt, bad = _guarded_argmax(logits)
        self.sample_fallbacks += int(jnp.sum(bad))
        return nxt, cache, dt

    def decode_chunk(self, cache, kv_lens, tokens, produced, targets,
                     steps: int, temperature: float = 0.0,
                     top_k: Optional[int] = None, slot_keys=None):
        """Run ``steps`` fused decode iterations (one host sync). All array
        args/results are device-side; returns (cache, tok, kv_lens, produced,
        slot_keys, step_tokens [steps,B], step_active [steps,B],
        wall_seconds).  ``slot_keys`` ([B, 2], one PRNG key per slot) ride
        the scan carry and each slot splits its own key once per decode
        step — sampled streams are invariant to chunking AND to which
        bucket/slot a request occupies (pass the gathered keys after
        elastic compaction, and thread the returned keys into the next
        chunk, as ``generate`` does).  ``slot_keys=None`` with
        ``temperature>0`` falls back to fresh per-slot keys forked off
        the advancing engine stream (``Engine._sample_key``) — still
        well-distributed randomness per call, but only threading the keys
        gives cross-chunk stream invariance; greedy callers get dummy
        zeros (never consumed)."""
        b = int(tokens.shape[0])
        if slot_keys is None:
            if temperature > 0.0:
                self._sample_key, base = jax.random.split(self._sample_key)
                slot_keys = jax.vmap(
                    lambda i: jax.random.fold_in(base, i))(jnp.arange(b))
            else:
                slot_keys = jnp.zeros((b, 2), jnp.uint32)
        fn = self._get_decode_chunk(b, steps, temperature, top_k)
        t0 = time.perf_counter()
        cache, tok, kv_lens, produced, slot_keys, toks, actives, nbad = fn(
            self.params, cache, tokens, kv_lens, produced, targets,
            slot_keys)
        tok = jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        self.host_syncs += 1
        self.sample_fallbacks += int(nbad)
        self.step_log.append(
            {"kind": "decode_chunk", "batch": b, "steps": steps,
             "seq": int(jnp.max(kv_lens)), "seconds": dt})
        return cache, tok, kv_lens, produced, slot_keys, toks, actives, dt

    def compact(self, cache, kv_lens, tokens, keep_idx: np.ndarray,
                slot_keys=None):
        """Gather live slots into a smaller bucket (elastic batching's real
        speedup on TPU) — HOST reference path: the keep indices live on
        host and each cache leaf's gather is dispatched eagerly, so every
        compaction is one host-visible event (counted in ``host_syncs``
        and ``step_log``).  ``compact_fused`` is the device-resident twin
        the engine runs by default.  ``slot_keys`` are gathered alongside
        so each surviving request keeps its own sampling stream."""
        nb = _bucket(len(keep_idx), self.ecfg.min_bucket, self.ecfg.max_batch)
        idx = np.zeros((nb,), np.int32)
        idx[:len(keep_idx)] = keep_idx
        gidx = jnp.asarray(idx)
        cache = jax.tree.map(
            lambda leaf: leaf[:, gidx] if leaf.ndim >= 2 else leaf, cache)
        keys = None if slot_keys is None else slot_keys[gidx]
        self.host_syncs += 1
        self.step_log.append(
            {"kind": "compact", "impl": "host", "batch": nb, "syncs": 1})
        return (cache, kv_lens[gidx], tokens[gidx], nb,
                int(len(keep_idx)), keys)

    def compact_fused(self, cache, kv_lens, tokens, produced, targets,
                      n_live: int, slot_keys=None):
        """Device-resident compaction (``EngineConfig.compact_impl=
        "fused"``): ONE jitted call around the scalar-prefetch Pallas
        gather kernel (:mod:`repro.kernels.compaction`).  The keep indices
        are derived ON DEVICE from the chunk's ``produced``/``targets``
        carry (live iff ``produced < targets`` — bit-identical to the host
        path's ``still`` selection), so nothing crosses the host boundary
        and ``host_syncs`` per compaction event is zero.  Only the bucket
        size ``nb`` is a host decision (static shapes), made from counts
        the chunk-boundary sync already paid for.  Bit-equal to
        :meth:`compact` — including the gathered per-slot PRNG keys, so
        sampled streams stay invariant to compaction (PR 4 guarantee)."""
        from repro.kernels.compaction import fused_compact
        nb = _bucket(n_live, self.ecfg.min_bucket, self.ecfg.max_batch)
        cache, kv_lens, tokens, keys, _ = fused_compact(
            cache, kv_lens, tokens, slot_keys, produced, targets, nb=nb)
        self.step_log.append(
            {"kind": "compact", "impl": "fused", "batch": nb, "syncs": 0})
        return cache, kv_lens, tokens, nb, keys

    # ------------------------------------------------------------------
    def _track_kv(self, kv_lens, nlive: int) -> int:
        """Record live KV occupancy (sum of kv_lens over occupied slots —
        the REAL tokens pinned in the cache, not the worst case)."""
        live_kv = int(np.asarray(kv_lens)[:nlive].sum())
        if live_kv > self.kv_peak:
            self.kv_peak = live_kv
        return live_kv

    def kv_report(self) -> dict:
        """Realized KV occupancy vs the configured budget (the engine-layer
        twin of the simulator's ``memory`` block)."""
        cap = self.ecfg.kv_budget
        return {
            "kv_budget": cap,
            "kv_peak": int(self.kv_peak),
            "utilization": (self.kv_peak / cap) if cap else 0.0,
        }

    # ------------------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], target_tokens: List[int],
                 elastic: bool = False, n_max: Optional[int] = None,
                 chunk: Optional[int] = None, return_tokens: bool = False,
                 temperature: Optional[float] = None,
                 top_k: Optional[int] = None, seed: Optional[int] = None):
        """Run one batch to completion on the fused chunked-decode loop.

        Padded ('dynamic') mode decodes everyone for max(target) steps (the
        paper's padding semantics). Elastic mode lets finished replies exit
        and compacts buckets at chunk boundaries. ``chunk`` overrides
        ``EngineConfig.decode_chunk`` (chunk=1 == the per-step reference
        loop; larger chunks produce identical tokens with O(tokens/chunk)
        host syncs). ``temperature``/``top_k`` override the EngineConfig
        sampling settings (temperature 0 == greedy, the default).  Each
        request gets its OWN sampling key (``fold_in`` of the batch base
        key by request index) carried per-slot through the fused scan and
        gathered on compaction, so for a given ``seed`` sampled tokens are
        invariant to chunk size AND to elastic bucket compaction — padded
        and elastic runs emit identical streams per request. Returns dict
        with per-request completion times (seconds of engine wall time
        after batch start) and token counts.
        """
        chunk = int(chunk if chunk is not None else self.ecfg.decode_chunk)
        assert chunk >= 1
        temperature = float(self.ecfg.temperature if temperature is None
                            else temperature)
        top_k = self.ecfg.top_k if top_k is None else top_k
        if seed is not None:
            self._sample_key = jax.random.PRNGKey(seed)
        targets = np.asarray(target_tokens)
        if n_max is not None:
            targets = np.minimum(targets, n_max)
        nreq = len(prompts)
        if self.ecfg.kv_budget is not None:
            worst = int(sum(min(len(p), self.ecfg.max_seq) + int(t)
                            for p, t in zip(prompts, targets)))
            if worst > self.ecfg.kv_budget:
                raise ValueError(
                    f"batch worst-case KV footprint {worst} exceeds "
                    f"kv_budget {self.ecfg.kv_budget}; cap the batch "
                    "upstream (memory-gated admission) or raise the budget")
        syncs0 = self.host_syncs
        cache, kv_lens, last, b, t_prefill = self.prefill_batch(prompts)
        self._track_kv(kv_lens, nreq)
        slot_keys = None
        if temperature > 0.0:
            # one key per REQUEST (slot i holds request i right after
            # prefill); padding slots get keys too, but never emit tokens
            self._sample_key, base = jax.random.split(self._sample_key)
            slot_keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
                jnp.arange(b))
            slot_keys, subs = _split_slot_keys(slot_keys)
            tok, bad0 = _sample_tokens(subs, last, temperature, top_k)
        else:
            tok, bad0 = _guarded_argmax(last)
        self.sample_fallbacks += int(jnp.sum(bad0[:nreq]))
        live = np.arange(nreq)
        produced = np.ones(nreq, np.int64)    # first token from prefill
        done_at = np.full(nreq, np.nan)
        clock = t_prefill
        done_at[targets <= 1] = clock
        out_tokens = ([list(t) for t in
                       np.asarray(tok)[:nreq, None]] if return_tokens
                      else None)

        def slot_state(bucket, ids):
            prod = np.zeros(bucket, np.int64)
            targ = np.zeros(bucket, np.int64)
            prod[:len(ids)] = produced[ids]
            targ[:len(ids)] = targets[ids]
            return jnp.asarray(prod), jnp.asarray(targ)

        prod_d = targ_d = None      # device twins of the slot counters
        while True:
            rem = targets[live] - produced[live]
            if elastic:
                still = live[rem > 0]
                if len(still) == 0:
                    break
                if len(still) <= b // 2 and b > self.ecfg.min_bucket:
                    if self.ecfg.compact_impl == "fused":
                        # device-resident keep: the produced/targets carry
                        # from the last chunk (or a fresh upload right
                        # after prefill) selects the live slots in-jit —
                        # zero additional host syncs
                        if prod_d is None:
                            prod_d, targ_d = slot_state(b, live)
                        cache, kv_lens, tok, b, slot_keys = \
                            self.compact_fused(cache, kv_lens, tok, prod_d,
                                               targ_d, len(still), slot_keys)
                    else:
                        # host reference path: map global ids to slot ids
                        slot_of = {g: i for i, g in enumerate(live)}
                        keep = np.array([slot_of[g] for g in still], np.int32)
                        cache, kv_lens, tok, b, _, slot_keys = self.compact(
                            cache, kv_lens, tok, keep, slot_keys)
                    live = still
                    rem = targets[live] - produced[live]
                    prod_d = targ_d = None   # stale after re-bucketing
            else:
                if np.all(produced >= targets):
                    break
            # quantize tail chunks to powers of two: produced counts gate
            # every step, so shorter chunks never change tokens, and this
            # bounds the executable count at log2(chunk) per bucket
            rem_max = int(rem.max())
            steps = chunk if rem_max >= chunk else 1 << (rem_max.bit_length() - 1)
            prod_d, targ_d = slot_state(b, live)     # also feeds compaction
            cache, tok, kv_lens, prod_d, slot_keys, toks, actives, dt = \
                self.decode_chunk(cache, kv_lens, tok, prod_d, targ_d, steps,
                                  temperature=temperature, top_k=top_k,
                                  slot_keys=slot_keys)
            self._track_kv(kv_lens, len(live))
            clock += dt
            actives_np = np.asarray(actives)            # [steps, b]
            produced[live] = np.asarray(prod_d)[:len(live)]
            if return_tokens:
                toks_np = np.asarray(toks)
                for s, g in enumerate(live):
                    out_tokens[g].extend(
                        toks_np[actives_np[:, s], s].tolist())
            newly = live[(produced[live] >= targets[live])
                         & np.isnan(done_at[live])]
            slot_of = {g: i for i, g in enumerate(live)}
            for g in newly:
                hit = np.nonzero(actives_np[:, slot_of[g]])[0]
                fin = int(hit[-1]) if hit.size else 0
                # completion interpolated at that step's chunk fraction
                done_at[g] = clock - dt + dt * (fin + 1) / steps
        done_at[np.isnan(done_at)] = clock
        if not elastic:
            # padded semantics (paper Eq 18): the whole batch is returned
            # when its longest member completes
            done_at[:] = clock
        res = {
            "completion_seconds": done_at,
            "batch_seconds": clock,
            "produced": produced,
            "prefill_seconds": t_prefill,
            "host_syncs": self.host_syncs - syncs0,
        }
        if return_tokens:
            res["tokens"] = out_tokens
        return res

    # ------------------------------------------------------------------
    def calibration_log(self) -> dict:
        """Measurements for fitting the paper's latency constants. Chunked
        decode entries are normalized to per-step seconds so the k3/k4 fit
        is chunk-size independent."""
        pre = [(e["batch"], e["seq"], e["seconds"])
               for e in self.step_log if e["kind"] == "prefill"]
        dec = [(e["batch"], e["seconds"])
               for e in self.step_log if e["kind"] == "decode"]
        dec += [(e["batch"], e["seconds"] / e["steps"])
                for e in self.step_log if e["kind"] == "decode_chunk"]
        return {"prefill": pre, "decode": dec}
