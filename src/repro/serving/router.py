"""Fleet serving layer: a RoutingPolicy in front of R replica schedulers.

:mod:`repro.core.fleet` defines *what* a router is (assignment as a
function of arrivals + predicted work, never of replica service state) and
validates it on the simulator layers; this module runs the same routers on
the request-list layers:

  * :class:`FleetScheduler` — the virtual-timeline fleet: route a request
    list, then drive R independent :class:`~repro.serving.scheduler.
    PolicyScheduler` timelines (one per replica, any registered
    ``BatchPolicy``) and merge the results back into global request order.
  * :func:`run_fleet_schedule` — the engine fleet: each replica's batches
    execute on a REAL engine (one :class:`~repro.serving.engine.Engine`
    per replica, or one engine shared across replica-tagged batches —
    replica timelines are virtual, so wall-clock batch durations compose
    either way).

Both resolve the predicted-length column ONCE for the whole fleet
(:func:`repro.core.predictors.resolve_predictions` — the same shared
resolver the single-server scheduler and engine layers use) and hand each
replica its slice, so routing (``least_work`` backlogs) and membership
(SRPT ordering, multi-bin routing) see ONE consistent set of predictions.

:func:`summarize_fleet` reports aggregate + per-replica serving metrics.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

import numpy as np

from repro.core.fleet import router_from_spec
from repro.core.policies import BatchPolicy, ContinuousPolicy, Workload
from repro.data.pipeline import Request
from repro.serving.metrics import summarize
from repro.serving.scheduler import (
    ModelClock, PolicyScheduler, ScheduleResult, _request_predictions,
    run_engine_schedule)


@dataclasses.dataclass
class FleetScheduleResult:
    """ScheduleResult-compatible aggregate (``summarize`` consumes it
    directly) plus the routing decomposition.  Requests a replica's policy
    never serves (fixed batching's ragged tail) are marked ``lost``."""

    waits: np.ndarray            # global request order
    e2e: np.ndarray
    lost: np.ndarray
    batch_sizes: List[int]
    makespan: float              # latest replica makespan
    replica_of: np.ndarray
    per_replica: List[ScheduleResult]


def _fleet_predictions(policy, predictor, predict_seed: int,
                       ns: np.ndarray, reqs: List[Request]):
    """(membership predictions, the routing view of the stream): both
    drawn once, globally — a router's own predictor (if any) overrides
    only its work estimate inside ``routing_work``, never the membership
    column."""
    predicted = _request_predictions(policy, predictor, predict_seed, ns,
                                     reqs)
    return predicted, Workload(
        arrivals=np.array([r.arrival for r in reqs]),
        tokens=ns, predicted=predicted)


def _merge_replicas(reqs, rep, per, n_total) -> FleetScheduleResult:
    waits = np.zeros(n_total)
    e2e = np.zeros(n_total)
    lost = np.ones(n_total, bool)      # un-served stays lost (ragged tails)
    sizes: List[int] = []
    makespan = 0.0
    for r, res in enumerate(per):
        if res is None:
            continue
        gi = np.nonzero(rep == r)[0][:len(res.waits)]
        waits[gi] = res.waits
        e2e[gi] = res.e2e
        lost[gi] = res.lost
        sizes += list(res.batch_sizes)
        makespan = max(makespan, res.makespan)
    return FleetScheduleResult(waits, e2e, lost, sizes, makespan,
                               rep, per)


def _route_and_dispatch(router, policy: BatchPolicy, reqs: List[Request],
                        work_lat, predictor, predict_seed: int, R: int,
                        runner) -> FleetScheduleResult:
    """The ONE serving-layer fleet body shared by :class:`FleetScheduler`
    and :func:`run_fleet_schedule`: resolve the global predicted column,
    estimate routing work (request prompts reach a router-owned
    predictor), assign, then hand each replica's sub-list + prediction
    slice to ``runner(replica, sub_reqs, predicted_slice)``."""
    router = router_from_spec(router)
    ns = np.array([policy.clip(r.target_output_tokens) for r in reqs],
                  np.float64)
    predicted, wl = _fleet_predictions(policy, predictor, predict_seed,
                                       ns, reqs)
    work = router.routing_work(wl, work_lat, predict_seed,
                               prompts=[r.prompt_tokens for r in reqs])
    rep = np.asarray(router.assign(wl.arrivals, work, R, predict_seed),
                     np.int64)
    per: List[Optional[ScheduleResult]] = []
    for r in range(R):
        idx = np.nonzero(rep == r)[0]
        if not len(idx):
            per.append(None)
            continue
        per.append(runner(r, [reqs[i] for i in idx],
                          None if predicted is None else predicted[idx]))
    return _merge_replicas(reqs, rep, per, len(reqs))


class FleetScheduler:
    """Bind a router + a batch policy to R virtual-timeline replicas.

    ``router``: a :mod:`repro.core.fleet` RoutingPolicy, registry name, or
    spec dict.  ``policy`` is the template every replica runs (policies
    are stateless between runs, so one instance serves all replicas).
    ``predictor`` overrides the policy's length predictor exactly like
    :class:`~repro.serving.scheduler.PolicyScheduler`'s parameter."""

    def __init__(self, router, policy: BatchPolicy, clock: ModelClock,
                 R: int, predictor=None, predict_seed: int = 0,
                 faults=None, **fault_kw):
        assert R >= 1
        self.router = router_from_spec(router)
        self.policy = policy
        self.clock = clock
        self.R = int(R)
        self.predictor = predictor
        self.predict_seed = predict_seed
        # resilience path (repro.serving.resilience): a fault model/spec
        # or any of its knobs (kill_at / shed_prob / hedge_slo / ...)
        # reroutes run() through the fault-aware twin; None + no knobs
        # keeps the PR 5 body verbatim.
        self.faults = faults
        self.fault_kw = fault_kw

    def run(self, reqs: List[Request]) -> FleetScheduleResult:
        pol = self.policy
        if self.faults is not None or self.fault_kw:
            from repro.serving.resilience import ResilientFleetScheduler
            return ResilientFleetScheduler(
                self.router, pol, self.clock, self.R,
                predictor=self.predictor, predict_seed=self.predict_seed,
                faults=self.faults, **self.fault_kw).run(reqs)

        def runner(r, sub, predicted):
            if isinstance(pol, ContinuousPolicy):
                # continuous batching binds its own scheduler (slot refill
                # has no formation(); admission is FCFS, prediction-free)
                return pol.scheduler(self.clock).run(sub)
            return PolicyScheduler(pol, self.clock,
                                   predict_seed=self.predict_seed).run(
                sub, predicted=predicted)

        return _route_and_dispatch(self.router, pol, reqs,
                                   getattr(self.clock, "single", None),
                                   self.predictor, self.predict_seed,
                                   self.R, runner)


def run_fleet_schedule(router, policy: BatchPolicy,
                       engines, reqs: List[Request],
                       R: Optional[int] = None, lat=None,
                       predictor=None, predict_seed: int = 0,
                       faults=None, **fault_kw) -> FleetScheduleResult:
    """Execute a routed fleet on the REAL engine layer: form each
    replica's batches on the virtual arrival timeline and run them through
    :func:`~repro.serving.scheduler.run_engine_schedule` (prefill + fused
    chunked decode, wall-clock batch durations).

    ``engines``: a list of R :class:`~repro.serving.engine.Engine`
    instances, or ONE engine shared by every replica (replica timelines
    are virtual, so batches are simply replica-tagged work on the same
    hardware).  ``lat`` (a ``BatchLatencyModel``/``LatencyModel``)
    calibrates the router's work units in seconds; without it the backlog
    routers fall back to raw predicted tokens as the work unit.

    ``faults`` (a :mod:`repro.core.faults` model/name/spec) or any
    resilience knob (``kill_at``, ``shed_prob``, ``hedge_slo``, ...)
    reroutes through
    :func:`repro.serving.resilience.run_resilient_engine_fleet`;
    omitted, the PR 5 body runs verbatim."""
    if faults is not None or fault_kw:
        from repro.serving.resilience import run_resilient_engine_fleet
        return run_resilient_engine_fleet(
            router, policy, engines, reqs, R=R, lat=lat,
            predictor=predictor, predict_seed=predict_seed,
            faults=faults, **fault_kw)
    if isinstance(engines, (list, tuple)):
        engine_of = list(engines)
        if R is None:
            R = len(engine_of)
        assert R == len(engine_of)
    else:
        assert R is not None and R >= 1, "pass R with a single shared engine"
        engine_of = [engines] * R

    def runner(r, sub, predicted):
        return run_engine_schedule(policy, engine_of[r], sub,
                                   predict_seed=predict_seed,
                                   predicted=predicted)

    return _route_and_dispatch(router, policy, reqs, lat, predictor,
                               predict_seed, R, runner)


def summarize_fleet(result: FleetScheduleResult,
                    warmup_frac: float = 0.1) -> dict:
    """Aggregate serving metrics plus the per-replica breakdown and the
    load split (requests per replica)."""
    out = summarize(result, warmup_frac=warmup_frac)
    rep = result.replica_of
    out["replica_requests"] = np.bincount(
        rep[rep >= 0], minlength=len(result.per_replica)).tolist()
    out["per_replica"] = [
        None if res is None else summarize(res, warmup_frac=warmup_frac)
        for res in result.per_replica]
    return out


__all__ = ["FleetScheduleResult", "FleetScheduler", "run_fleet_schedule",
           "summarize_fleet"]
