"""Fleet serving layer: a RoutingPolicy in front of R replica schedulers.

:mod:`repro.core.fleet` defines *what* a router is (assignment as a
function of arrivals + predicted work, never of replica service state) and
validates it on the simulator layers; this module runs the same routers on
the request-list layers:

  * :class:`FleetScheduler` — the virtual-timeline fleet: route a request
    list, then drive R independent :class:`~repro.serving.scheduler.
    PolicyScheduler` timelines (one per replica, any registered
    ``BatchPolicy``) and merge the results back into global request order.
  * :func:`run_fleet_schedule` — the engine fleet: each replica's batches
    execute on a REAL engine (one :class:`~repro.serving.engine.Engine`
    per replica, or one engine shared across replica-tagged batches —
    replica timelines are virtual, so wall-clock batch durations compose
    either way).

Both resolve the predicted-length column ONCE for the whole fleet
(:func:`repro.core.predictors.resolve_predictions` — the same shared
resolver the single-server scheduler and engine layers use) and hand each
replica its slice, so routing (``least_work`` backlogs) and membership
(SRPT ordering, multi-bin routing) see ONE consistent set of predictions.

:func:`summarize_fleet` reports aggregate + per-replica serving metrics.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

import numpy as np

from repro.core.fleet import router_from_spec
from repro.core.policies import BatchPolicy, ContinuousPolicy, Workload
from repro.data.pipeline import Request
from repro.serving.metrics import summarize
from repro.serving.scheduler import (
    ModelClock, PolicyScheduler, ScheduleResult, _request_predictions,
    run_engine_schedule)


@dataclasses.dataclass
class FleetScheduleResult:
    """ScheduleResult-compatible aggregate (``summarize`` consumes it
    directly) plus the routing decomposition.  Requests a replica's policy
    never serves (fixed batching's ragged tail) are marked ``lost``."""

    waits: np.ndarray            # global request order
    e2e: np.ndarray
    lost: np.ndarray
    batch_sizes: List[int]
    makespan: float              # latest replica makespan
    replica_of: np.ndarray
    per_replica: List[ScheduleResult]
    # per-session accounting (repro.core.sessions); None on
    # session-free runs — the historical result shape
    sessions: Optional[dict] = None
    # fleet KV-occupancy accounting (repro.core.memory); None on
    # budget-free runs
    memory: Optional[dict] = None


def _fleet_predictions(policy, predictor, predict_seed: int,
                       ns: np.ndarray, reqs: List[Request]):
    """(membership predictions, the routing view of the stream): both
    drawn once, globally — a router's own predictor (if any) overrides
    only its work estimate inside ``routing_work``, never the membership
    column."""
    predicted = _request_predictions(policy, predictor, predict_seed, ns,
                                     reqs)
    sess = np.array([r.session for r in reqs], np.int64)
    has_sessions = bool(len(sess)) and bool((sess >= 0).any())
    return predicted, Workload(
        arrivals=np.array([r.arrival for r in reqs]),
        tokens=ns, predicted=predicted,
        session=sess if has_sessions else None,
        turn=(np.array([r.turn for r in reqs], np.int64)
              if has_sessions else None))


def _fleet_memory(per) -> Optional[dict]:
    """Fleet roll-up of per-replica KV accounting: each replica has its
    OWN budget (per-replica HBM, not a pooled resource), so peaks and
    utilizations take the worst replica and token/event counts sum."""
    live = [p for p in per if p is not None]
    ms = [getattr(p, "memory", None) for p in live]
    if not ms or any(m is None for m in ms):
        return None
    ws = np.array([max(len(p.waits), 1) for p in live], np.float64)
    out = {
        "capacity": ms[0]["capacity"],
        "kv_peak": max(m["kv_peak"] for m in ms),
        "kv_mean": float(np.average([m["kv_mean"] for m in ms],
                                    weights=ws)),
        "utilization": max(m["utilization"] for m in ms),
        "allocated": float(sum(m["allocated"] for m in ms)),
        "freed": float(sum(m["freed"] for m in ms)),
        "deferred_requests": int(sum(m.get("deferred_requests", 0)
                                     for m in ms)),
    }
    if all("blocked_batches" in m for m in ms):
        out["blocked_batches"] = int(sum(m["blocked_batches"] for m in ms))
        out["blocked_time"] = float(sum(m["blocked_time"] for m in ms))
    return out


def _merge_replicas(reqs, rep, per, n_total) -> FleetScheduleResult:
    waits = np.zeros(n_total)
    e2e = np.zeros(n_total)
    lost = np.ones(n_total, bool)      # un-served stays lost (ragged tails)
    sizes: List[int] = []
    makespan = 0.0
    for r, res in enumerate(per):
        if res is None:
            continue
        gi = np.nonzero(rep == r)[0][:len(res.waits)]
        waits[gi] = res.waits
        e2e[gi] = res.e2e
        lost[gi] = res.lost
        sizes += list(res.batch_sizes)
        makespan = max(makespan, res.makespan)
    return FleetScheduleResult(waits, e2e, lost, sizes, makespan,
                               rep, per, memory=_fleet_memory(per))


def _route_and_dispatch(router, policy: BatchPolicy, reqs: List[Request],
                        work_lat, predictor, predict_seed: int, R: int,
                        runner) -> FleetScheduleResult:
    """The ONE serving-layer fleet body shared by :class:`FleetScheduler`
    and :func:`run_fleet_schedule`: resolve the global predicted column,
    estimate routing work (request prompts reach a router-owned
    predictor), assign, then hand each replica's sub-list + prediction
    slice to ``runner(replica, sub_reqs, predicted_slice)``."""
    router = router_from_spec(router)
    ns = np.array([policy.clip(r.target_output_tokens) for r in reqs],
                  np.float64)
    predicted, wl = _fleet_predictions(policy, predictor, predict_seed,
                                       ns, reqs)
    work = router.routing_work(wl, work_lat, predict_seed,
                               prompts=[r.prompt_tokens for r in reqs])
    rep = np.asarray(router.assign(wl.arrivals, work, R, predict_seed,
                                   sessions=wl.session),
                     np.int64)
    per: List[Optional[ScheduleResult]] = []
    for r in range(R):
        idx = np.nonzero(rep == r)[0]
        if not len(idx):
            per.append(None)
            continue
        per.append(runner(r, [reqs[i] for i in idx],
                          None if predicted is None else predicted[idx]))
    return _merge_replicas(reqs, rep, per, len(reqs))


class FleetScheduler:
    """Bind a router + a batch policy to R virtual-timeline replicas.

    ``router``: a :mod:`repro.core.fleet` RoutingPolicy, registry name, or
    spec dict.  ``policy`` is the template every replica runs (policies
    are stateless between runs, so one instance serves all replicas).
    ``predictor`` overrides the policy's length predictor exactly like
    :class:`~repro.serving.scheduler.PolicyScheduler`'s parameter."""

    def __init__(self, router, policy: BatchPolicy, clock: ModelClock,
                 R: int, predictor=None, predict_seed: int = 0,
                 faults=None, memory=None, **fault_kw):
        assert R >= 1
        self.router = router_from_spec(router)
        self.policy = policy
        self.clock = clock
        self.R = int(R)
        self.predictor = predictor
        self.predict_seed = predict_seed
        # resilience path (repro.serving.resilience): a fault model/spec
        # or any of its knobs (kill_at / shed_prob / hedge_slo / ...)
        # reroutes run() through the fault-aware twin; None + no knobs
        # keeps the PR 5 body verbatim.
        self.faults = faults
        self.fault_kw = fault_kw
        # per-replica KV budget (repro.core.memory); every replica gets
        # its own copy of the budget (its own HBM)
        from repro.core.memory import (
            check_policy_supports_memory, memory_from_spec)
        budget = memory_from_spec(memory)
        if budget.is_null:
            self.memory = None
        else:
            check_policy_supports_memory(policy)
            if faults is not None or fault_kw:
                raise ValueError(
                    "memory= is not composed with the serving resilience "
                    "path; use the core layers (simulate/fastsim) for "
                    "faults x memory")
            self.memory = budget

    def run(self, reqs: List[Request]) -> FleetScheduleResult:
        pol = self.policy
        if self.faults is not None or self.fault_kw:
            from repro.serving.resilience import ResilientFleetScheduler
            return ResilientFleetScheduler(
                self.router, pol, self.clock, self.R,
                predictor=self.predictor, predict_seed=self.predict_seed,
                faults=self.faults, **self.fault_kw).run(reqs)

        def runner(r, sub, predicted):
            if isinstance(pol, ContinuousPolicy):
                # continuous batching binds its own scheduler (slot refill
                # has no formation(); admission is FCFS, prediction-free)
                return pol.scheduler(self.clock).run(sub)
            return PolicyScheduler(pol, self.clock,
                                   predict_seed=self.predict_seed,
                                   memory=self.memory).run(
                sub, predicted=predicted)

        return _route_and_dispatch(self.router, pol, reqs,
                                   getattr(self.clock, "single", None),
                                   self.predictor, self.predict_seed,
                                   self.R, runner)

    def run_sessions(self, reqs: List[Request],
                     prefix_discount: float = 0.0) -> FleetScheduleResult:
        """Session-aware fleet timeline: the feedback fixed point of
        :mod:`repro.core.sessions` with a routing pass per iteration —
        turn t+1 re-enters the GLOBAL queue at turn t's completion +
        ``think`` and is re-routed (sticky routers key on the session
        column).  ``prefix_discount`` γ: a turn >= 2 landing on its
        parent's replica finds the session's KV there and serves
        ``tokens·(1−γ)``; on any other replica the prefix is cold and
        the full length is served — the affinity-vs-``least_work``
        trade-off, measured end-to-end.  A stream with no multi-turn
        rows takes the plain :meth:`run` path (bit-equal to PR 5/6).
        The resilience path is not composed with sessions."""
        if all(r.turn <= 1 for r in reqs):
            return self.run(reqs)
        if self.faults is not None or self.fault_kw:
            raise ValueError("sessions are not composed with the serving "
                             "resilience path; construct the "
                             "FleetScheduler without faults/knobs")
        if self.memory is not None:
            raise ValueError(
                "sessions x memory is not supported: turn re-entry holds "
                "KV across think times, which the per-batch "
                "allocate/release ledger does not model")
        from repro.core.sessions import (
            _MAX_PASSES, _TOL, _cascade_cancel, _session_summary,
            check_policy_supports_sessions, plan_from_requests)
        pol = self.policy
        check_policy_supports_sessions(pol)
        router = self.router
        m = len(reqs)
        turn = np.array([r.turn for r in reqs], np.int64)
        plan, order_sm, lb = plan_from_requests(reqs)
        ns_full = np.array([pol.clip(r.target_output_tokens) for r in reqs],
                           np.float64)
        predicted, _ = _fleet_predictions(pol, self.predictor,
                                          self.predict_seed, ns_full, reqs)
        prompts = [r.prompt_tokens for r in reqs]
        tok_true = np.array([r.target_output_tokens for r in reqs],
                            np.int64)
        disc_tok = tok_true.copy()
        if prefix_discount > 0.0:
            later = turn > 1
            disc_tok[later] = np.maximum(
                1, np.round(tok_true[later]
                            * (1.0 - prefix_discount)).astype(np.int64))
        arr = lb.copy()
        child = np.nonzero(plan.parent >= 0)[0]
        cancelled = np.zeros(m, bool)
        lost = np.zeros(m, bool)
        rep_row = np.full(m, -1, np.int64)
        ids = np.arange(m)
        w_row = np.zeros(m)
        e2e_row = np.zeros(m)
        comp = np.full(m, np.inf)
        per: List[Optional[ScheduleResult]] = []
        sizes: List[int] = []
        makespan = 0.0
        canc_pass = cancelled
        seen_states = set()
        for _ in range(_MAX_PASSES):
            canc_pass = cancelled   # the set that defines this pass's ids
            active = np.nonzero(~cancelled)[0]
            ids = active[np.lexsort((active, arr[active]))]
            ridx = order_sm[ids]
            wl = Workload(
                arrivals=arr[ids], tokens=ns_full[ridx],
                predicted=None if predicted is None else predicted[ridx],
                session=plan.session[ids], turn=plan.turn[ids])
            work = router.routing_work(wl, getattr(self.clock, "single",
                                                   None),
                                       self.predict_seed,
                                       prompts=[prompts[i] for i in ridx])
            rep_s = np.asarray(router.assign(wl.arrivals, work, self.R,
                                             self.predict_seed,
                                             sessions=wl.session), np.int64)
            new_rep = np.full(m, -1, np.int64)
            new_rep[ids] = rep_s
            sticky = np.zeros(m, bool)
            sticky[child] = (new_rep[child] >= 0) & \
                (new_rep[child] == new_rep[plan.parent[child]])
            comp = np.full(m, np.inf)
            w_row = np.zeros(m)
            e2e_row = np.zeros(m)
            lost_row = np.zeros(m, bool)
            per = []
            sizes = []
            makespan = 0.0
            for r in range(self.R):
                mask = rep_s == r
                sub_p = ids[mask]
                if not len(sub_p):
                    per.append(None)
                    continue
                sub_r = order_sm[sub_p]
                sub_reqs = [dataclasses.replace(
                    reqs[i], arrival=float(arr[p]),
                    target_output_tokens=int(
                        disc_tok[i] if sticky[p] else tok_true[i]))
                    for p, i in zip(sub_p, sub_r)]
                res = PolicyScheduler(
                    pol, self.clock,
                    predict_seed=self.predict_seed).run(
                    sub_reqs, predicted=(None if predicted is None
                                         else predicted[sub_r]))
                per.append(res)
                srv = ~res.lost
                comp[sub_p[srv]] = arr[sub_p[srv]] + res.e2e[srv]
                w_row[sub_p] = res.waits
                e2e_row[sub_p] = res.e2e
                lost_row[sub_p] = res.lost
                sizes += list(res.batch_sizes)
                makespan = max(makespan, res.makespan)
            new_cancelled = _cascade_cancel(plan, lost_row)
            new_arr = arr.copy()
            new_arr[child] = comp[plan.parent[child]] + plan.think[child]
            unresolved = child[~np.isfinite(new_arr[child])]
            new_arr[unresolved] = lb[unresolved]
            new_arr[new_cancelled] = lb[new_cancelled]
            live = child[~new_cancelled[child]]
            delta = float(np.max(np.abs(new_arr[live] - arr[live]))) \
                if len(live) else 0.0
            stable = (np.array_equal(new_cancelled, cancelled)
                      and np.array_equal(lost_row, lost)
                      and np.array_equal(new_rep, rep_row))
            arr, cancelled, lost, rep_row = (new_arr, new_cancelled,
                                             lost_row, new_rep)
            if stable and delta <= _TOL:
                break
            if not stable:
                # shedding can cycle the lost/cancel sets (no fixed
                # point); a repeated set state never converges
                state = (new_cancelled.tobytes(), lost_row.tobytes(),
                         new_rep.tobytes())
                if state in seen_states:
                    break
                seen_states.add(state)
        # report the last SIMULATED pass's cancel set: identical on a
        # converged break, self-consistent on pass exhaustion (shedding
        # can cycle — see repro.core.sessions._tau_event_loop)
        cancelled = canc_pass
        return FleetScheduleResult(
            w_row[ids], e2e_row[ids], lost[ids], sizes, makespan,
            rep_row[ids], per,
            sessions=_session_summary(plan, arr, w_row, comp, cancelled,
                                      lost))


def run_fleet_schedule(router, policy: BatchPolicy,
                       engines, reqs: List[Request],
                       R: Optional[int] = None, lat=None,
                       predictor=None, predict_seed: int = 0,
                       faults=None, memory=None,
                       **fault_kw) -> FleetScheduleResult:
    """Execute a routed fleet on the REAL engine layer: form each
    replica's batches on the virtual arrival timeline and run them through
    :func:`~repro.serving.scheduler.run_engine_schedule` (prefill + fused
    chunked decode, wall-clock batch durations).

    ``engines``: a list of R :class:`~repro.serving.engine.Engine`
    instances, or ONE engine shared by every replica (replica timelines
    are virtual, so batches are simply replica-tagged work on the same
    hardware).  ``lat`` (a ``BatchLatencyModel``/``LatencyModel``)
    calibrates the router's work units in seconds; without it the backlog
    routers fall back to raw predicted tokens as the work unit.

    ``faults`` (a :mod:`repro.core.faults` model/name/spec) or any
    resilience knob (``kill_at``, ``shed_prob``, ``hedge_slo``, ...)
    reroutes through
    :func:`repro.serving.resilience.run_resilient_engine_fleet`;
    omitted, the PR 5 body runs verbatim.

    ``memory`` (budget spec, :mod:`repro.core.memory`): each replica
    admits against its OWN KV budget via
    :func:`~repro.serving.scheduler.run_engine_schedule`'s real-footprint
    gate (not composed with the resilience path)."""
    if memory is not None and (faults is not None or fault_kw):
        raise ValueError(
            "memory= is not composed with the serving resilience path; "
            "use the core layers (simulate/fastsim) for faults x memory")
    if faults is not None or fault_kw:
        from repro.serving.resilience import run_resilient_engine_fleet
        return run_resilient_engine_fleet(
            router, policy, engines, reqs, R=R, lat=lat,
            predictor=predictor, predict_seed=predict_seed,
            faults=faults, **fault_kw)
    if isinstance(engines, (list, tuple)):
        engine_of = list(engines)
        if R is None:
            R = len(engine_of)
        assert R == len(engine_of)
    else:
        assert R is not None and R >= 1, "pass R with a single shared engine"
        engine_of = [engines] * R

    def runner(r, sub, predicted):
        return run_engine_schedule(policy, engine_of[r], sub,
                                   predict_seed=predict_seed,
                                   predicted=predicted, memory=memory)

    return _route_and_dispatch(router, policy, reqs, lat, predictor,
                               predict_seed, R, runner)


def summarize_fleet(result: FleetScheduleResult,
                    warmup_frac: float = 0.1) -> dict:
    """Aggregate serving metrics plus the per-replica breakdown and the
    load split (requests per replica)."""
    out = summarize(result, warmup_frac=warmup_frac)
    rep = result.replica_of
    out["replica_requests"] = np.bincount(
        rep[rep >= 0], minlength=len(result.per_replica)).tolist()
    out["per_replica"] = [
        None if res is None else summarize(res, warmup_frac=warmup_frac)
        for res in result.per_replica]
    return out


__all__ = ["FleetScheduleResult", "FleetScheduler", "run_fleet_schedule",
           "summarize_fleet"]
