"""Serving metrics: the paper's evaluation axis is latency (queueing delay,
loss fraction); we add standard serving percentiles."""

from __future__ import annotations

import numpy as np


def summarize(result, warmup_frac: float = 0.1) -> dict:
    k = int(len(result.waits) * warmup_frac)
    waits = result.waits[k:]
    lost = result.lost[k:]
    e2e = result.e2e[k:]
    served = ~lost
    out = {
        "mean_wait": float(waits.mean()) if waits.size else 0.0,
        "p50_wait": float(np.percentile(waits, 50)) if waits.size else 0.0,
        "p95_wait": float(np.percentile(waits, 95)) if waits.size else 0.0,
        "p99_wait": float(np.percentile(waits, 99)) if waits.size else 0.0,
        "loss_frac": float(lost.mean()) if lost.size else 0.0,
        "mean_wait_served": float(waits[served].mean()) if served.any() else 0.0,
        "mean_e2e": float(e2e[served].mean()) if served.any() else 0.0,
        "mean_batch": (float(np.mean(result.batch_sizes))
                       if result.batch_sizes else 0.0),
        "requests": int(len(waits)),
        "makespan": float(result.makespan),
    }
    rep = getattr(result, "resilience", None)
    if rep is not None:
        # fault accounting (repro.serving.resilience.ResilienceReport):
        # conservation served + shed + failed == arrived
        out.update({
            "served": int(rep.served), "shed": int(rep.shed),
            "failed": int(rep.failed), "retries": int(rep.retries),
            "hedged": int(rep.hedged), "hedge_wins": int(rep.hedge_wins),
            "kill_events": len(rep.kill_events),
            "availability": [float(a) for a in rep.availability],
        })
    memo = getattr(result, "memory", None)
    if memo is not None:
        # KV-occupancy accounting (repro.core.memory): peak/mean live KV
        # tokens vs the budget, plus admission blocking/deferral counts
        out["memory"] = {
            "capacity": memo["capacity"],
            "kv_peak": float(memo["kv_peak"]),
            "kv_mean": float(memo["kv_mean"]),
            "utilization": float(memo["utilization"]),
            "allocated": float(memo["allocated"]),
            "freed": float(memo["freed"]),
            "blocked_batches": int(memo.get("blocked_batches", 0)),
            "blocked_time": float(memo.get("blocked_time", 0.0)),
            "deferred_requests": int(memo.get("deferred_requests", 0)),
        }
    sess = getattr(result, "sessions", None)
    if sess is not None:
        # re-entrant session accounting (repro.core.sessions): per-turn
        # conservation arrived == served + lost, and per-session
        # end-to-end latency (first-turn arrival -> last-turn completion)
        out.update({
            "n_sessions": int(sess["n_sessions"]),
            "turns_arrived": int(sess["turns_arrived"]),
            "turns_served": int(sess["turns_served"]),
            "turns_lost": int(sess["turns_lost"]),
            "turns_cancelled": int(sess["turns_cancelled"]),
            "sessions_completed": int(sess["sessions_completed"]),
            "mean_session_e2e": float(sess["mean_session_e2e"]),
            "p95_session_e2e": float(sess["p95_session_e2e"]),
        })
    return out
