"""Production serving launcher: the paper's technique as the control plane.

Runs the batched engine on a Poisson request stream; the AdaptiveController
watches arrivals/completions and sets (n_max, b_max, policy) from the
paper's queueing models (Eqs 10-13, 25, §IV-D). Straggler mitigation at the
request level = elastic batching + max-token clipping (DESIGN.md §6).

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 32 --lam 0.5
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "dynamic", "elastic"])
    ap.add_argument("--log-mean", type=float, default=3.0)
    ap.add_argument("--log-std", type=float, default=0.7)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.core.control import AdaptiveController
    from repro.core.distributions import LogNormalTokens
    from repro.core.latency_model import BatchLatencyModel, LatencyModel
    from repro.data.pipeline import make_request_stream
    from repro.serving.engine import Engine, EngineConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, decode_cache_update="scatter")
    eng = Engine(cfg, EngineConfig(max_batch=args.max_batch,
                                   max_seq=args.max_seq, prompt_bucket=16))
    dist = LogNormalTokens(args.log_mean, args.log_std,
                           support=args.max_seq // 2)
    reqs = make_request_stream(args.requests, args.lam, dist,
                               vocab=cfg.vocab_size, seed=0)
    ctrl = AdaptiveController(
        LatencyModel(a=5e-3, c=0.05),
        BatchLatencyModel(k1=5e-3, k2=5e-2, k3=1e-4, k4=5e-3),
        theta=119 / 120, elastic_available=(args.policy != "dynamic"),
        min_samples=8)

    clock = 0.0
    served = 0
    waits = []
    i = 0
    while i < len(reqs):
        # collect everything that has arrived by `clock` (dynamic batching)
        rec = ctrl.recommendation()
        b_cap = rec.b_max or args.max_batch
        batch = [reqs[i]]
        ctrl.observe_arrival(reqs[i].arrival)
        clock = max(clock, reqs[i].arrival)
        i += 1
        while i < len(reqs) and reqs[i].arrival <= clock and len(batch) < b_cap:
            ctrl.observe_arrival(reqs[i].arrival)
            batch.append(reqs[i])
            i += 1
        for r in batch:
            waits.append(clock - r.arrival)
        elastic = (rec.policy == "elastic") if args.policy == "auto" \
            else (args.policy == "elastic")
        res = eng.generate([r.prompt_tokens for r in batch],
                           [r.target_output_tokens for r in batch],
                           elastic=elastic, n_max=rec.n_max)
        clock += res["batch_seconds"]
        for r, produced in zip(batch, res["produced"]):
            ctrl.observe_completion(int(produced))
        served += len(batch)
        print(f"[serve] t={clock:8.2f}s batch={len(batch)} "
              f"policy={'elastic' if elastic else 'dynamic'} "
              f"n_max={rec.n_max} served={served}/{args.requests}",
              flush=True)

    print(f"[serve] mean queue wait {np.mean(waits):.3f}s | "
          f"p95 {np.percentile(waits, 95):.3f}s | "
          f"final rec: policy={ctrl.recommendation().policy} "
          f"n_max={ctrl.recommendation().n_max} "
          f"b_max={ctrl.recommendation().b_max}", flush=True)


if __name__ == "__main__":
    main()
