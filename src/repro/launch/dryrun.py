import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, on the single-pod (16,16) and
multi-pod (2,16,16) meshes: ``jax.jit(step).lower(*input_specs).compile()``,
then record

  * ``compiled.memory_analysis()``  (per-chip bytes — proves it fits)
  * ``compiled.cost_analysis()``    (XLA's own numbers, while-body-once)
  * trip-count-corrected FLOPs / bytes / collective wire bytes from our HLO
    parser (repro.utils.hlo) — the numbers §Roofline uses

into results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np


def run_cell(arch: str, shape_id: str, mesh_kind: str, out_dir: str,
             overrides=None, tag: str = "") -> dict:
    from repro.configs import get_config, shape_applicable, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.utils.hlo import analyze_hlo_text, cost_summary

    cfg = get_config(arch)
    rec = {
        "arch": arch, "shape": shape_id, "mesh": mesh_kind, "tag": tag,
        "status": "ok", "time_s": None,
    }
    if not shape_applicable(cfg, shape_id):
        rec["status"] = "skipped_by_design"
        rec["reason"] = ("long_500k requires sub-quadratic decode context; "
                        f"{arch} is pure full attention (DESIGN.md §4)")
        return _write(rec, out_dir)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        cell = build_cell(cfg, shape_id, mesh, overrides=dict(overrides or {}))
        with mesh:
            jitted = jax.jit(cell.step_fn, donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(mem, k) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(mem, k)}
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals", "utilization")}
        hlo_text = compiled.as_text()
        cost = analyze_hlo_text(hlo_text)
        rec["hlo_cost"] = cost_summary(cost)
        rec["hlo_bytes"] = len(hlo_text)
        # cache compressed HLO so the cost model can be refined without
        # recompiling (scripts/reanalyze.py)
        try:
            import zstandard as zstd
            tagp = f"__{tag}" if tag else ""
            os.makedirs(out_dir, exist_ok=True)
            hpath = os.path.join(
                out_dir, f"{arch}__{shape_id}__{mesh_kind}{tagp}.hlo.zst")
            with open(hpath, "wb") as f:
                f.write(zstd.ZstdCompressor(level=6).compress(
                    hlo_text.encode()))
        except Exception:
            pass
        rec["tokens_per_step"] = cell.tokens_per_step
        rec["kind"] = cell.kind
        rec["model_params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        rec["model_flops_total"] = cfg.model_flops(
            cell.tokens_per_step, training=(cell.kind == "train"))
        rec["num_devices"] = mesh.size
        rec["time_s"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["time_s"] = round(time.time() - t0, 1)
    return _write(rec, out_dir)


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        ma = rec.get("memory_analysis", {})
        extra = (f" args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB"
                 f" temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
                 f" flops/dev={rec['hlo_cost']['flops']:.3g}"
                 f" wire={rec['hlo_cost']['collective_wire_bytes']:.3g}B"
                 f" t={rec['time_s']}s")
    elif status == "error":
        extra = " " + rec["error"][:160]
    print(f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
          f"{status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPE_IDS

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = SHAPE_IDS if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_kind}{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped_by_design"):
                            print(f"[dryrun] skip existing {path}", flush=True)
                            continue
                run_cell(arch, shape, mesh_kind, args.out, tag=args.tag)


if __name__ == "__main__":
    main()
