"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
zero allocation) for every (architecture x input shape) dry-run cell, plus
the step function each shape kind lowers.

Shape semantics (per assignment):
  train_4k    -> train_step(params, opt_state, batch)
  prefill_32k -> serve_prefill(params, tokens/embeds [, cross], cache)
  decode_32k  -> serve_decode(params, cache, tokens[B], kv_lens[B])
  long_500k   -> serve_decode with a 512k-token KV cache, batch 1
                 (sub-quadratic archs only; see DESIGN.md)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.distributed.sharding import (
    DEFAULT_RULES, FSDP_RULES, ShardCtx, make_named_sharding)
from repro.models.config import ModelConfig
from repro.models.model import (
    param_specs, cache_specs, prefill, decode_step)
from repro.models.params import Spec, abstract_params, is_spec
from repro.training.optimizer import AdamWConfig, AdamWState
from repro.training.train_step import (
    TrainConfig, make_train_step, train_input_specs)


def rules_for(cfg: ModelConfig):
    rules = dict(FSDP_RULES if cfg.use_fsdp else DEFAULT_RULES)
    rules.update(dict(cfg.sharding_overrides))
    return rules


def _sds(shape, dtype, mesh, axes, rules):
    sharding = None
    if mesh is not None:
        sharding = make_named_sharding(mesh, axes, rules, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def abstract_opt_state(pspecs, mesh, rules, moment_dtype,
                       zero_moments: bool = False):
    """AdamW state stand-ins. With ``zero_moments`` the moments additionally
    shard their embed dim over the data axis (ZeRO-1: GSPMD inserts the
    grad reduce-scatter + param all-gather around the update)."""
    mrules = dict(rules)
    if zero_moments and mrules.get("embed") is None:
        mrules["embed"] = "data"
    m = abstract_params(pspecs, jnp.dtype(moment_dtype), mesh, mrules)
    v = abstract_params(pspecs, jnp.dtype(moment_dtype), mesh, mrules)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=make_named_sharding(mesh, (), rules)
                                if mesh is not None else None)
    return AdamWState(step=step, m=m, v=v)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, mesh, rules,
                   dtype=jnp.bfloat16):
    cs = cache_specs(cfg, batch, max_seq)
    return jax.tree.map(
        lambda s: _sds(s.shape, dtype, mesh, s.axes, rules),
        cs, is_leaf=is_spec)


@dataclasses.dataclass
class DryrunCell:
    """Everything needed to lower one (arch x shape) cell on a mesh."""
    step_fn: callable
    args: tuple           # ShapeDtypeStructs
    donate: tuple
    kind: str
    tokens_per_step: int  # for MODEL_FLOPS accounting


def moment_dtype_for(cfg: ModelConfig) -> str:
    # >=100B params: bf16 moments to fit v5e HBM (DESIGN.md §5)
    return "bfloat16" if cfg.param_count() > 100e9 else "float32"


def param_dtype_for(cfg: ModelConfig):
    return jnp.bfloat16


def microbatches_for(cfg: ModelConfig, global_batch: int, mesh) -> int:
    """Per-device microbatch of ~1 keeps the remat-scan carry bounded."""
    if mesh is None:
        return 1
    batch_shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            batch_shards *= mesh.shape[ax]
    per_dev = max(global_batch // batch_shards, 1)
    return min(per_dev, global_batch)


def build_cell(cfg: ModelConfig, shape_id: str, mesh,
               overrides: dict = None) -> DryrunCell:
    shp = SHAPES[shape_id]
    seq, gb, kind = shp["seq_len"], shp["global_batch"], shp["kind"]
    rules = rules_for(cfg)
    zero_moments = False
    if overrides:
        if "rules" in overrides:
            rules.update(overrides.pop("rules"))
        zero_moments = bool(overrides.pop("zero_moments", False))
    if kind == "train":
        mb = microbatches_for(cfg, gb, mesh)
        cfg = dataclasses.replace(cfg, num_microbatches=mb)
    if cfg.num_experts and mesh is not None:
        shards = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                shards *= mesh.shape[ax]
        cfg = dataclasses.replace(cfg, moe_groups=shards)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    ctx = ShardCtx(mesh=mesh, rules=rules)
    pspecs = param_specs(cfg)
    pdt = param_dtype_for(cfg)
    aparams = abstract_params(pspecs, pdt, mesh, rules)

    if kind == "train":
        tcfg = TrainConfig(
            adamw=AdamWConfig(moment_dtype=moment_dtype_for(cfg)),
            grad_accum_dtype=("bfloat16" if cfg.param_count() > 100e9
                              else "float32"))
        step = make_train_step(cfg, tcfg, ctx)
        batch = {
            k: _sds(v.shape, v.dtype, mesh,
                    ("batch",) + (None,) * (len(v.shape) - 1), rules)
            for k, v in train_input_specs(cfg, gb, seq).items()
        }
        opt = abstract_opt_state(pspecs, mesh, rules, tcfg.adamw.moment_dtype,
                                 zero_moments=zero_moments)
        return DryrunCell(step_fn=step, args=(aparams, opt, batch),
                          donate=(0, 1), kind=kind, tokens_per_step=gb * seq)

    if kind == "prefill":
        cache = abstract_cache(cfg, gb, seq, mesh, rules)

        def serve_prefill(params, cache, inputs):
            return prefill(cfg, params, cache=cache, ctx=ctx, **inputs)

        inputs = {}
        if cfg.embeddings_input:
            inputs["embeds"] = _sds((gb, seq, cfg.d_model), jnp.bfloat16,
                                    mesh, ("batch", "seq", "embed"), rules)
        else:
            inputs["tokens"] = _sds((gb, seq), jnp.int32, mesh,
                                    ("batch", "seq"), rules)
        if cfg.vision_seq:
            inputs["cross_kv"] = _sds((gb, cfg.vision_seq, cfg.d_model),
                                      jnp.bfloat16, mesh,
                                      ("batch", "vis_seq", "embed"), rules)
        return DryrunCell(step_fn=serve_prefill,
                          args=(aparams, cache, inputs),
                          donate=(1,), kind=kind, tokens_per_step=gb * seq)

    # decode
    if cfg.decode_unroll_layers:
        cs = cache_specs(cfg, gb, seq)
        cache = {
            f"g{g}": jax.tree.map(
                lambda s: _sds(s.shape[1:], jnp.bfloat16, mesh,
                               s.axes[1:], rules),
                cs, is_leaf=is_spec)
            for g in range(cfg.num_groups)
        }
    else:
        cache = abstract_cache(cfg, gb, seq, mesh, rules)

    def serve_decode(params, cache, tokens, kv_lens):
        return decode_step(cfg, params, cache, tokens, kv_lens, ctx=ctx)

    tokens = _sds((gb,), jnp.int32, mesh, ("batch",), rules)
    kv_lens = _sds((gb,), jnp.int32, mesh, ("batch",), rules)
    return DryrunCell(step_fn=serve_decode,
                      args=(aparams, cache, tokens, kv_lens),
                      donate=(1,), kind=kind, tokens_per_step=gb)
