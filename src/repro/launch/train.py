"""Production training launcher with fault tolerance.

Supervisor loop: build mesh -> restore latest checkpoint (resharding if the
mesh changed) -> step with heartbeat + step-timeout detection -> periodic
async checkpoints -> on failure, restart from the last complete checkpoint.

CPU-scale usage (smoke model, real training):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt

Cluster usage keeps the same driver; the mesh comes from
``make_production_mesh()`` and each host runs this entrypoint under its own
process index (jax.distributed).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--step-timeout-s", type=float, default=600.0,
                    help="straggler/failure detection: a step exceeding this "
                         "aborts the attempt and restarts from checkpoint")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--simulate-failure-at", type=int, default=-1,
                    help="test hook: raise at this step on the first attempt")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import SyntheticLMDataset
    from repro.models.model import param_specs
    from repro.models.params import init_params
    from repro.training.checkpoint import CheckpointManager
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_step import TrainConfig, make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=args.lr, warmup_steps=10,
                                         total_steps=args.steps))
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3, async_write=True)
    ds = SyntheticLMDataset(cfg, args.seq_len, args.global_batch, seed=0)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    attempt = 0
    while attempt <= args.max_restarts:
        try:
            params = init_params(param_specs(cfg), jax.random.PRNGKey(0),
                                 jnp.float32)
            opt = adamw_init(params, tcfg.adamw)
            start_step = 0
            if mgr.latest_step() is not None:
                (params, opt), start_step, extra = mgr.restore((params, opt))
                ds.index = int(extra.get("data_index", start_step))
                print(f"[train] restored step {start_step} "
                      f"(data index {ds.index})", flush=True)
            for step in range(start_step, args.steps):
                t0 = time.time()
                if attempt == 0 and step == args.simulate_failure_at:
                    raise RuntimeError("injected failure (test hook)")
                batch = {k: jnp.asarray(v) for k, v in ds.batch().items()}
                params, opt, metrics = step_fn(params, opt, batch)
                dt = time.time() - t0
                if dt > args.step_timeout_s:
                    raise TimeoutError(
                        f"step {step} took {dt:.1f}s > timeout "
                        f"(straggler/failure suspected)")
                if step % 10 == 0 or step == args.steps - 1:
                    print(f"[train] step {step} loss={float(metrics['loss']):.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
                    mgr.save(step + 1, (params, opt),
                             extra={"data_index": ds.index})
            mgr.wait()
            print("[train] done", flush=True)
            return
        except (RuntimeError, TimeoutError) as e:
            attempt += 1
            print(f"[train] attempt failed ({e}); restart {attempt}/"
                  f"{args.max_restarts} from latest checkpoint", flush=True)
    raise SystemExit("[train] exceeded max restarts")


if __name__ == "__main__":
    main()
