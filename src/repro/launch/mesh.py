"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax initialization, while smoke tests and benches must see 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod:   (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
    pure data parallelism across the DCN/ICI-superpod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_degraded_mesh(data: int = 8, model: int = 16):
    """Elastic-scaling target: e.g. after losing half a pod's hosts, restart
    on (8, 16) = 128 chips and restore the checkpoint (resharded)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_mesh(devices=None):
    """Whatever devices exist (CPU smoke tests): 1xN mesh."""
    devices = devices if devices is not None else jax.devices()
    return jax.make_mesh((1, len(devices)), ("data", "model"))
