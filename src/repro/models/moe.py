"""Mixture-of-Experts FFN (token-choice top-k, capacity-bounded dispatch).

TPU-friendly static-shape implementation: tokens are scattered into a
``[groups, experts, capacity, d_model]`` buffer (position-in-expert via
cumsum, GShard style), expert FFNs run as one batched einsum over the expert
dim (expert parallelism over the `model` mesh axis when ``num_experts``
divides it; otherwise the expert FFN dim shards), and results combine with
the routing weights. Overflowing tokens are dropped (their residual passes
through) — standard capacity-factor semantics; ``capacity_factor >= E/k`` is
exactly dropless because capacity then clamps at the group token count.

``cfg.moe_groups`` (GShard's group dim) makes dispatch *local to a data
shard*: with groups == batch shards, the scatter/gather never crosses
devices, eliminating the dispatch collectives entirely (EXPERIMENTS.md
SPerf, mixtral iteration 2). groups=1 reproduces global dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardCtx
from repro.models.config import ModelConfig
from repro.models.params import Spec


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    specs = {
        "router": Spec((d, e), ("embed", "experts"), scale=0.1),
        "w_up": Spec((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_down": Spec((e, f, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.gated_ffn:
        specs["w_gate"] = Spec((e, d, f), ("experts", "embed", "expert_ffn"))
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        specs["shared_up"] = Spec((d, fs), ("embed", "ffn"))
        specs["shared_down"] = Spec((fs, d), ("ffn", "embed"))
        if cfg.gated_ffn:
            specs["shared_gate"] = Spec((d, fs), ("embed", "ffn"))
    return specs


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    cap = int(np.ceil(cfg.capacity_factor * group_tokens *
                      cfg.num_experts_per_tok / cfg.num_experts))
    cap = max(4, ((cap + 3) // 4) * 4)
    # a single expert can never receive more than group_tokens assignments
    # (top-k indices are distinct), so capacity_factor >= E/k is dropless.
    return min(cap, group_tokens)


def _dispatch_group(xg, top_w, top_idx, e: int, cap: int):
    """xg: [t,d]; top_w/top_idx: [t,k]. Returns (buf [E,cap,d],
    e_flat [t*k], p_flat [t*k], keep [t,k])."""
    t, d = xg.shape
    k = top_idx.shape[1]
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)      # [t,k,E]
    flat = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                # [t*k,E]
    pos = (pos_in_e * flat).sum(-1).reshape(t, k)             # [t,k]
    keep = pos < cap
    e_flat = jnp.where(keep, top_idx, e).reshape(-1)          # drop -> row e
    p_flat = jnp.where(keep, pos, 0).reshape(-1)
    tok_src = jnp.repeat(xg[:, None, :], k, axis=1)           # [t,k,d]
    buf = jnp.zeros((e + 1, cap, d), xg.dtype).at[
        e_flat, p_flat].add(tok_src.reshape(t * k, d))[:e]
    return buf, e_flat, p_flat, keep


def moe_block(p, x, cfg: ModelConfig, ctx: ShardCtx, *, return_aux=False):
    """x: [B,S,D] -> [B,S,D] (+ aux load-balancing loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    g = cfg.moe_groups if t % max(cfg.moe_groups, 1) == 0 else 1
    tg = t // g
    cap = _capacity(cfg, tg)
    act = jax.nn.gelu if cfg.ffn_activation == "gelu" else jax.nn.silu

    xt = x.reshape(t, d)
    gates = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                       p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gates, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)                  # [T,k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # group-local dispatch (vmapped over groups; groups map to data shards)
    xg = xt.reshape(g, tg, d)
    wg = top_w.reshape(g, tg, k)
    ig = top_idx.reshape(g, tg, k)
    xg = ctx.c(xg, "moe_groups", None, "embed")
    buf, e_flat, p_flat, keep = jax.vmap(
        lambda xx, ii: _dispatch_group(xx, None, ii, e, cap),
        in_axes=(0, 0))(xg, ig)                               # buf [G,E,c,d]
    buf = ctx.c(buf, "moe_groups", "experts", None, "embed")

    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    if cfg.gated_ffn:
        gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    h = ctx.c(h, "moe_groups", "experts", None, "expert_ffn")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    out_buf = ctx.c(out_buf, "moe_groups", "experts", None, "embed")

    # gather back per group
    def _combine(ob, ef, pf, kp, ww):
        gathered = ob[ef.clip(0, e - 1), pf]                  # [t*k,d]
        gathered = jnp.where(kp.reshape(-1, 1), gathered, 0.0)
        weighted = gathered * ww.reshape(-1, 1).astype(ob.dtype)
        return weighted.reshape(tg, k, d).sum(axis=1)

    out = jax.vmap(_combine)(out_buf, e_flat, p_flat, keep, wg)  # [G,tg,d]
    out = out.reshape(t, d)

    if cfg.num_shared_experts:
        s_up = xt @ p["shared_up"].astype(x.dtype)
        if cfg.gated_ffn:
            s_h = act(xt @ p["shared_gate"].astype(x.dtype)) * s_up
        else:
            s_h = act(s_up)
        out = out + s_h @ p["shared_down"].astype(x.dtype)

    out = out.reshape(b, s, d)
    out = ctx.c(out, "batch", "seq", "embed")

    if return_aux:
        # Switch-style load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
        onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
        frac = onehot.sum(axis=(0, 1)) / (t * k)
        mean_p = probs.mean(axis=0)
        aux = e * jnp.sum(frac * mean_p)
        return out, aux
    return out, jnp.float32(0.0)
