"""Model assembly: embeddings -> scanned layer groups -> head.

The layer stack is ``cfg.group_pattern`` repeated ``cfg.num_groups`` times and
executed with ``jax.lax.scan`` over stacked parameters, so HLO size is
independent of depth (100-layer configs compile on one CPU core). Each
pattern position owns its parameter subtree and (optionally) a cache slot.

Three entry points:
  forward(...)      full-sequence logits (training)
  prefill(...)      full-sequence + writes KV/SSM caches, returns last logits
  decode_step(...)  one token against the caches
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.config import ModelConfig
from repro.models.params import Spec, stack_specs
from repro.models import layers as L
from repro.models.moe import moe_specs, moe_block
from repro.models.mamba import mamba_specs, mamba_block


# ----------------------------------------------------------------------------
# Specs
# ----------------------------------------------------------------------------

def _position_specs(cfg: ModelConfig, mixer: str, ffn: str):
    s = {"pre_norm": L.rmsnorm_specs(cfg.d_model)}
    if mixer == "attn":
        s["mixer"] = L.attention_specs(cfg)
    elif mixer == "cross_attn":
        s["mixer"] = L.attention_specs(cfg, cross=True)
    elif mixer == "mamba":
        s["mixer"] = mamba_specs(cfg)
    if ffn == "dense":
        s["ffn"] = L.ffn_specs(cfg)
        s["ffn_norm"] = L.rmsnorm_specs(cfg.d_model)
        if mixer == "cross_attn":
            s["ffn_gate"] = Spec((), (), init="zeros")
    elif ffn == "moe":
        s["ffn"] = moe_specs(cfg)
        s["ffn_norm"] = L.rmsnorm_specs(cfg.d_model)
    return s


def param_specs(cfg: ModelConfig):
    group = {}
    for i, (mixer, ffn) in enumerate(cfg.group_pattern):
        group[f"pos{i}"] = _position_specs(cfg, mixer, ffn)
    specs = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": L.rmsnorm_specs(cfg.d_model),
        "groups": stack_specs(group, cfg.num_groups),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                cache_dtype=jnp.bfloat16):
    """Spec tree for the decode caches (stacked over groups)."""
    g = cfg.num_groups
    tree = {}
    for i, (mixer, _) in enumerate(cfg.group_pattern):
        if mixer == "attn":
            span = max_seq if cfg.sliding_window is None else min(
                max_seq, cfg.sliding_window)
            # NOTE: sliding-window caches are allocated at window size only
            # when max_seq exceeds the window (ring-buffer semantics handled
            # by position arithmetic in the scheduler; dry-run uses full span
            # for faithfulness when max_seq <= window).
            if cfg.cache_layout == "bhsd":
                shp = (g, batch, cfg.num_kv_heads, span, cfg.head_dim)
                ax = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
            else:
                shp = (g, batch, span, cfg.num_kv_heads, cfg.head_dim)
                ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            tree[f"pos{i}"] = {"k": Spec(shp, ax, init="zeros"),
                               "v": Spec(shp, ax, init="zeros")}
        elif mixer == "cross_attn":
            shp = (g, batch, cfg.vision_seq, cfg.num_kv_heads, cfg.head_dim)
            ax = ("layers", "batch", "vis_seq", "kv_heads", "head_dim")
            tree[f"pos{i}"] = {"k_img": Spec(shp, ax, init="zeros"),
                               "v_img": Spec(shp, ax, init="zeros")}
        elif mixer == "mamba":
            ck = (g, batch, cfg.ssm_conv_kernel - 1, cfg.ssm_conv_dim)
            ss = (g, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
            tree[f"pos{i}"] = {
                "conv": Spec(ck, ("layers", "batch", None, "conv_dim"), init="zeros"),
                "ssm": Spec(ss, ("layers", "batch", "ssm_heads", None, "ssm_state"),
                            init="zeros"),
            }
    return tree


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               cache_dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, cache_dtype),
        cache_specs(cfg, batch, max_seq, cache_dtype),
        is_leaf=lambda x: isinstance(x, Spec))


# ----------------------------------------------------------------------------
# Group application
# ----------------------------------------------------------------------------

def _apply_position(cfg: ModelConfig, mixer: str, ffn: str, p, x, ctx,
                    *, positions, pos_cache, kv_lens, cross_kv, mode):
    """One (mixer, ffn) layer. Returns (x, new_pos_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    h = L.rmsnorm(x, p["pre_norm"], cfg.norm_eps)
    new_cache = pos_cache

    if mixer == "attn":
        attn_cache = None
        if pos_cache is not None:
            attn_cache = {"k": pos_cache["k"], "v": pos_cache["v"]}
        out, upd = L.attention_block(
            p["mixer"], h, cfg, ctx, positions=positions,
            cache=attn_cache, kv_lens=kv_lens)
        if upd is not None:
            new_cache = {"k": upd["k"], "v": upd["v"]}
        x = x + out
    elif mixer == "cross_attn":
        if mode == "decode":
            # use cached image K/V
            q = jnp.einsum("bsd,dhk->bshk", h, p["mixer"]["wq"].astype(h.dtype))
            if "q_norm" in p["mixer"]:
                q = L.rmsnorm(q, p["mixer"]["q_norm"], cfg.norm_eps)
            out = L.decode_attention(
                q, pos_cache["k_img"], pos_cache["v_img"],
                jnp.full((h.shape[0],), pos_cache["k_img"].shape[1], jnp.int32),
                window=None, ctx=ctx)
            out = jnp.einsum("bshk,hkd->bsd", out, p["mixer"]["wo"].astype(h.dtype))
            out = jnp.tanh(p["mixer"]["attn_gate"].astype(jnp.float32)).astype(
                out.dtype) * out
        else:
            out, _ = L.attention_block(
                p["mixer"], h, cfg, ctx, positions=positions, cross_kv=cross_kv)
            if pos_cache is not None:
                k = jnp.einsum("bsd,dhk->bshk", cross_kv,
                               p["mixer"]["wk"].astype(h.dtype))
                v = jnp.einsum("bsd,dhk->bshk", cross_kv,
                               p["mixer"]["wv"].astype(h.dtype))
                if "k_norm" in p["mixer"]:
                    k = L.rmsnorm(k, p["mixer"]["k_norm"], cfg.norm_eps)
                new_cache = {"k_img": k.astype(pos_cache["k_img"].dtype),
                             "v_img": v.astype(pos_cache["v_img"].dtype)}
        x = x + out
    elif mixer == "mamba":
        out, upd = mamba_block(p["mixer"], h, cfg, ctx, state=pos_cache)
        if upd is not None:
            new_cache = upd
        x = x + out

    if ffn != "none":
        h2 = L.rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        if ffn == "dense":
            out = L.ffn_block(p["ffn"], h2, cfg, ctx)
            if "ffn_gate" in p:
                out = jnp.tanh(p["ffn_gate"].astype(jnp.float32)).astype(
                    out.dtype) * out
        else:
            out, aux = moe_block(p["ffn"], h2, cfg, ctx, return_aux=True)
        x = x + out
    return ctx.c(x, "batch", "seq", "embed"), new_cache, aux


def _apply_group(cfg: ModelConfig, gparams, x, ctx, *, positions,
                 group_cache, kv_lens, cross_kv, mode):
    auxes = jnp.float32(0.0)
    new_cache = {} if group_cache is not None else None
    for i, (mixer, ffn) in enumerate(cfg.group_pattern):
        key = f"pos{i}"
        pos_cache = None if group_cache is None else group_cache.get(key)
        x, upd, aux = _apply_position(
            cfg, mixer, ffn, gparams[key], x, ctx, positions=positions,
            pos_cache=pos_cache, kv_lens=kv_lens, cross_kv=cross_kv, mode=mode)
        auxes = auxes + aux
        if group_cache is not None and pos_cache is not None:
            new_cache[key] = upd
    return x, new_cache, auxes


# ----------------------------------------------------------------------------
# Entry points
# ----------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params, tokens=None, embeds=None,
                  positions=None, ctx: ShardCtx = NULL_CTX):
    if embeds is not None:
        x = embeds
    else:
        tok = jnp.clip(tokens, 0, cfg.padded_vocab - 1)
        x = params["embed"].astype(jnp.bfloat16 if cfg.dtype == "bfloat16"
                                   else jnp.float32)[tok]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return ctx.c(x, "batch", "seq", "embed")


def _head(cfg: ModelConfig, params, x, ctx: ShardCtx):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.logits_fp32:
        logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return ctx.c(logits, "batch", "seq", "vocab")


def _scan_groups(cfg: ModelConfig, params, x, ctx, *, positions, cache,
                 kv_lens, cross_kv, mode):
    """Scan the group stack; cache (if any) rides along as scan xs/ys."""

    def body(carry, xs):
        h, aux = carry
        gparams, gcache = xs
        h, new_cache, a = _apply_group(
            cfg, gparams, h, ctx, positions=positions, group_cache=gcache,
            kv_lens=kv_lens, cross_kv=cross_kv, mode=mode)
        return (h, aux + a), new_cache

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (params["groups"], cache)
    (x, aux), new_cache = lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, new_cache, aux


def forward(cfg: ModelConfig, params, tokens=None, *, embeds=None,
            cross_kv=None, ctx: ShardCtx = NULL_CTX, positions=None):
    """Full-sequence logits (training / evaluation). No caches."""
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed_inputs(cfg, params, tokens, embeds, positions, ctx)
    x, _, aux = _scan_groups(cfg, params, x, ctx, positions=positions,
                             cache=None, kv_lens=None, cross_kv=cross_kv,
                             mode="forward")
    return _head(cfg, params, x, ctx), aux


def prefill(cfg: ModelConfig, params, tokens=None, *, embeds=None,
            cross_kv=None, cache, prompt_lens=None, ctx: ShardCtx = NULL_CTX):
    """Run the prompt, fill the caches, return last-position logits."""
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if prompt_lens is None:
        prompt_lens = jnp.full((b,), s, jnp.int32)
    x = _embed_inputs(cfg, params, tokens, embeds, positions, ctx)
    x, new_cache, _ = _scan_groups(cfg, params, x, ctx, positions=positions,
                                   cache=cache, kv_lens=prompt_lens,
                                   cross_kv=cross_kv, mode="prefill")
    logits = _head(cfg, params, x, ctx)
    last = jnp.take_along_axis(
        logits, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]
    return last, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, kv_lens,
                ctx: ShardCtx = NULL_CTX):
    """One decode step. tokens: [B] int32; kv_lens: [B] current lengths.

    Returns (logits [B, vocab], new_cache).

    With ``cfg.decode_unroll_layers`` the (small) decode body is unrolled:
    each group's cache leaves are indexed statically so XLA aliases every
    cache update in place instead of copying through the scan's stacked
    carry. ``cache`` may then be either the stacked pytree (sliced here) or
    a pre-split {"g<i>": group_cache} dict.
    """
    b = tokens.shape[0]
    positions = kv_lens[:, None]
    x = _embed_inputs(cfg, params, tokens[:, None], None, positions, ctx)
    if cfg.decode_unroll_layers:
        split = isinstance(cache, dict) and "g0" in cache
        new_cache = {}
        aux = jnp.float32(0.0)
        for g in range(cfg.num_groups):
            gparams = jax.tree.map(lambda l: l[g], params["groups"])
            gcache = (cache[f"g{g}"] if split
                      else jax.tree.map(lambda l: l[g], cache))
            x, upd, a = _apply_group(
                cfg, gparams, x, ctx, positions=positions, group_cache=gcache,
                kv_lens=kv_lens, cross_kv=None, mode="decode")
            new_cache[f"g{g}"] = upd
        logits = _head(cfg, params, x, ctx)
        return logits[:, 0], new_cache
    x, new_cache, _ = _scan_groups(cfg, params, x, ctx, positions=positions,
                                   cache=cache, kv_lens=kv_lens,
                                   cross_kv=None, mode="decode")
    logits = _head(cfg, params, x, ctx)
    return logits[:, 0], new_cache


def split_cache(cache, num_groups: int):
    """Stacked cache pytree -> {"g<i>": per-group leaves} (for unrolled
    decode; one-time cost after prefill)."""
    return {f"g{g}": jax.tree.map(lambda l: l[g], cache)
            for g in range(num_groups)}


def stack_group_cache(split, num_groups: int):
    """Inverse of ``split_cache``: {"g<i>": group leaves} -> stacked pytree.
    Used by the fused decode loop to keep a structure-invariant scan carry
    when ``cfg.decode_unroll_layers`` makes decode_step return a split
    cache."""
    return jax.tree.map(lambda *ls: jnp.stack(ls),
                        *[split[f"g{g}"] for g in range(num_groups)])
